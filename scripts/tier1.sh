#!/usr/bin/env bash
# Tier-1 verification: install dev deps when the environment allows it
# (hermetic containers fall back to tests/_hypothesis_fallback.py) and run
# the full suite.
#
#   ./scripts/tier1.sh [extra pytest args...]
#
# Environment:
#   PYTHON=...        interpreter to use (default: python, else python3)
#   TIER1_OFFLINE=1   never touch pip — rely on the vendored hypothesis
#                     fallback (CI sets this so a flaky index can't fail
#                     or, worse, silently alter the run)
#
# Exit-code audit: `exec` replaces this shell with pytest, so pytest's
# exit code IS the script's exit code — no `$?` plumbing to get wrong.
# The only command allowed to fail is the best-effort pip install, which
# is explicitly `|| echo`-guarded; everything else aborts via `set -e`.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ -z "${PYTHON:-}" ]]; then
    PYTHON=python
    command -v python >/dev/null 2>&1 || PYTHON=python3
fi

if [[ "${TIER1_OFFLINE:-0}" != "1" ]] \
        && ! "$PYTHON" -c "import hypothesis" >/dev/null 2>&1; then
    "$PYTHON" -m pip install -r requirements-dev.txt >/dev/null 2>&1 \
        || echo "note: pip install unavailable; using vendored hypothesis fallback"
fi

# Prepend the repo's src/ as an ABSOLUTE path (a relative entry breaks if
# a test chdirs) while preserving any PYTHONPATH the caller already set.
PYTHONPATH="$PWD/src${PYTHONPATH:+:$PYTHONPATH}" \
    exec "$PYTHON" -m pytest -x -q "$@"

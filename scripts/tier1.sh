#!/usr/bin/env bash
# Tier-1 verification: install dev deps when the environment allows it
# (hermetic containers fall back to tests/_hypothesis_fallback.py) and run
# the full suite.
#
#   ./scripts/tier1.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" >/dev/null 2>&1; then
    pip install -r requirements-dev.txt >/dev/null 2>&1 \
        || echo "note: pip install unavailable; using vendored hypothesis fallback"
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"

"""Preempt -> migrate -> resume, end to end (paper §4, Table 5).

Shows that (a) the barrier quiesces all workers within two mini-batches,
(b) the checkpoint is consistent and deduped, (c) the job resumes at
EXACTLY the preempted step on different resources, bit-identically.

    PYTHONPATH=src python examples/elastic_migration.py
"""
from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.core.barrier import run_barrier_simulation
from repro.core.checkpoint import CheckpointStore
from repro.core.elastic import ElasticRuntime
from repro.core.migration import migrate


def main() -> None:
    cfg = get_smoke_config("mamba2-130m")
    tcfg = TrainConfig(total_steps=20, warmup_steps=1, learning_rate=1e-3)
    job = ElasticRuntime(cfg, tcfg, world_size=4, physical_devices=4,
                         global_batch=8, seq_len=32)
    print("== run 5 steps on cluster A (4 devices) ==")
    for rec in job.run_steps(5):
        print(f"  step {rec['step']} loss={rec['loss']:.4f}")

    print("== scheduler decides to preempt: acquire distributed barrier ==")
    bres = run_barrier_simulation(world_size=4, n_collectives=3,
                                  command_at_step=7, schedule_seed=1)
    print(f"  barrier acquired={bres.acquired} within "
          f"{bres.minibatches_to_acquire} mini-batches; "
          f"consistent cut={bres.consistent_cut}")

    print("== migrate to cluster B (2 devices, 2-way splicing) ==")
    store = CheckpointStore()
    job_b, report = migrate(job, store, "demo-job", 2, cfg, tcfg, 8, 32)
    print(f"  barrier {report.barrier_seconds:.2f}s | dump "
          f"{report.dump_seconds:.2f}s | transfer "
          f"{report.transfer_seconds():.3f}s | restore "
          f"{report.restore_seconds:.2f}s | total "
          f"{report.total_seconds:.2f}s")
    print(f"  work conserving: {report.work_conserving} "
          f"(resumed at step {int(job_b.state['step'])})")

    print("== continue on cluster B — trajectory is unchanged ==")
    for rec in job_b.run_steps(5):
        print(f"  step {rec['step']} loss={rec['loss']:.4f} "
              f"(physical={rec['physical']})")


if __name__ == "__main__":
    main()

"""The hierarchical scheduler driving REAL jobs (Figure 1, end to end).

A 4-slot fleet runs an actual basic-tier training job; a premium job
arrives and the scheduler preempts the basic job THROUGH the real
mechanisms — in-graph tandem-meta-allreduce quiesce, content-deduplicated
checkpoint — then restores it at the exact step once capacity frees up.

    PYTHONPATH=src python examples/real_fleet.py
"""
from repro.scheduler.executor import FleetExecutor, ManagedJob


def main() -> None:
    ex = FleetExecutor(total_slots=4)
    ex.submit(ManagedJob(id="research-run", tier="basic",
                         arch="olmo-1b", world_size=4, total_steps=10))
    print("== basic job admitted at full scale (4 slots) ==")
    ex.tick(); ex.tick()
    j = ex.jobs["research-run"]
    print(f"  steps={j.steps_done} allocated={j.allocated}")

    print("== premium job arrives: fleet preempts the basic job ==")
    ex.submit(ManagedJob(id="prod-training", tier="premium",
                         arch="mamba2-130m", world_size=4, total_steps=6))
    ex.tick()
    print(f"  basic: allocated={j.allocated} preemptions={j.preemptions} "
          f"(checkpointed at step {j.steps_done} via in-graph barrier)")
    print(f"  premium: allocated={ex.jobs['prod-training'].allocated}")

    print("== run to completion ==")
    log = ex.run(max_ticks=40)
    for e in log:
        print(f"  {e}")
    for job in ex.jobs.values():
        print(f"  {job.id}: done={job.done} steps={job.steps_done} "
              f"preempt={job.preemptions} resize={job.resizes}")


if __name__ == "__main__":
    main()

"""Batched serving: prefill + KV/SSM-cache decode across architectures.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.serving.engine import ServingEngine


def main() -> None:
    for arch in ("olmo-1b", "h2o-danube-3-4b", "mamba2-130m", "zamba2-1.2b"):
        cfg = get_smoke_config(arch)
        engine = ServingEngine(cfg, seed=0)
        prompts = jax.random.randint(jax.random.PRNGKey(4), (4, 24), 0,
                                     cfg.vocab_size, jnp.int32)
        t0 = time.time()
        out = engine.generate(prompts, max_new_tokens=12)
        dt = time.time() - t0
        print(f"{arch:18s} batch=4 prompt=24 decode=12 "
              f"wall={dt:5.2f}s first-row={out[0][:8].tolist()}")


if __name__ == "__main__":
    main()

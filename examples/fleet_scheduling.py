"""Planet-scale fleet simulation: Singularity policy vs static baseline.

A 2-region / 4-cluster / 2048-GPU fleet under a mixed-tier workload.
The elastic policy preempts, resizes and migrates (all work-conserving
because of the mechanisms in core/) and drives utilization up while
protecting premium-tier SLAs — and it pays for every mechanism
invocation: the cost model charges Table-5 downtime per preemption /
migration / resize, reported per tier below.

    PYTHONPATH=src python examples/fleet_scheduling.py
"""
from repro.scheduler.policy import ElasticPolicy, StaticGangPolicy
from repro.scheduler.simulator import (FleetSimulator, SimConfig, make_fleet,
                                       synth_workload)


def main() -> None:
    for seed in (3, 11):
        print(f"== workload seed {seed} (120 jobs, 2048 GPUs, 36h) ==")
        for policy in (StaticGangPolicy(), ElasticPolicy()):
            fleet = make_fleet()
            jobs = synth_workload(120, fleet.total(), seed=seed)
            sim = FleetSimulator(fleet, jobs, policy,
                                 SimConfig(horizon_seconds=36 * 3600))
            res = sim.run()
            print(f"  {policy.name:8s} {res.summary()}")
            print(f"           idle={res.gpu_seconds_idle/3.6e6:.1f} kGPUh "
                  f"dead={res.gpu_seconds_dead/3600:.1f} GPUh "
                  f"(mechanism downtime, charged)")
        print()


if __name__ == "__main__":
    main()

"""Quickstart: train a small model with Singularity's always-on mechanisms.

Runs a ~30-step training job through the public API: elastic runtime
(fixed logical world size), transparent checkpoint mid-run, and a
scale-down resize — everything the paper makes "default for all jobs".

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.core.checkpoint import CheckpointStore
from repro.core.elastic import ElasticRuntime
from repro.core.migration import checkpoint_job


def main() -> None:
    cfg = get_smoke_config("olmo-1b")           # reduced same-family config
    tcfg = TrainConfig(total_steps=30, warmup_steps=2, learning_rate=1e-3)

    # a job with logical world size 4, fully scaled up on 4 "devices"
    job = ElasticRuntime(cfg, tcfg, world_size=4, physical_devices=4,
                         global_batch=8, seq_len=32)

    print("== training at full scale ==")
    for rec in job.run_steps(10):
        print(f"  step {rec['step']:3d} loss={rec['loss']:.4f} "
              f"(physical={rec['physical']})")

    print("== transparent checkpoint (content-deduplicated) ==")
    store = CheckpointStore()
    stats = checkpoint_job(job, store, "quickstart")
    print(f"  {stats.n_workers} workers, logical "
          f"{stats.device_logical_bytes/1e6:.1f} MB -> stored "
          f"{stats.device_stored_bytes/1e6:.1f} MB (S_G dedup)")

    print("== capacity crunch: transparently scale down 4 -> 1 ==")
    job.resize(1)                                # 4-way time-slicing
    for rec in job.run_steps(10):
        print(f"  step {rec['step']:3d} loss={rec['loss']:.4f} "
              f"(physical={rec['physical']}, splice={rec['splice']})")

    print("== capacity back: scale up 1 -> 4, zero lost work ==")
    job.resize(4)
    for rec in job.run_steps(10):
        print(f"  step {rec['step']:3d} loss={rec['loss']:.4f} "
              f"(physical={rec['physical']})")
    print("done — the job never knew any of this happened.")


if __name__ == "__main__":
    main()

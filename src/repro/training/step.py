"""Spliced training step: the paper's replica splicing as a compiled program.

The logical world size W is constant; the scheduler maps W logical ranks
onto P physical devices (splice factor s = W/P).  Inside the jitted step:

- ``lax.scan`` over the s time-slices — each iteration is one resident
  logical-rank group's forward/backward (the context switch of §5.1);
- gradients are accumulated locally across slices in f32 scratch (the
  device-proxy's local accumulation: the cross-device collective sees ONE
  rank per device);
- the optimizer update runs once per device after the last slice —
  squashing (§5.2.3) expressed structurally: there is simply no per-slice
  update to omit.

The same lowering artifact gives elasticity AND activation-memory control
(slices bound live activations), which is what the dry-run exercises on the
production mesh.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import model_forward
from repro.optim.adamw import adamw_update, global_norm
from repro.optim.schedule import lr_schedule


def build_train_step(cfg: ModelConfig, tcfg: TrainConfig, splice: int = 1,
                     with_barrier: bool = False, mesh: Optional[Mesh] = None,
                     data_axes: Tuple[str, ...] = ("data",)) -> Callable:
    """Returns train_step(state, batch[, barrier_flags]) -> (state, metrics).

    batch leaves have leading dim = global_batch; they are split into
    ``splice`` time-slices internally.
    """

    def split(batch: Dict) -> Dict:
        def r(a):
            g = a.shape[0]
            assert g % splice == 0, (g, splice)
            return a.reshape((splice, g // splice) + a.shape[1:])
        return jax.tree_util.tree_map(r, batch)

    def loss_fn(params, mb):
        loss, metrics = model_forward(params, mb, cfg, remat=tcfg.remat,
                                      remat_policy=tcfg.remat_policy)
        return loss, metrics

    def train_step(state, batch, barrier_flags=None):
        params = state["params"]
        mbs = split(batch)

        grad_zero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def slice_body(carry, mb):
            gacc, lacc = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb)
            gacc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            return (gacc, lacc + loss), None

        if splice == 1:
            mb = jax.tree_util.tree_map(lambda a: a[0], mbs)
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb)
            grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32),
                                           grads)
            lsum = loss
        else:
            (grads, lsum), _ = jax.lax.scan(
                slice_body, (grad_zero, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / splice, grads)

        lr = lr_schedule(state["step"], tcfg)
        new_params, new_opt = adamw_update(params, grads, state["opt"], lr, tcfg)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {
            "loss": lsum / splice,
            "lr": lr,
            "grad_norm": global_norm(grads),
        }
        if with_barrier:
            # lazy import: core/__init__ imports elastic -> this module
            from repro.core.barrier_jax import meta_allreduce
            assert barrier_flags is not None
            metrics["barrier"] = meta_allreduce(barrier_flags, mesh, data_axes)
        return new_state, metrics

    return train_step

from repro.training.state import TrainState, init_train_state  # noqa: F401
from repro.training.step import build_train_step  # noqa: F401

"""Training state: the complete, checkpointable program state of a job.

In the paper, CRIU snapshots the host address space so the job resumes at
the exact program point.  In JAX the training program is functional: the
ENTIRE program state is this pytree plus the data cursor — capturing it at
a step boundary is exactly work-conserving (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import init_params
from repro.optim.adamw import adamw_init

TrainState = Dict[str, Any]


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig,
                     key: jax.Array) -> TrainState:
    params = init_params(cfg, key)
    return {
        "params": params,
        "opt": adamw_init(params),
        "step": jnp.zeros((), jnp.int32),
    }

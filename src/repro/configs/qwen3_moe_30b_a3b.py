"""qwen3-moe-30b-a3b [moe] — 128 experts, top-8.

48L d_model=2048 32H (GQA kv=4) d_ff=768(per-expert) vocab=151936, MoE 128e top-8.
[hf:Qwen/Qwen3-30B-A3B]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=768,                  # per-expert FFN width
    vocab_size=151936,
    rope_theta=1000000.0,
    norm="rmsnorm",
    mlp="swiglu",
    moe=MoEConfig(num_experts=128, top_k=8),
    source="hf:Qwen/Qwen3-30B-A3B",
)

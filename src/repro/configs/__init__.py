"""Architecture config registry.

``get_config(name)`` returns the full production config; ``get_smoke_config``
returns the reduced same-family variant used by CPU smoke tests.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (
    INPUT_SHAPES,
    EncDecConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    TrainConfig,
    VLMConfig,
    get_shape,
    reduced,
)

from repro.configs.h2o_danube_3_4b import CONFIG as _h2o
from repro.configs.zamba2_1_2b import CONFIG as _zamba2
from repro.configs.olmo_1b import CONFIG as _olmo
from repro.configs.whisper_base import CONFIG as _whisper
from repro.configs.yi_9b import CONFIG as _yi
from repro.configs.llama_3_2_vision_11b import CONFIG as _llama_vis
from repro.configs.granite_moe_3b_a800m import CONFIG as _granite_moe
from repro.configs.granite_8b import CONFIG as _granite
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3_moe
from repro.configs.mamba2_130m import CONFIG as _mamba2
from repro.configs.paper_gpt2_1_8b import CONFIG as _paper_gpt2

_REGISTRY: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _h2o, _zamba2, _olmo, _whisper, _yi, _llama_vis,
        _granite_moe, _granite, _qwen3_moe, _mamba2, _paper_gpt2,
    )
}

# the 10 assigned architectures (paper-native gpt2 excluded)
ASSIGNED_ARCHS: List[str] = [
    "h2o-danube-3-4b", "zamba2-1.2b", "olmo-1b", "whisper-base", "yi-9b",
    "llama-3.2-vision-11b", "granite-moe-3b-a800m", "granite-8b",
    "qwen3-moe-30b-a3b", "mamba2-130m",
]


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return _REGISTRY[name]


def get_smoke_config(name: str) -> ModelConfig:
    return reduced(get_config(name))


__all__ = [
    "ASSIGNED_ARCHS", "INPUT_SHAPES", "EncDecConfig", "ModelConfig",
    "MoEConfig", "ShapeConfig", "SSMConfig", "TrainConfig", "VLMConfig",
    "get_config", "get_shape", "get_smoke_config", "list_archs", "reduced",
]

"""whisper-base [audio] — encoder-decoder transformer backbone.

6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865.  [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor frontend is a STUB per the
assignment carve-out: input_specs() provides precomputed frame embeddings
of shape (batch, 1500, 512).
"""
from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    num_layers=6,              # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    norm="layernorm",
    mlp="gelu",
    encdec=EncDecConfig(encoder_layers=6, encoder_seq=1500),
    source="arXiv:2212.04356",
)

"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig`` (plus optional
MoE / SSM / enc-dec / VLM sub-configs).  Input shapes are ``ShapeConfig``.
All configs are plain frozen dataclasses so they hash, compare and print
cleanly and can be used as jit static arguments.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # capacity factor for expert-parallel dispatch (tokens per expert buffer).
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # shared (always-on) expert FFN width; 0 = none.
    shared_expert_ff: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block configuration (arXiv:2405.21060)."""
    state_dim: int = 128          # N, SSM state size
    head_dim: int = 64            # P, channels per SSD head
    expand: int = 2               # d_inner = expand * d_model
    chunk_size: int = 128         # SSD chunked-scan block length
    conv_width: int = 4           # depthwise causal conv width


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (whisper-style) backbone.  Frontend is a stub."""
    encoder_layers: int = 6
    encoder_seq: int = 1500       # whisper-base: 30s audio -> 1500 frames
    cross_attention: bool = True


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    """VLM cross-attention configuration (llama-3.2-vision style)."""
    cross_attn_every: int = 5     # a cross-attn layer every k layers
    num_image_tokens: int = 1601  # stubbed vision-encoder output tokens
    image_embed_dim: int = 1280   # stubbed vision embedding width (pre-projector)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    # attention
    sliding_window: int = 0       # 0 = full causal attention
    rope_theta: float = 10000.0
    # normalization: "rmsnorm" | "nonparametric_ln" (olmo) | "layernorm"
    norm: str = "rmsnorm"
    # mlp: "swiglu" | "gelu"
    mlp: str = "swiglu"
    tie_embeddings: bool = False
    # hybrid: attention block every k layers (zamba2-style shared block); 0 = n/a
    attn_every: int = 0
    shared_attn_block: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    max_seq_len: int = 1 << 20
    dtype: str = "bfloat16"
    source: str = ""              # citation

    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        assert self.num_heads > 0
        return self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                      # embed
        if not self.tie_embeddings:
            total += v * d                 # lm head
        hd = self.resolved_head_dim() if self.num_heads else 0

        def attn_params() -> int:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            return q + kv + o

        def mlp_params(ff: int) -> int:
            mult = 3 if self.mlp == "swiglu" else 2
            return mult * d * ff

        def ssm_params() -> int:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            in_proj = d * (2 * d_in + 2 * s.state_dim + nheads)
            conv = s.conv_width * (d_in + 2 * s.state_dim)
            out = d_in * d
            return in_proj + conv + out + 2 * nheads  # + A_log, D

        per_layer = 0
        if self.arch_type in ("dense", "audio", "vlm"):
            per_layer = attn_params() + mlp_params(self.d_ff)
        elif self.arch_type == "moe":
            m = self.moe
            expert = mlp_params(self.d_ff) * m.num_experts
            router = d * m.num_experts
            shared = mlp_params(m.shared_expert_ff) if m.shared_expert_ff else 0
            per_layer = attn_params() + expert + router + shared
        elif self.arch_type == "ssm":
            per_layer = ssm_params()
        elif self.arch_type == "hybrid":
            per_layer = ssm_params() + mlp_params(self.d_ff) // self.num_layers
        total += per_layer * self.num_layers
        if self.arch_type == "hybrid":
            # one shared attention block (zamba2-style)
            total += attn_params() + mlp_params(self.d_ff)
        if self.arch_type == "vlm":
            n_cross = self.num_layers // self.vlm.cross_attn_every
            total += n_cross * attn_params()
            total += self.vlm.image_embed_dim * d  # projector
        if self.arch_type == "audio":
            e = self.encdec
            total += e.encoder_layers * (attn_params() + mlp_params(self.d_ff))
            total += self.num_layers * attn_params()  # decoder cross-attn
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k of experts)."""
        if self.arch_type != "moe":
            return self.param_count()
        m = self.moe
        d = self.d_model
        mult = 3 if self.mlp == "swiglu" else 2
        expert_all = mult * d * self.d_ff * m.num_experts * self.num_layers
        expert_active = mult * d * self.d_ff * m.top_k * self.num_layers
        return self.param_count() - expert_all + expert_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in INPUT_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown input shape {name!r}; have {[s.name for s in INPUT_SHAPES]}")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    zero_shard_factor: int = 1    # ZeRO partial-sharding factor (paper §5.4)
    remat: bool = True
    remat_policy: str = "full"    # "full" | "dots" (save matmul outputs)
    seed: int = 0


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 256,
            n_heads: int = 4, max_experts: int = 4, vocab: int = 512,
            d_ff: int = 0) -> ModelConfig:
    """Build a reduced smoke-test variant of the same architecture family."""
    kv = max(1, min(cfg.num_kv_heads, n_heads) if cfg.num_kv_heads else 0)
    if cfg.num_kv_heads and cfg.num_heads:
        # preserve GQA ratio where possible
        ratio = max(1, cfg.num_heads // cfg.num_kv_heads)
        kv = max(1, n_heads // ratio)
    changes = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=n_heads if cfg.num_heads else 0,
        num_kv_heads=kv if cfg.num_kv_heads else 0,
        d_ff=d_ff or (d_model * 4 if cfg.d_ff else 0),
        vocab_size=vocab,
        head_dim=0,
        sliding_window=min(cfg.sliding_window, 128) if cfg.sliding_window else 0,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, max_experts),
            top_k=min(cfg.moe.top_k, 2),
            shared_expert_ff=min(cfg.moe.shared_expert_ff, d_model) if cfg.moe.shared_expert_ff else 0)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=min(cfg.ssm.state_dim, 16), head_dim=32,
            chunk_size=32)
    if cfg.encdec is not None:
        changes["encdec"] = dataclasses.replace(
            cfg.encdec, encoder_layers=2, encoder_seq=64)
    if cfg.vlm is not None:
        changes["vlm"] = dataclasses.replace(
            cfg.vlm, cross_attn_every=2, num_image_tokens=16, image_embed_dim=64)
    return dataclasses.replace(cfg, **changes)

"""yi-9b [dense] — llama-arch with aggressive GQA.

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.  [arXiv:2403.04652]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    arch_type="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    norm="rmsnorm",
    mlp="swiglu",
    source="arXiv:2403.04652",
)

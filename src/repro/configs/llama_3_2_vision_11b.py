"""llama-3.2-vision-11b [vlm] — cross-attention image layers.

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
[hf:meta-llama/Llama-3.2-11B-Vision]

The ViT vision encoder + projector is a STUB per the assignment carve-out:
input_specs() provides precomputed patch embeddings.  Cross-attention layers
are inserted every 5 decoder layers (8 total), matching the model card.
"""
from repro.configs.base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    arch_type="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    norm="rmsnorm",
    mlp="swiglu",
    vlm=VLMConfig(cross_attn_every=5, num_image_tokens=1601, image_embed_dim=1280),
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)

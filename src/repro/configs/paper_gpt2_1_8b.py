"""paper-gpt2-1.8b — the paper's own 3D-parallel evaluation model (Table 2).

Singularity evaluates GPT-2 1.8B via Megatron-LM 3D parallelism.  We include
it as the paper-native config so the paper's experiments (device-proxy
overhead, splicing, migration) run on the model family the paper used.
Config follows Megatron GPT-2 scaled to ~1.8B params.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-gpt2-1.8b",
    arch_type="dense",
    num_layers=40,
    d_model=1920,
    num_heads=24,
    num_kv_heads=24,
    d_ff=7680,
    vocab_size=50304,
    norm="layernorm",
    mlp="gelu",
    tie_embeddings=True,
    source="Singularity paper Table 2 / arXiv:1909.08053",
)

"""granite-moe-3b-a800m [moe] — 40 experts, top-8.

32L d_model=1536 24H (GQA kv=8) d_ff=512(per-expert) vocab=49155, MoE 40e top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base family]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,                  # per-expert FFN width
    vocab_size=49155,
    norm="rmsnorm",
    mlp="swiglu",
    moe=MoEConfig(num_experts=40, top_k=8),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

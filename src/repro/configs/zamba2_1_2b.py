"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.
[arXiv:2411.15242]

Adaptation note (DESIGN.md §4): the shared attention block (single set of
weights, applied periodically — every 6th layer here) is the zamba2
signature.  The shared block uses a sliding window so long_500k decode is
sub-quadratic.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    attn_every=6,
    shared_attn_block=True,
    sliding_window=4096,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk_size=128),
    norm="rmsnorm",
    mlp="swiglu",
    source="arXiv:2411.15242",
)

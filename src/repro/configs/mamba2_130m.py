"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

24L d_model=768 vocab=50280, ssm_state=128.  [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,               # attention-free
    num_kv_heads=0,
    d_ff=0,                    # no separate MLP; SSD block has internal expand
    vocab_size=50280,
    norm="rmsnorm",
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=128),
    source="arXiv:2405.21060",
)

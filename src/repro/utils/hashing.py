"""Host-side content checksums.

The paper's checksum-based dedup (§4.6, §5.2.1) fingerprints device buffers
by content.  On-device fingerprints use the Pallas kernel in
``repro.kernels.checksum``; this module provides the host-side reference
(used for checkpoint chunk addressing and in tests).
"""
from __future__ import annotations

import hashlib
from typing import Any

import numpy as np


def buffer_checksum(arr: Any) -> str:
    """Stable content checksum of an array (dtype+shape+bytes)."""
    a = np.asarray(arr)
    h = hashlib.blake2b(digest_size=16)
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def chunk_checksums(data: bytes, chunk_size: int = 1 << 20):
    """Content checksums of fixed-size chunks (CRIU page-dedup analogue)."""
    out = []
    for i in range(0, len(data), chunk_size):
        h = hashlib.blake2b(data[i:i + chunk_size], digest_size=16)
        out.append(h.hexdigest())
    return out

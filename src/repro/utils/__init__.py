"""Utility package.

``constants`` is eager (pure numbers, used by the jax-free scheduler path);
``hashing`` and ``pytree`` load lazily on first attribute access so that
importing the scheduler or the analytic serving model does not drag in jax.
"""

import importlib

from repro.utils import constants  # noqa: F401

_LAZY = ("hashing", "pytree")


def __getattr__(name):
    if name in _LAZY:
        mod = importlib.import_module(f"repro.utils.{name}")
        globals()[name] = mod
        return mod
    raise AttributeError(f"module 'repro.utils' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))

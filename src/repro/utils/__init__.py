from repro.utils import constants, hashing, pytree  # noqa: F401

"""Small pytree helpers used across the framework."""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Tuple

import jax
import numpy as np


def tree_bytes(tree: Any) -> int:
    """Total bytes of all array leaves."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(np.prod(l.shape, dtype=np.int64) * np.dtype(l.dtype).itemsize
               for l in leaves if hasattr(l, "shape"))


def tree_count(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape, dtype=np.int64) for l in leaves
                   if hasattr(l, "shape")))


def tree_flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    """Flatten a pytree to (dotted-path, leaf) pairs with stable ordering."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        out.append((jax.tree_util.keystr(path), leaf))
    return out


def tree_allclose(a: Any, b: Any, rtol: float = 1e-5, atol: float = 1e-6) -> bool:
    la, sa = jax.tree_util.tree_flatten(a)
    lb, sb = jax.tree_util.tree_flatten(b)
    if sa != sb:
        return False
    return all(np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)
               for x, y in zip(la, lb))


def tree_map_with_path(fn: Callable, tree: Any) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, l: fn(jax.tree_util.keystr(p), l), tree)


def tree_equal(a: Any, b: Any) -> bool:
    la, sa = jax.tree_util.tree_flatten(a)
    lb, sb = jax.tree_util.tree_flatten(b)
    if sa != sb:
        return False
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def named_leaves(tree: Any) -> Dict[str, Any]:
    return dict(tree_flatten_with_paths(tree))

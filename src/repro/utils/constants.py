"""Target-hardware constants (TPU v5e) used for roofline analysis.

The container runs on CPU; these constants describe the TARGET so the
dry-run roofline terms are physically meaningful.
"""

# per-chip peak
PEAK_BF16_FLOPS = 197e12        # 197 TFLOP/s bf16
HBM_BANDWIDTH = 819e9           # 819 GB/s
ICI_LINK_BANDWIDTH = 50e9       # ~50 GB/s per link

# production meshes
SINGLE_POD_SHAPE = (16, 16)                 # ("data", "model") — 256 chips
MULTI_POD_SHAPE = (2, 16, 16)               # ("pod", "data", "model") — 512 chips

# migration/transfer modelling (paper Table 5): remote blob store bandwidth
BLOB_STORE_BANDWIDTH = 2e9      # 2 GB/s effective to remote storage
HOST_DEVICE_BANDWIDTH = 32e9    # host<->device staging

VMEM_BYTES = 128 * 1024 * 1024  # v5e ~128 MiB VMEM (for BlockSpec sizing)
HBM_BYTES = 16 * 1024**3        # v5e 16 GiB HBM per chip

from repro.parallel.sharding import (  # noqa: F401
    batch_specs,
    decode_state_specs,
    param_specs,
    to_shardings,
)

"""In-graph sharding constraints for model internals.

XLA's sharding propagation loses the head/FFN partitioning through the
reshapes and scans inside blockwise attention, MoE dispatch and the SSD
blocks (observed: replicated attention-score buffers and spurious
score all-reduces on the 16x16 mesh).  These helpers pin the intended
layout at the tensor level.

``constrain`` is a no-op outside a mesh context (CPU smoke tests) and
silently drops axes that don't divide the dimension, so model code can
state intent unconditionally.
"""
from __future__ import annotations

from typing import Tuple, Union

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

BATCH = ("pod", "data")      # global-batch sharding axes
MODEL = "model"

# §Perf toggle (paired with param_specs profile="replicate_model"): drop
# "model" from activation constraints so small models run pure-DP.
DISABLE_MODEL_CONSTRAINTS = False


def current_mesh():
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m is None or m.empty else m


def constrain(x: jax.Array, *spec: Axis) -> jax.Array:
    """with_sharding_constraint under the ambient mesh, with divisibility
    and axis-existence guards.  spec entries: None | axis | tuple of axes."""
    mesh = current_mesh()
    if mesh is None or not hasattr(x, "shape") or x.ndim != len(spec):
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    clean = []
    for dim, s in zip(x.shape, spec):
        if s is None:
            clean.append(None)
            continue
        if DISABLE_MODEL_CONSTRAINTS and s == MODEL:
            clean.append(None)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        axes = tuple(a for a in axes if sizes.get(a, 1) > 1)
        total = int(np.prod([sizes[a] for a in axes])) if axes else 1
        if not axes or total <= 1 or dim % total != 0 or dim < total:
            # try dropping the leading axis (e.g. pod) for partial fit
            if len(axes) > 1:
                sub = axes[1:]
                t2 = int(np.prod([sizes[a] for a in sub]))
                if dim % t2 == 0 and dim >= t2:
                    clean.append(sub if len(sub) > 1 else sub[0])
                    continue
            clean.append(None)
            continue
        clean.append(axes if len(axes) > 1 else axes[0])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*clean)))

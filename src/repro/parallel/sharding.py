"""Sharding rules for the production meshes.

Mesh axes: ``("data", "model")`` single-pod (16 x 16) or
``("pod", "data", "model")`` multi-pod (2 x 16 x 16).

Strategy (DESIGN.md §5):
- Parameters & optimizer state: FSDP-style — "model" on the natural
  tensor-parallel dim (heads / FFN / experts / vocab) and "data" on the
  largest remaining divisible dim; replicated across "pod" (pods are pure
  DP; gradient all-reduce crosses the pod axis).
- Batch: sharded over ("pod", "data").
- Decode caches: batch dim over ("pod", "data") when divisible; heads/
  head_dim over "model" when divisible.
- Stacked per-layer leading axes (lax.scan over layers) are never sharded.

The rules are divisibility-driven rather than name-driven so every assigned
architecture (GQA with 4 kv heads, 40-expert MoE, SSD heads...) lowers
without special cases; names only mark stacked leading dims.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# markers for stacked per-layer leading axes (appear ANYWHERE in the path —
# optimizer state nests the param tree under ['m']/['v'])
STACKED_MARKERS = ("['blocks']", "['cross']")


def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _assign(shape: Tuple[int, ...], start: int, mesh: Mesh,
            prefer_last_for_model: bool = True) -> list:
    """Greedy: 'model' on the best divisible dim (preferring trailing dims,
    where the tensor-parallel reduction lives), then 'data' on the largest
    remaining divisible dim."""
    sizes = _axis_sizes(mesh)
    model = sizes.get("model", 1)
    data = sizes.get("data", 1)
    spec: list = [None] * len(shape)

    dims = list(range(start, len(shape)))
    if model > 1:
        order = sorted(dims, key=lambda i: (-int(shape[i] % model == 0), -i))
        for i in order:
            if shape[i] % model == 0 and shape[i] >= model:
                spec[i] = "model"
                break
    if data > 1:
        cands = [i for i in dims if spec[i] is None
                 and shape[i] % data == 0 and shape[i] >= data]
        if cands:
            i = max(cands, key=lambda i: shape[i])
            spec[i] = "data"
    return spec


def _named_param_spec(pstr: str, shape: Tuple[int, ...], start: int,
                      mesh: Mesh) -> Optional[list]:
    """Megatron-convention tensor-parallel placement by parameter name:
    column-parallel up-projections shard the output dim over "model",
    row-parallel down-projections shard the CONTRACTED dim over "model"
    (matching the activation sharding the model pins via constraints).
    Remaining capacity shards over "data" (FSDP).  Returns None when the
    name has no rule (generic fallback applies)."""
    sizes = _axis_sizes(mesh)
    model, data = sizes.get("model", 1), sizes.get("data", 1)
    dims = shape[start:]
    nd = len(dims)
    spec = [None] * nd

    def fits(i, n):
        return dims[i] % n == 0 and dims[i] >= n

    def put(i, axis, n):
        if spec[i] is None and n > 1 and fits(i, n):
            spec[i] = axis
            return True
        return False

    import re as _re
    keys = _re.findall(r"\['([^']+)'\]", pstr)
    name = keys[-1] if keys else ""
    in_attn = "'attn'" in pstr
    in_moe = "'moe'" in pstr or "'shared'" in pstr

    matched = True
    if in_attn and name in ("wq", "wk", "wv") and nd == 3:
        put(1, "model", model)          # heads
        put(0, "data", data)            # d_model
    elif in_attn and name == "wo" and nd == 3:
        put(0, "model", model)          # heads (contracted)
        put(2, "data", data)            # d_model
    elif in_moe and name in ("wi", "wg", "wo") and nd == 3:
        # (E, d, f) / (E, f, d): experts over model when divisible,
        # else the FFN dim; data on the remaining big dim
        if not put(0, "model", model):
            ffn_dim = 2 if name in ("wi", "wg") else 1
            put(ffn_dim, "model", model)
        other = 2 if spec[2] is None else 1
        put(other, "data", data)
    elif name in ("wi", "wg") and nd == 2:
        put(1, "model", model)          # d_ff (column-parallel)
        put(0, "data", data)
    elif name == "wo" and nd == 2:
        put(0, "model", model)          # d_ff (row-parallel, contracted)
        put(1, "data", data)
    elif name == "router" and nd == 2:
        put(0, "data", data)
    elif name == "in_proj" and nd == 2:
        put(1, "model", model)          # fused z/x/B/C/dt outputs
        put(0, "data", data)
    elif name == "out_proj" and nd == 2:
        put(0, "model", model)          # d_inner (contracted)
        put(1, "data", data)
    elif name == "conv_w" and nd == 2:
        put(1, "data", data)
    elif name == "embed" and nd == 2:
        put(0, "model", model)          # vocab
        put(1, "data", data)
    elif name == "head" and nd == 2:
        put(1, "model", model)          # vocab
        put(0, "data", data)
    elif name == "projector" and nd == 2:
        put(0, "data", data)
    else:
        matched = False
    if not matched:
        return None
    return [None] * start + spec


def param_specs(params: Any, mesh: Mesh, profile: str = "default") -> Any:
    """PartitionSpecs for a parameter/optimizer pytree (name-aware
    tensor-parallel rules + generic divisibility fallback).

    profile="replicate_model": no tensor parallelism — params replicated
    over "model", sharded over "data" only (FSDP).  The right layout for
    small models where per-chip TP work is dwarfed by the collectives it
    introduces (mamba2-130m, whisper-base serving).
    """
    def spec_for(path, leaf):
        pstr = jax.tree_util.keystr(path)
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return P()
        start = 1 if any(m in pstr for m in STACKED_MARKERS) \
            and leaf.ndim > 1 else 0
        if profile == "replicate_model":
            sizes = _axis_sizes(mesh)
            data = sizes.get("data", 1)
            spec = [None] * leaf.ndim
            cands = [i for i in range(start, leaf.ndim)
                     if leaf.shape[i] % data == 0 and leaf.shape[i] >= data]
            if cands and data > 1:
                spec[max(cands, key=lambda i: leaf.shape[i])] = "data"
            return P(*spec)
        named = _named_param_spec(pstr, leaf.shape, start, mesh)
        if named is not None:
            return P(*named)
        return P(*_assign(leaf.shape, start, mesh))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def batch_specs(batch: Any, mesh: Mesh) -> Any:
    """Batch leaves: leading (global-batch) dim over ("pod","data")."""
    sizes = _axis_sizes(mesh)
    daxes = tuple(a for a in ("pod", "data") if sizes.get(a, 1) > 1)
    dsize = int(np.prod([sizes[a] for a in daxes])) if daxes else 1

    def spec_for(leaf):
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return P()
        if daxes and leaf.shape[0] % dsize == 0 and leaf.shape[0] >= dsize:
            return P(daxes if len(daxes) > 1 else daxes[0])
        # batch not divisible by pod*data: try data alone
        if "data" in [a for a in daxes] and leaf.shape[0] % sizes["data"] == 0 \
                and leaf.shape[0] >= sizes["data"]:
            return P("data")
        return P()

    return jax.tree_util.tree_map(spec_for, batch)


def decode_state_specs(state: Any, mesh: Mesh, batch: int,
                       profile: str = "default") -> Any:
    """Decode-state leaves: (L, B, ...) caches -> B over ("pod","data"),
    heads/head_dim over "model".  profile="replicate_model": batch only."""
    sizes = _axis_sizes(mesh)
    daxes = tuple(a for a in ("pod", "data") if sizes.get(a, 1) > 1)
    dsize = int(np.prod([sizes[a] for a in daxes])) if daxes else 1
    model = sizes.get("model", 1)

    def spec_for(path, leaf):
        pstr = jax.tree_util.keystr(path)
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return P()
        shape = leaf.shape
        spec = [None] * leaf.ndim
        # find the batch dim (first dim == batch after the stacked L dim)
        bdim = None
        for i, d in enumerate(shape[:2]):
            if d == batch:
                bdim = i
                break
        if bdim is not None and daxes and batch % dsize == 0 and batch >= dsize:
            spec[bdim] = daxes if len(daxes) > 1 else daxes[0]
        if profile == "replicate_model":
            return P(*spec)
        if model > 1:
            if "kv" in pstr and leaf.ndim == 5 and bdim is not None:
                # KV caches (L, B, C, KVH, HD): shard the CACHE-LENGTH dim
                # over "model" — decode attends with a partial softmax over
                # cache segments (small score all-reduces) instead of
                # all-gathering the cache (few-KV-head GQA can't shard
                # heads 16-way).
                if shape[2] % model == 0 and shape[2] >= model:
                    spec[2] = "model"
                    return P(*spec)
            # fallback: first divisible trailing dim
            for i in range(len(shape) - 1, (bdim if bdim is not None else 0), -1):
                if spec[i] is None and shape[i] % model == 0 \
                        and shape[i] >= model:
                    spec[i] = "model"
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, state)


def to_shardings(specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))

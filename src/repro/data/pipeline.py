"""Deterministic, resumable, sharded synthetic token pipeline.

The pipeline is part of the job's *program state*: its cursor is captured in
the transparent checkpoint (DESIGN.md §2) so a resumed/migrated/resized job
continues on exactly the batch it would have seen — required for the
work-conserving property the paper claims.

Tokens are generated from a counter-mode PRNG keyed by (seed, step, logical
rank), so batch content is a pure function of the cursor — independent of
how many *physical* devices the job currently occupies.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int

    def to_dict(self) -> Dict:
        return {"seed": self.seed, "step": self.step}

    @staticmethod
    def from_dict(d: Dict) -> "PipelineState":
        return PipelineState(seed=int(d["seed"]), step=int(d["step"]))


class DataPipeline:
    """Yields (tokens, labels) for a fixed logical world size.

    ``global_batch`` rows per step, row r belongs to logical rank
    ``r * world_size // global_batch``.  ``batch_for_ranks`` returns the rows
    for any subset of logical ranks, which is what the elastic runtime uses
    when several logical ranks are spliced onto one physical device.
    """

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 world_size: int, seed: int = 0):
        assert global_batch % world_size == 0, (global_batch, world_size)
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.world_size = world_size
        self.per_rank = global_batch // world_size
        self.state = PipelineState(seed=seed, step=0)

    # -- deterministic content ------------------------------------------------
    def _rows(self, step: int, row_start: int, nrows: int) -> np.ndarray:
        """Counter-mode generation: each (step, row) is an independent stream."""
        out = np.empty((nrows, self.seq_len + 1), dtype=np.int32)
        for i in range(nrows):
            row = row_start + i
            rng = np.random.Generator(np.random.Philox(
                key=self.state.seed, counter=[0, 0, step, row]))
            out[i] = rng.integers(0, self.vocab_size, self.seq_len + 1,
                                  dtype=np.int32)
        return out

    def batch_for_ranks(self, ranks, step: int | None = None
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """(tokens, labels) for the given logical ranks at the given step."""
        step = self.state.step if step is None else step
        rows = []
        for r in ranks:
            start = r * self.per_rank
            rows.append(self._rows(step, start, self.per_rank))
        data = np.concatenate(rows, axis=0)
        return data[:, :-1], data[:, 1:]

    def next_batch(self) -> Tuple[np.ndarray, np.ndarray]:
        """Full global batch; advances the cursor."""
        tokens, labels = self.batch_for_ranks(range(self.world_size))
        self.state.step += 1
        return tokens, labels

    # -- checkpointable cursor ------------------------------------------------
    def snapshot(self) -> Dict:
        return self.state.to_dict()

    def restore(self, d: Dict) -> None:
        self.state = PipelineState.from_dict(d)

"""AdamW optimizer (pure JAX, pytree-based)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def adamw_init(params: Any) -> Dict:
    def zeros(p):
        return jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params: Any, grads: Any, opt_state: Dict, lr: jax.Array,
                 cfg: TrainConfig) -> Tuple[Any, Dict]:
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + eps)
        new_p = (p.astype(jnp.float32)
                 - lr * (step + cfg.weight_decay * p.astype(jnp.float32)))
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [x[0] for x in new])
    new_m = jax.tree_util.tree_unflatten(tdef, [x[1] for x in new])
    new_v = jax.tree_util.tree_unflatten(tdef, [x[2] for x in new])
    return new_p, {"m": new_m, "v": new_v, "count": count}

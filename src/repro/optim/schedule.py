"""Learning-rate schedules (warmup + cosine)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def lr_schedule(step, cfg: TrainConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)

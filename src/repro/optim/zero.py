"""ZeRO partial sharding (paper §5.4).

The paper decouples the ZeRO *sharding factor* (minimum needed to fit the
model) from the *data-parallelism degree* (for parallelism).  If DP = k ×
shard_factor, the job can be scaled down / time-sliced up to k-way: only
replicas of the SAME ZeRO shard are spliced together, so the splicing
invariants (identical P/O buffers across resident ranks) hold.

In JAX the optimizer state is sharded via PartitionSpec over the "data"
mesh axis with the partial factor expressed as a sub-axis split; here we
provide (a) the placement rule used by the elastic runtime and (b) the
partition-spec builder used by the launcher.
"""
from __future__ import annotations

from typing import Any, List

import jax
import numpy as np
from jax.sharding import PartitionSpec as P


def shard_group(rank: int, dp_degree: int, shard_factor: int) -> int:
    """Which ZeRO shard a DP rank holds.

    Ranks are assigned round-robin so that ranks {i, i+shard_factor, ...}
    hold the same shard — the groups that may be spliced together.
    """
    assert dp_degree % shard_factor == 0, (dp_degree, shard_factor)
    return rank % shard_factor


def spliceable_groups(dp_degree: int, shard_factor: int) -> List[List[int]]:
    """Groups of DP ranks holding identical optimizer shards (spliceable)."""
    return [[r for r in range(dp_degree) if shard_group(r, dp_degree, shard_factor) == g]
            for g in range(shard_factor)]


def max_splice_factor(dp_degree: int, shard_factor: int) -> int:
    """Paper: DP = k x shard_factor supports up to k-way time-slicing."""
    assert dp_degree % shard_factor == 0
    return dp_degree // shard_factor


def validate_partial_sharding(dp_degree: int, shard_factor: int,
                              target_splice: int) -> None:
    """Refuse a resize that would splice ranks of different ZeRO shards."""
    k = max_splice_factor(dp_degree, shard_factor)
    if target_splice > k:
        raise ValueError(
            f"cannot splice {target_splice}-way: ZeRO shard factor "
            f"{shard_factor} with DP={dp_degree} supports at most {k}-way "
            f"time-slicing (paper §5.4 partial sharding)")


def partial_shard_specs(params: Any, shard_factor: int,
                        data_axis: str = "data") -> Any:
    """PartitionSpecs sharding optimizer state over a sub-slice of the data
    axis.  shard_factor=1 -> fully replicated optimizer state (pure DP);
    shard_factor=dp -> fully sharded (classic ZeRO-1).

    We shard each tensor's largest divisible axis over the data axis.
    """
    def spec_for(leaf) -> P:
        if shard_factor == 1 or not hasattr(leaf, "shape") or leaf.ndim == 0:
            return P()
        shape = leaf.shape
        # largest axis divisible by shard factor
        cands = [(dim, ax) for ax, dim in enumerate(shape)
                 if dim % shard_factor == 0]
        if not cands:
            return P()
        _, ax = max(cands)
        spec = [None] * leaf.ndim
        spec[ax] = data_axis
        return P(*spec)

    return jax.tree_util.tree_map(spec_for, params)


def shard_slice(leaf: np.ndarray, spec: P, shard_idx: int, shard_factor: int):
    """Host-side slice of a leaf for a given ZeRO shard (checkpoint layout)."""
    for ax, name in enumerate(spec):
        if name is not None:
            n = leaf.shape[ax] // shard_factor
            sl = [slice(None)] * leaf.ndim
            sl[ax] = slice(shard_idx * n, (shard_idx + 1) * n)
            return leaf[tuple(sl)]
    return leaf

"""Conservative validation of squashing (§5.2.3).

Squashing alters the execution sequence using domain knowledge, so it must
be *provably* safe or disabled.  The paper's approach: run the first (and
every k-th) mini-batch with squashing DISABLED, infer the effect of the
squashing-window operations post-facto from buffer content checksums, and
enforce:

  1. all buffer mutations during the window are identical across resident
     ranks — same addresses, same sizes, same checksums;
  2. device-to-host copies during the window are identical across ranks.

If validation fails the model is marked unsafe and the engine permanently
falls back to swap-based switching: a potential correctness problem becomes
a measurable performance problem, never silent corruption.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.splicing import SplicedTrainer


@dataclasses.dataclass
class ValidationReport:
    ok: bool
    reason: Optional[str] = None
    n_ranks_checked: int = 0
    n_buffers_checked: int = 0


def validate_squashing_window(mutations: Dict[int, Dict[str, Tuple[int, str]]],
                              d2h_copies: Optional[Dict[int, list]] = None
                              ) -> ValidationReport:
    """Check invariants over per-rank mutation records from a validation
    mini-batch: {rank: {buffer_name: (addr, checksum_after)}}."""
    ranks = sorted(mutations)
    if not ranks:
        return ValidationReport(ok=True, n_ranks_checked=0)
    ref = mutations[ranks[0]]
    for r in ranks[1:]:
        mr = mutations[r]
        if set(mr) != set(ref):
            return ValidationReport(
                ok=False, reason=f"rank {r} mutated different buffer set "
                f"{sorted(mr)} vs {sorted(ref)}", n_ranks_checked=len(ranks))
        for name in ref:
            if mr[name] != ref[name]:
                return ValidationReport(
                    ok=False, reason=f"rank {r} buffer {name}: "
                    f"{mr[name]} != {ref[name]}", n_ranks_checked=len(ranks))
    if d2h_copies:
        ref_d2h = d2h_copies.get(ranks[0], [])
        for r in ranks[1:]:
            if d2h_copies.get(r, []) != ref_d2h:
                return ValidationReport(
                    ok=False, reason=f"rank {r} divergent D2H copies",
                    n_ranks_checked=len(ranks))
    return ValidationReport(ok=True, n_ranks_checked=len(ranks),
                            n_buffers_checked=len(ref))


def run_validated_training(trainer: SplicedTrainer, n_minibatches: int,
                           validate_every: int = 8) -> Dict:
    """Drive a spliced trainer with conservative validation: mini-batch 0
    (and every k-th) runs unsquashed + checked; a failure permanently
    disables squashing (fallback to swap mode)."""
    reports = []
    for mb in range(n_minibatches):
        is_validation = (mb % validate_every == 0) \
            and trainer.squash_disabled_reason is None
        out = trainer.run_minibatch(validate=is_validation)
        if is_validation:
            rep = validate_squashing_window(out["mutations"])
            reports.append(rep)
            if not rep.ok:
                trainer.squash_disabled_reason = rep.reason
    return {
        "reports": reports,
        "squash_disabled": trainer.squash_disabled_reason,
        "metrics": trainer.device.metrics,
    }

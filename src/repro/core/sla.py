"""GPU-fraction SLAs (§2.5, Table 1).

``gpu_fraction = T_ideal / T_real``: the relative slowdown a job experiences
from preemption/scale-down versus dedicated capacity.  Tiers:

  Premium  — 95% guarantee, almost never preempted, scale-up first.
  Standard — 70% guarantee, infrequent preemption.
  Basic    — best effort (spot-like), preempted first, scale-down first.

The SLA is enforced at an hourly granularity; the scheduler consults
``worst_window_fraction`` when choosing preemption/shrink victims.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Tuple

HOUR = 3600.0


@dataclasses.dataclass(frozen=True)
class SLATier:
    name: str
    gpu_fraction: float      # guaranteed T_ideal/T_real
    preempt_priority: int    # lower = preempted later
    scaleup_priority: int    # lower = offered spare capacity first


TIERS = {
    "premium": SLATier("premium", 0.95, preempt_priority=2, scaleup_priority=0),
    "standard": SLATier("standard", 0.70, preempt_priority=1, scaleup_priority=1),
    "basic": SLATier("basic", 0.0, preempt_priority=0, scaleup_priority=2),
}


class GpuFractionAccount:
    """Tracks a job's delivered vs. demanded GPU time over wall intervals.

    The account is on the scheduler's per-tick hot path (the policy consults
    ``headroom`` for every guaranteed job at every tick), so queries must not
    rescan history: contiguous equal-allocation records are coalesced,
    delivered time is answered from a prefix sum in O(log n), and the
    completed-window worst fraction is cached incrementally per window size.
    """

    def __init__(self, tier: str, demand_gpus: int):
        self.tier = TIERS[tier]
        self.demand = demand_gpus
        # (start, end, allocated_gpus); contiguous, append-only, coalesced
        self.intervals: List[Tuple[float, float, int]] = []
        self._starts: List[float] = []
        # _cum[i] = delivered seconds in all intervals before interval i
        self._cum: List[float] = []
        # window size -> (worst over completed windows, next window start)
        self._wcache: dict = {}

    def _weight(self, g: int) -> float:
        return min(g / self.demand, 1.0) if self.demand > 0 else 0.0

    def record(self, start: float, end: float, allocated: int) -> None:
        if end <= start:
            return
        if self.intervals:
            ls, le, lg = self.intervals[-1]
            if lg == allocated and start <= le + 1e-9:
                self.intervals[-1] = (ls, max(le, end), lg)
                return
        self.intervals.append((start, end, allocated))
        self._starts.append(start)
        if len(self.intervals) == 1:
            self._cum.append(0.0)
        else:
            ps, pe, pg = self.intervals[-2]
            self._cum.append(self._cum[-1] + (pe - ps) * self._weight(pg))

    # progress rate while holding g of n demanded GPUs is g/n (work-
    # conserving elasticity; splicing overhead is handled separately)
    def _delivered_before(self, t: float) -> float:
        i = bisect.bisect_right(self._starts, t) - 1
        if i < 0:
            return 0.0
        s, e, g = self.intervals[i]
        return self._cum[i] + max(0.0, min(t, e) - s) * self._weight(g)

    def delivered_seconds(self, t0: float, t1: float) -> float:
        if not self.intervals or t1 <= t0:
            return 0.0
        return max(0.0, self._delivered_before(t1) - self._delivered_before(t0))

    def fraction(self, t0: float, t1: float) -> float:
        if t1 <= t0:
            return 1.0
        return self.delivered_seconds(t0, t1) / (t1 - t0)

    def worst_window_fraction(self, now: float, window: float = HOUR) -> float:
        """Worst fraction over any completed window (hourly enforcement).

        A window is only cached once it is fully behind the recorded
        frontier — its fraction is then final (records are append-only in
        time).  Windows past the frontier are evaluated fresh each call, so
        a query issued before the interval was recorded never poisons the
        cache.
        """
        if not self.intervals:
            return 1.0
        start = self.intervals[0][0]
        frontier = self.intervals[-1][1]
        worst, t = self._wcache.get(window, (1.0, start))
        while t + window <= min(now, frontier) + 1e-9:
            worst = min(worst, self.fraction(t, t + window))
            t += window
        self._wcache[window] = (worst, t)
        # completed windows beyond the recorded frontier: not final yet
        while t + window <= now + 1e-9:
            worst = min(worst, self.fraction(t, t + window))
            t += window
        # also the trailing partial window
        if now > start:
            worst = min(worst, self.fraction(max(start, now - window), now))
        return worst

    def violated(self, now: float) -> bool:
        return self.worst_window_fraction(now) < self.tier.gpu_fraction - 1e-9

    def headroom(self, now: float, window: float = HOUR) -> float:
        """How much fraction above the guarantee this job currently has —
        the scheduler shrinks/preempts high-headroom jobs first."""
        return self.worst_window_fraction(now, window) - self.tier.gpu_fraction

"""GPU-fraction SLAs (§2.5, Table 1).

``gpu_fraction = T_ideal / T_real``: the relative slowdown a job experiences
from preemption/scale-down versus dedicated capacity.  Tiers:

  Premium  — 95% guarantee, almost never preempted, scale-up first.
  Standard — 70% guarantee, infrequent preemption.
  Basic    — best effort (spot-like), preempted first, scale-down first.

The SLA is enforced at an hourly granularity; the scheduler consults
``worst_window_fraction`` when choosing preemption/shrink victims.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Tuple

HOUR = 3600.0


@dataclasses.dataclass(frozen=True)
class SLATier:
    name: str
    gpu_fraction: float      # guaranteed T_ideal/T_real
    preempt_priority: int    # lower = preempted later
    scaleup_priority: int    # lower = offered spare capacity first


TIERS = {
    "premium": SLATier("premium", 0.95, preempt_priority=2, scaleup_priority=0),
    "standard": SLATier("standard", 0.70, preempt_priority=1, scaleup_priority=1),
    "basic": SLATier("basic", 0.0, preempt_priority=0, scaleup_priority=2),
}


class GpuFractionAccount:
    """Tracks a job's delivered vs. demanded GPU time over wall intervals."""

    def __init__(self, tier: str, demand_gpus: int):
        self.tier = TIERS[tier]
        self.demand = demand_gpus
        # (start, end, allocated_gpus); contiguous, append-only
        self.intervals: List[Tuple[float, float, int]] = []

    def record(self, start: float, end: float, allocated: int) -> None:
        if end <= start:
            return
        self.intervals.append((start, end, allocated))

    # progress rate while holding g of n demanded GPUs is g/n (work-
    # conserving elasticity; splicing overhead is handled separately)
    def delivered_seconds(self, t0: float, t1: float) -> float:
        tot = 0.0
        for s, e, g in self.intervals:
            lo, hi = max(s, t0), min(e, t1)
            if hi > lo:
                tot += (hi - lo) * min(g / self.demand, 1.0) \
                    if self.demand else 0.0
        return tot

    def fraction(self, t0: float, t1: float) -> float:
        if t1 <= t0:
            return 1.0
        return self.delivered_seconds(t0, t1) / (t1 - t0)

    def worst_window_fraction(self, now: float, window: float = HOUR) -> float:
        """Worst fraction over any completed window (hourly enforcement)."""
        if not self.intervals:
            return 1.0
        start = self.intervals[0][0]
        worst = 1.0
        t = start
        while t + window <= now + 1e-9:
            worst = min(worst, self.fraction(t, t + window))
            t += window
        # also the trailing partial window
        if now > start:
            worst = min(worst, self.fraction(max(start, now - window), now))
        return worst

    def violated(self, now: float) -> bool:
        return self.worst_window_fraction(now) < self.tier.gpu_fraction - 1e-9

    def headroom(self, now: float, window: float = HOUR) -> float:
        """How much fraction above the guarantee this job currently has —
        the scheduler shrinks/preempts high-headroom jobs first."""
        return self.worst_window_fraction(now, window) - self.tier.gpu_fraction

"""GPU-fraction SLAs (§2.5, Table 1).

``gpu_fraction = T_ideal / T_real``: the relative slowdown a job experiences
from preemption/scale-down versus dedicated capacity.  Tiers:

  Premium  — 95% guarantee, almost never preempted, scale-up first.
  Standard — 70% guarantee, infrequent preemption.
  Basic    — best effort (spot-like), preempted first, scale-down first.

The SLA is enforced at an hourly granularity; the scheduler consults
``worst_window_fraction`` when choosing preemption/shrink victims.

Two implementations share the same semantics:

- ``GpuFractionAccount`` — the scalar per-job account.  O(log n) queries,
  incremental per-window caching.  Kept as the reference oracle.
- ``FleetSLAAccounts`` + ``FleetSlotAccount`` — a struct-of-arrays ledger
  holding every active job's intervals in shared numpy arrays, answering
  ``headroom_all``/``worst_window_fraction_all`` for the whole fleet in a
  few batched passes.  This is what keeps the scheduler's decide path
  free of per-job Python loops at million-job scale; the property test in
  ``tests/test_sla_ledger.py`` pins it to the scalar oracle bit-for-bit.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Tuple, Union

import numpy as np

HOUR = 3600.0


@dataclasses.dataclass(frozen=True)
class SLATier:
    name: str
    gpu_fraction: float  # guaranteed T_ideal/T_real
    preempt_priority: int  # lower = preempted later
    scaleup_priority: int  # lower = offered spare capacity first


TIERS = {
    "premium": SLATier("premium", 0.95, preempt_priority=2, scaleup_priority=0),
    "standard": SLATier("standard", 0.70, preempt_priority=1, scaleup_priority=1),
    "basic": SLATier("basic", 0.0, preempt_priority=0, scaleup_priority=2),
}


class GpuFractionAccount:
    """Tracks a job's delivered vs. demanded GPU time over wall intervals.

    The account is on the scheduler's per-tick hot path (the policy consults
    ``headroom`` for every guaranteed job at every tick), so queries must not
    rescan history: contiguous equal-allocation records are coalesced,
    delivered time is answered from a prefix sum in O(log n), and the
    completed-window worst fraction is cached incrementally per window size.
    """

    def __init__(self, tier: str, demand_gpus: int):
        self.tier = TIERS[tier]
        self.demand = demand_gpus
        # (start, end, allocated_gpus); contiguous, append-only, coalesced
        self.intervals: List[Tuple[float, float, int]] = []
        self._starts: List[float] = []
        # _cum[i] = delivered seconds in all intervals before interval i
        self._cum: List[float] = []
        # window size -> (worst over completed windows, next window start)
        self._wcache: dict = {}

    def _weight(self, g: int) -> float:
        return min(g / self.demand, 1.0) if self.demand > 0 else 0.0

    def record(self, start: float, end: float, allocated: int) -> None:
        if end <= start:
            return
        if self.intervals:
            ls, le, lg = self.intervals[-1]
            if lg == allocated and start <= le + 1e-9:
                self.intervals[-1] = (ls, max(le, end), lg)
                return
        self.intervals.append((start, end, allocated))
        self._starts.append(start)
        if len(self.intervals) == 1:
            self._cum.append(0.0)
        else:
            ps, pe, pg = self.intervals[-2]
            self._cum.append(self._cum[-1] + (pe - ps) * self._weight(pg))

    # progress rate while holding g of n demanded GPUs is g/n (work-
    # conserving elasticity; splicing overhead is handled separately)
    def _delivered_before(self, t: float) -> float:
        i = bisect.bisect_right(self._starts, t) - 1
        if i < 0:
            return 0.0
        s, e, g = self.intervals[i]
        return self._cum[i] + max(0.0, min(t, e) - s) * self._weight(g)

    def delivered_seconds(self, t0: float, t1: float) -> float:
        if not self.intervals or t1 <= t0:
            return 0.0
        return max(0.0, self._delivered_before(t1) - self._delivered_before(t0))

    def fraction(self, t0: float, t1: float) -> float:
        if t1 <= t0:
            return 1.0
        return self.delivered_seconds(t0, t1) / (t1 - t0)

    def worst_window_fraction(self, now: float, window: float = HOUR) -> float:
        """Worst fraction over any completed window (hourly enforcement).

        A window is only cached once it is fully behind the recorded
        frontier — its fraction is then final (records are append-only in
        time).  Windows past the frontier are evaluated fresh each call, so
        a query issued before the interval was recorded never poisons the
        cache.
        """
        if not self.intervals:
            return 1.0
        start = self.intervals[0][0]
        frontier = self.intervals[-1][1]
        worst, t = self._wcache.get(window, (1.0, start))
        while t + window <= min(now, frontier) + 1e-9:
            worst = min(worst, self.fraction(t, t + window))
            t += window
        self._wcache[window] = (worst, t)
        # completed windows beyond the recorded frontier: not final yet
        while t + window <= now + 1e-9:
            worst = min(worst, self.fraction(t, t + window))
            t += window
        # also the trailing partial window
        if now > start:
            worst = min(worst, self.fraction(max(start, now - window), now))
        return worst

    def violated(self, now: float) -> bool:
        return self.worst_window_fraction(now) < self.tier.gpu_fraction - 1e-9

    def headroom(self, now: float, window: float = HOUR) -> float:
        """How much fraction above the guarantee this job currently has —
        the scheduler shrinks/preempts high-headroom jobs first."""
        return self.worst_window_fraction(now, window) - self.tier.gpu_fraction


_RELEASED = -2  # view slot marker: the slot was freed back to the ledger


class FleetSLAAccounts:
    """Struct-of-arrays SLA ledger for every active job in the fleet.

    Interval records for all slots live in shared 2-D numpy arrays
    (``start``/``end``/``alloc``/``wgt``/``cum``, one row per slot, grown
    by doubling), mirroring the scalar account exactly: contiguous
    equal-allocation records coalesce, ``cum`` is the delivered-seconds
    prefix sum appended at record time, and the per-window worst fraction
    is cached incrementally with the same unfinalized-frontier rule — a
    window is only cached once it is fully behind the slot's recorded
    frontier, so early queries never poison the cache.

    Queries are batched: ``worst_window_fraction_all``/``headroom_all``
    answer an arbitrary slot subset in a few array passes (a vectorized
    ``bisect_right`` into the interval rows plus one fraction evaluation
    per *window round*, not per job).  Arithmetic is performed in the same
    order as the scalar oracle, so results agree bit-for-bit; the property
    test in ``tests/test_sla_ledger.py`` enforces a 1e-9 bound.

    Slots are registered lazily (on a view's first real record), and
    ``release`` returns a completed job's row to a free list for reuse, so
    live memory tracks the number of *concurrently* active jobs rather
    than the length of the trace.

    **Compaction.**  A months-long churny job appends intervals forever;
    without intervention the shared interval axis doubles without bound.
    Once the axis reaches ``compact_after`` columns, a full slot first
    tries ``_compact_slot``: every interval finalized for all cached
    windows AND older than ``keep_horizon_seconds`` behind the slot's
    recorded frontier collapses into ONE summary interval whose weight
    reproduces the exact delivered-seconds prefix (the absolute ``cum``
    values of the kept suffix are untouched, so deliveries and window
    fractions that only touch the suffix are bit-identical; queries
    *inside* the compacted prefix see its average rate).  Only when
    compaction frees nothing does the axis actually grow — so the axis is
    bounded by churn within the keep horizon, not by job lifetime.
    ``compact_after=None`` disables.
    """

    def __init__(
        self,
        slot_capacity: int = 64,
        interval_capacity: int = 4,
        compact_after: int = 512,
        keep_horizon_seconds: float = 24 * HOUR,
    ):
        self._cap = max(1, int(slot_capacity))
        self._iv_cap = max(2, int(interval_capacity))
        self._compact_after = compact_after
        self._keep_horizon = float(keep_horizon_seconds)
        self._n = 0  # high-water slot mark
        self._free: List[int] = []
        self._demand = np.zeros(self._cap, np.int64)
        self._count = np.zeros(self._cap, np.int64)
        self._first = np.full(self._cap, np.nan)
        # unused cells keep +inf starts so the row binary search is safe
        self._start = np.full((self._cap, self._iv_cap), np.inf)
        self._end = np.zeros((self._cap, self._iv_cap))
        self._alloc = np.zeros((self._cap, self._iv_cap), np.int64)
        self._wgt = np.zeros((self._cap, self._iv_cap))
        self._cum = np.zeros((self._cap, self._iv_cap))
        # window size -> (worst over finalized windows, next window start);
        # a NaN start marks a slot with no cache entry for that window yet
        self._wcache: Dict[float, Tuple[np.ndarray, np.ndarray]] = {}

    # ------------------------------------------------------------- slots
    @property
    def slots_in_use(self) -> int:
        return self._n - len(self._free)

    def register(self, demand_gpus: int) -> int:
        """Claim a slot (reusing released rows first) for a job demanding
        ``demand_gpus`` at full speed."""
        if self._free:
            slot = self._free.pop()
        else:
            if self._n == self._cap:
                self._grow_slots()
            slot = self._n
            self._n += 1
        self._demand[slot] = int(demand_gpus)
        self._reset_slot(slot)
        return slot

    def release(self, slot: int) -> None:
        """Return a slot to the free list (the job completed; its account
        will never be queried again)."""
        self._reset_slot(slot)
        self._free.append(slot)

    def _reset_slot(self, slot: int) -> None:
        self._count[slot] = 0
        self._first[slot] = np.nan
        self._start[slot, :] = np.inf
        for worst, wstart in self._wcache.values():
            worst[slot] = 1.0
            wstart[slot] = np.nan

    @staticmethod
    def _grown(a: np.ndarray, shape, fill) -> np.ndarray:
        out = np.full(shape, fill, dtype=a.dtype)
        if a.ndim == 1:
            out[: a.size] = a
        else:
            out[: a.shape[0], : a.shape[1]] = a
        return out

    def _grow_slots(self) -> None:
        cap = self._cap * 2
        self._demand = self._grown(self._demand, cap, 0)
        self._count = self._grown(self._count, cap, 0)
        self._first = self._grown(self._first, cap, np.nan)
        self._start = self._grown(self._start, (cap, self._iv_cap), np.inf)
        self._end = self._grown(self._end, (cap, self._iv_cap), 0.0)
        self._alloc = self._grown(self._alloc, (cap, self._iv_cap), 0)
        self._wgt = self._grown(self._wgt, (cap, self._iv_cap), 0.0)
        self._cum = self._grown(self._cum, (cap, self._iv_cap), 0.0)
        for window, (worst, wstart) in list(self._wcache.items()):
            self._wcache[window] = (
                self._grown(worst, cap, 1.0),
                self._grown(wstart, cap, np.nan),
            )
        self._cap = cap

    def _grow_intervals(self) -> None:
        cols = self._iv_cap * 2
        self._start = self._grown(self._start, (self._cap, cols), np.inf)
        self._end = self._grown(self._end, (self._cap, cols), 0.0)
        self._alloc = self._grown(self._alloc, (self._cap, cols), 0)
        self._wgt = self._grown(self._wgt, (self._cap, cols), 0.0)
        self._cum = self._grown(self._cum, (self._cap, cols), 0.0)
        self._iv_cap = cols

    # -------------------------------------------------------- compaction
    def _compact_cutoff(self, slot: int) -> float:
        """Latest time before which this slot's intervals are summary-
        safe: behind every cached window's finalized frontier AND at
        least the keep horizon behind the recorded frontier (so trailing
        windows and moderately out-of-order queries stay exact)."""
        cnt = int(self._count[slot])
        cutoff = float(self._end[slot, cnt - 1]) - self._keep_horizon
        for _, wstart in self._wcache.values():
            ws = float(wstart[slot])
            if not np.isnan(ws):
                cutoff = min(cutoff, ws)
        return cutoff

    def _compact_slot(self, slot: int) -> int:
        """Collapse the slot's finalized interval prefix into one summary
        interval; returns the number of rows freed.  The summary weight
        reproduces the exact delivered-seconds total over the prefix, so
        every query outside it is unchanged (to float rounding); queries
        inside it see the prefix's average delivery rate.
        """
        cnt = int(self._count[slot])
        if cnt < 3:
            return 0
        cutoff = self._compact_cutoff(slot)
        # rows fully behind the cutoff (interval ends are strictly
        # increasing: records are append-only in time)
        k = int(np.searchsorted(self._end[slot, :cnt], cutoff, side="right"))
        if k < 2:
            return 0
        s0 = float(self._start[slot, 0])
        last_s = float(self._start[slot, k - 1])
        last_e = float(self._end[slot, k - 1])
        delivered = float(
            self._cum[slot, k - 1] + (last_e - last_s) * self._wgt[slot, k - 1]
        )
        span = last_e - s0
        m = cnt - k  # suffix rows kept verbatim (absolute cum preserved)
        self._start[slot, 1 : 1 + m] = self._start[slot, k:cnt]
        self._end[slot, 1 : 1 + m] = self._end[slot, k:cnt]
        self._alloc[slot, 1 : 1 + m] = self._alloc[slot, k:cnt]
        self._wgt[slot, 1 : 1 + m] = self._wgt[slot, k:cnt]
        self._cum[slot, 1 : 1 + m] = self._cum[slot, k:cnt]
        self._start[slot, 0] = s0
        self._end[slot, 0] = last_e
        self._alloc[slot, 0] = -1  # sentinel: a summary row never coalesces
        self._wgt[slot, 0] = delivered / span if span > 0 else 0.0
        self._cum[slot, 0] = 0.0
        self._start[slot, 1 + m : cnt] = np.inf
        self._end[slot, 1 + m : cnt] = 0.0
        self._alloc[slot, 1 + m : cnt] = 0
        self._wgt[slot, 1 + m : cnt] = 0.0
        self._cum[slot, 1 + m : cnt] = 0.0
        self._count[slot] = m + 1
        return k - 1

    def _maybe_compact(self, slot: int) -> bool:
        """Auto-compaction hook for a full slot on the record path: only
        once the axis has reached ``compact_after`` columns, and only if
        it actually frees rows (otherwise the caller grows the axis)."""
        if self._compact_after is None or self._iv_cap < self._compact_after:
            return False
        return self._compact_slot(slot) > 0

    def compact(self) -> int:
        """Compact every live slot now; returns total rows freed.  The
        auto path (``compact_after``) makes explicit calls unnecessary,
        but long-lived ledgers can invoke this at quiet moments."""
        freed = 0
        free = set(self._free)
        for slot in range(self._n):
            if slot not in free and self._count[slot] > 0:
                freed += self._compact_slot(slot)
        return freed

    # ----------------------------------------------------------- records
    def record_batch(
        self,
        slots: np.ndarray,
        start: np.ndarray,
        end: np.ndarray,
        allocated: np.ndarray,
    ) -> None:
        """Append one (start, end, allocated) record per slot, coalescing
        contiguous equal-allocation records exactly like the scalar
        account.  Records with ``end <= start`` are no-ops.  A slot must
        appear at most once per call (per-slot record order within a tick
        is preserved by issuing multiple calls, as the simulator does for
        the downtime/productive split).
        """
        slots = np.asarray(slots, np.int64)
        start = np.asarray(start, np.float64)
        end = np.asarray(end, np.float64)
        allocated = np.asarray(allocated, np.int64)
        assert np.unique(slots).size == slots.size, "duplicate slot in batch"
        live = end > start
        if not live.any():
            return
        if not live.all():
            slots = slots[live]
            start = start[live]
            end = end[live]
            allocated = allocated[live]
        # compact full slots before growing the shared axis (a summary
        # merge never touches a slot's LAST row, so the coalescing /
        # prefix-sum logic below is unaffected)
        if self._compact_after is not None and self._iv_cap >= self._compact_after:
            for s in slots[self._count[slots] >= self._iv_cap]:
                self._compact_slot(int(s))
        cnt = self._count[slots]
        last = np.maximum(cnt - 1, 0)
        has = cnt > 0
        lend = self._end[slots, last]
        lalloc = self._alloc[slots, last]
        coal = has & (lalloc == allocated) & (start <= lend + 1e-9)
        if coal.any():
            rows = slots[coal]
            self._end[rows, last[coal]] = np.maximum(lend[coal], end[coal])
        app = ~coal
        if not app.any():
            return
        rows = slots[app]
        k = cnt[app]
        while (k >= self._iv_cap).any():
            self._grow_intervals()
        grew = has[app]
        cum_k = np.zeros(rows.size)
        if grew.any():
            rp = rows[grew]
            kp = k[grew] - 1
            cum_k[grew] = (
                self._cum[rp, kp]
                + (self._end[rp, kp] - self._start[rp, kp]) * self._wgt[rp, kp]
            )
        self._cum[rows, k] = cum_k
        self._start[rows, k] = start[app]
        self._end[rows, k] = end[app]
        self._alloc[rows, k] = allocated[app]
        demand = self._demand[rows]
        self._wgt[rows, k] = np.where(
            demand > 0,
            np.minimum(allocated[app] / np.maximum(demand, 1), 1.0),
            0.0,
        )
        self._count[rows] = k + 1
        fresh = ~grew
        if fresh.any():
            self._first[rows[fresh]] = start[app][fresh]

    def record_one(self, slot: int, start: float, end: float, allocated: int) -> None:
        """Scalar append for one slot — identical semantics and identical
        float arithmetic to ``record_batch``, without the per-call array
        allocations (the legacy per-event simulator loop and the views'
        ``record`` are scalar callers on a hot path)."""
        if end <= start:
            return
        cnt = int(self._count[slot])
        if cnt > 0:
            last = cnt - 1
            last_end = float(self._end[slot, last])
            same = int(self._alloc[slot, last]) == int(allocated)
            if same and start <= last_end + 1e-9:
                if end > last_end:
                    self._end[slot, last] = end
                return
        if cnt >= self._iv_cap:
            if self._maybe_compact(slot):
                cnt = int(self._count[slot])
            else:
                self._grow_intervals()
        if cnt > 0:
            prev = cnt - 1
            self._cum[slot, cnt] = (
                self._cum[slot, prev]
                + (self._end[slot, prev] - self._start[slot, prev])
                * self._wgt[slot, prev]
            )
        else:
            self._cum[slot, cnt] = 0.0
            self._first[slot] = start
        self._start[slot, cnt] = start
        self._end[slot, cnt] = end
        self._alloc[slot, cnt] = allocated
        demand = int(self._demand[slot])
        self._wgt[slot, cnt] = min(allocated / demand, 1.0) if demand > 0 else 0.0
        self._count[slot] = cnt + 1

    # ----------------------------------------------------------- queries
    def _delivered_before(self, slots: np.ndarray, t) -> np.ndarray:
        """Vectorized ``bisect_right(starts, t) - 1`` + prefix-sum lookup,
        replicating the scalar account's probe sequence exactly."""
        lo = np.zeros(slots.size, np.int64)
        hi = self._count[slots].astype(np.int64)
        while True:
            open_ = lo < hi
            if not open_.any():
                break
            mid = (lo + hi) // 2
            probe = self._start[slots, np.minimum(mid, self._iv_cap - 1)]
            le = open_ & (probe <= t)
            lo = np.where(le, mid + 1, lo)
            hi = np.where(open_ & ~le, mid, hi)
        i = lo - 1
        i0 = np.maximum(i, 0)
        s = self._start[slots, i0]
        e = self._end[slots, i0]
        part = np.maximum(0.0, np.minimum(t, e) - s) * self._wgt[slots, i0]
        return np.where(i < 0, 0.0, self._cum[slots, i0] + part)

    def _fraction(self, slots: np.ndarray, t0, t1) -> np.ndarray:
        delivered = np.maximum(
            0.0, self._delivered_before(slots, t1) - self._delivered_before(slots, t0)
        )
        return delivered / (t1 - t0)

    def worst_window_fraction_all(
        self, now: float, slots: np.ndarray, window: float = HOUR
    ) -> np.ndarray:
        """Worst completed-window fraction for every slot in ``slots`` at
        ``now`` — the scalar ``worst_window_fraction`` batched.  Slots < 0
        (views not yet registered) and slots with no records answer 1.0,
        like an empty scalar account.  The per-window cache advances only
        over windows behind each slot's recorded frontier.
        """
        slots = np.asarray(slots, np.int64)
        out = np.ones(slots.size)
        act = (slots >= 0) & (self._count[np.maximum(slots, 0)] > 0)
        if not act.any():
            return out
        s = slots[act]
        cached = self._wcache.get(window)
        if cached is None:
            cached = (np.ones(self._cap), np.full(self._cap, np.nan))
            self._wcache[window] = cached
        worst_c, wstart_c = cached
        worst = worst_c[s].copy()
        t = wstart_c[s].copy()
        uninit = np.isnan(t)
        if uninit.any():
            t[uninit] = self._first[s][uninit]
        frontier = self._end[s, self._count[s] - 1]
        lim = np.minimum(now, frontier) + 1e-9
        while True:
            m = t + window <= lim
            if not m.any():
                break
            worst[m] = np.minimum(worst[m], self._fraction(s[m], t[m], t[m] + window))
            t[m] = t[m] + window
        worst_c[s] = worst
        wstart_c[s] = t
        # completed windows beyond the recorded frontier: not final yet,
        # evaluated fresh on local copies so they never enter the cache
        wfresh = worst.copy()
        tfresh = t.copy()
        while True:
            m = tfresh + window <= now + 1e-9
            if not m.any():
                break
            wfresh[m] = np.minimum(
                wfresh[m], self._fraction(s[m], tfresh[m], tfresh[m] + window)
            )
            tfresh[m] = tfresh[m] + window
        # also the trailing partial window
        first = self._first[s]
        m = now > first
        if m.any():
            lo = np.maximum(first[m], now - window)
            wfresh[m] = np.minimum(wfresh[m], self._fraction(s[m], lo, now))
        out[act] = wfresh
        return out

    def headroom_all(
        self,
        now: float,
        slots: np.ndarray,
        gfrac: np.ndarray,
        window: float = HOUR,
    ) -> np.ndarray:
        """Fraction above each slot's guarantee (``gfrac`` aligned with
        ``slots``) — the one batched call the policy's decide path makes."""
        worst = self.worst_window_fraction_all(now, slots, window)
        return worst - np.asarray(gfrac, np.float64)


class FleetSlotAccount:
    """Thin per-job view onto one ``FleetSLAAccounts`` slot.

    Drop-in for ``GpuFractionAccount`` on the ``Job.account`` attribute:
    same query API, same semantics, but the data lives in the fleet
    ledger's shared arrays so the policy can consult the whole fleet in
    one batched call.  The slot is registered lazily on the first real
    record and freed with ``release()`` when the job completes.
    """

    __slots__ = ("ledger", "slot", "tier", "demand")

    def __init__(self, ledger: FleetSLAAccounts, tier: str, demand_gpus: int):
        self.ledger = ledger
        self.tier = TIERS[tier]
        self.demand = demand_gpus
        self.slot = -1  # registered on first record

    def _check(self) -> None:
        if self.slot == _RELEASED:
            raise RuntimeError("SLA account was released back to the ledger")

    def ensure_slot(self) -> int:
        """Register with the ledger if not yet; returns the slot index."""
        self._check()
        if self.slot < 0:
            self.slot = self.ledger.register(self.demand)
        return self.slot

    def record(self, start: float, end: float, allocated: int) -> None:
        if end <= start:
            return
        slot = self.ensure_slot()
        self.ledger.record_one(slot, float(start), float(end), int(allocated))

    def worst_window_fraction(self, now: float, window: float = HOUR) -> float:
        self._check()
        slots = np.array([self.slot], np.int64)
        return float(self.ledger.worst_window_fraction_all(now, slots, window)[0])

    def headroom(self, now: float, window: float = HOUR) -> float:
        return self.worst_window_fraction(now, window) - self.tier.gpu_fraction

    def violated(self, now: float) -> bool:
        return self.worst_window_fraction(now) < self.tier.gpu_fraction - 1e-9

    def delivered_seconds(self, t0: float, t1: float) -> float:
        self._check()
        if self.slot < 0 or t1 <= t0 or self.ledger._count[self.slot] == 0:
            return 0.0
        slots = np.array([self.slot], np.int64)
        after = self.ledger._delivered_before(slots, float(t1))
        before = self.ledger._delivered_before(slots, float(t0))
        return max(0.0, float(after[0]) - float(before[0]))

    def fraction(self, t0: float, t1: float) -> float:
        if t1 <= t0:
            return 1.0
        return self.delivered_seconds(t0, t1) / (t1 - t0)

    def release(self) -> None:
        """Free the slot; the account must not be queried afterwards."""
        if self.slot >= 0:
            self.ledger.release(self.slot)
        self.slot = _RELEASED


# what Job.account may hold: the scalar oracle or a ledger-backed view
SLAAccount = Union[GpuFractionAccount, FleetSlotAccount]

"""Transparent distributed barrier via tandem meta-allreduces (§4.3.1).

The protocol, verbatim from the paper:

- Before every data allreduce the worker issues an *asynchronous* tandem
  meta-allreduce: a SUM allreduce over two integers
  ``(need_barrier, ack_barrier)``.  Tandem issue trivially preserves program
  order, the requirement for collective libraries.
- *Phase 1* (steady state): metas are async, payload (0, 0); negligible cost.
- A worker that has received a barrier command contributes ``need=1``.
- A worker that observes a completed meta with ``SUM(need) > 0`` switches to
  *Phase 2*: it contributes ``ack=1`` and goes *synchronous* (every
  collective call blocks until completion) to guarantee timely termination.
- A worker that observes ``SUM(ack) == world_size`` knows every rank is in
  Phase 2 and acquires the barrier after its in-flight pair drains.

Guarantees (property-tested): the barrier is acquired by all ranks with no
in-flight collectives and identical per-communicator issue counts (a
consistent cut), within at most two mini-batches of the command.

For model-parallel jobs (tensor/pipeline groups, p2p send/recv) the paper
uses domain knowledge instead of reasoning about cross-group ordering: the
tandem meta is issued ONCE per mini-batch, at the end, where no collective
is in flight in any dimension (``mode="minibatch_end"``).

The engine below is a deterministic cooperative-interleaving simulator:
``hypothesis`` drives adversarial schedules in the tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Collective engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Instance:
    """One collective call instance on a communicator (identified by seq)."""
    payloads: Dict[int, Tuple[int, ...]] = dataclasses.field(default_factory=dict)

    def complete(self, world: int) -> bool:
        return len(self.payloads) == world

    def total(self) -> Tuple[int, ...]:
        vals = list(self.payloads.values())
        return tuple(int(sum(v[i] for v in vals)) for i in range(len(vals[0])))


class CollectiveEngine:
    """Tracks per-communicator call streams; a call completes when every
    participating rank has issued its matching (same-seq) call."""

    def __init__(self, world_size: int):
        self.world = world_size
        self.comms: Dict[str, Dict] = {}

    def register(self, comm: str, ranks: Optional[List[int]] = None) -> None:
        ranks = list(range(self.world)) if ranks is None else ranks
        self.comms[comm] = {"ranks": ranks, "seq": {r: 0 for r in ranks},
                            "instances": {}}

    def issue(self, comm: str, rank: int, payload: Tuple[int, ...] = (0,)) -> int:
        c = self.comms[comm]
        seq = c["seq"][rank]
        c["seq"][rank] = seq + 1
        inst = c["instances"].setdefault(seq, _Instance())
        inst.payloads[rank] = payload
        return seq

    def is_complete(self, comm: str, seq: int) -> bool:
        c = self.comms[comm]
        inst = c["instances"].get(seq)
        return inst is not None and inst.complete(len(c["ranks"]))

    def result(self, comm: str, seq: int) -> Tuple[int, ...]:
        assert self.is_complete(comm, seq)
        return self.comms[comm]["instances"][seq].total()

    def in_flight(self, comm: str) -> int:
        c = self.comms[comm]
        world = len(c["ranks"])
        return sum(0 if i.complete(world) else 1
                   for i in c["instances"].values())

    def issue_counts(self, comm: str) -> List[int]:
        return list(self.comms[comm]["seq"].values())


# ---------------------------------------------------------------------------
# Worker state machine
# ---------------------------------------------------------------------------

PHASE1, PHASE2, ACQUIRED = 0, 1, 2


class BarrierWorker:
    """A training worker: each mini-batch issues ``n_collectives`` data
    allreduces (each preceded by its tandem meta) and ends with a sync point.

    ``mode="per_allreduce"`` — data-parallel jobs (meta before every data AR)
    ``mode="minibatch_end"`` — model-parallel jobs (single meta at MB end);
    intra-minibatch collectives then run on group communicators.
    """

    def __init__(self, rank: int, engine: CollectiveEngine, n_collectives: int,
                 mode: str = "per_allreduce",
                 group_comms: Optional[List[str]] = None):
        self.rank = rank
        self.engine = engine
        self.n_collectives = n_collectives
        self.mode = mode
        self.group_comms = group_comms or []
        self.phase = PHASE1
        self.barrier_requested = False
        self.minibatch = 0
        self.op_idx = 0                 # op position within the minibatch
        self.outstanding: List[Tuple[str, int]] = []
        self.pending_meta: List[int] = []   # meta seqs not yet examined
        self.acquired_at_mb: Optional[int] = None
        self.blocked_on: Optional[Tuple[str, int]] = None
        self.saw_all_acked = False

    # -- external command ----------------------------------------------------
    def request_barrier(self) -> None:
        self.barrier_requested = True

    # -- helpers --------------------------------------------------------------
    def _meta_payload(self) -> Tuple[int, int]:
        need = 1 if self.barrier_requested else 0
        ack = 1 if self.phase == PHASE2 else 0
        return (need, ack)

    def _drain_meta_results(self) -> None:
        remaining = []
        for seq in self.pending_meta:
            if self.engine.is_complete("meta", seq):
                need, ack = self.engine.result("meta", seq)
                if need > 0 and self.phase == PHASE1:
                    self.phase = PHASE2
                if ack == self.engine.world:
                    self.saw_all_acked = True
            else:
                remaining.append(seq)
        self.pending_meta = remaining

    def _drain_outstanding(self) -> bool:
        self.outstanding = [(c, s) for (c, s) in self.outstanding
                            if not self.engine.is_complete(c, s)]
        return not self.outstanding

    @property
    def done(self) -> bool:
        return self.phase == ACQUIRED

    # -- one scheduling quantum ------------------------------------------------
    def step(self) -> bool:
        """Advance by at most one action.  Returns True if progress was made."""
        if self.done:
            return False
        self._drain_meta_results()

        # synchronous mode / sync point blocking
        if self.blocked_on is not None:
            if self.engine.is_complete(*self.blocked_on):
                self.blocked_on = None
            else:
                return False

        # acquire check: phase 2, everyone acked, nothing in flight for us
        if self.phase == PHASE2 and self.saw_all_acked:
            self._drain_meta_results()
            if self._drain_outstanding() and not self.pending_meta:
                self.phase = ACQUIRED
                self.acquired_at_mb = self.minibatch
                return True
            # wait for drains
            if self.outstanding:
                self.blocked_on = self.outstanding[0]
            elif self.pending_meta:
                self.blocked_on = ("meta", self.pending_meta[0])
            return True

        n_ops = self.n_collectives
        sync_mode = self.phase == PHASE2

        if self.op_idx < n_ops:
            i = self.op_idx
            if self.mode == "per_allreduce":
                mseq = self.engine.issue("meta", self.rank, self._meta_payload())
                self.pending_meta.append(mseq)
                dseq = self.engine.issue("data", self.rank, (0,))
                self.outstanding.append(("data", dseq))
                if sync_mode:
                    self.blocked_on = ("data", dseq)
            else:  # minibatch_end: intra-MB collectives on group comms
                comm = self.group_comms[i % len(self.group_comms)] \
                    if self.group_comms else "data"
                dseq = self.engine.issue(comm, self.rank, (0,))
                self.outstanding.append((comm, dseq))
                if sync_mode:
                    self.blocked_on = (comm, dseq)
            self.op_idx += 1
            return True

        # end of mini-batch: sync point (cudaStreamWaitEvent analogue)
        if not self._drain_outstanding():
            self.blocked_on = self.outstanding[0]
            return True
        if self.mode == "minibatch_end":
            mseq = self.engine.issue("meta", self.rank, self._meta_payload())
            self.pending_meta.append(mseq)
            if sync_mode or self.barrier_requested:
                self.blocked_on = ("meta", mseq)
        self.minibatch += 1
        self.op_idx = 0
        return True


# ---------------------------------------------------------------------------
# Simulation driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BarrierResult:
    acquired: bool
    minibatches_to_acquire: int     # max over workers since command delivery
    steps: int
    consistent_cut: bool
    issue_counts: Dict[str, List[int]]


def run_barrier_simulation(world_size: int, n_collectives: int,
                           command_at_step: int, schedule_seed: int,
                           mode: str = "per_allreduce",
                           n_groups: int = 2,
                           max_steps: int = 200_000) -> BarrierResult:
    """Run workers under a seeded adversarial interleaving until all acquire."""
    engine = CollectiveEngine(world_size)
    engine.register("meta")
    engine.register("data")
    group_comms = []
    if mode == "minibatch_end":
        for g in range(n_groups):
            name = f"group{g}"
            engine.register(name)
            group_comms.append(name)
    workers = [BarrierWorker(r, engine, n_collectives, mode, group_comms)
               for r in range(world_size)]

    rng = np.random.Generator(np.random.Philox(schedule_seed))
    steps = 0
    command_sent = False
    mb_at_command = [0] * world_size
    while not all(w.done for w in workers) and steps < max_steps:
        if steps >= command_at_step and not command_sent:
            for w in workers:
                w.request_barrier()
                mb_at_command[w.rank] = w.minibatch
            command_sent = True
        order = rng.permutation(world_size)
        progressed = False
        for idx in order:
            if workers[idx].step():
                progressed = True
                break  # one action per quantum -> fine-grained interleaving
        steps += 1
        if not progressed and command_sent is False:
            break

    acquired = all(w.done for w in workers)
    counts = {c: engine.issue_counts(c) for c in engine.comms}
    consistent = acquired
    for comm in engine.comms:
        cs = engine.issue_counts(comm)
        if len(set(cs)) != 1 or engine.in_flight(comm) != 0:
            consistent = False
    mbs = max((w.acquired_at_mb or 0) - mb_at_command[w.rank] for w in workers) \
        if acquired else -1
    return BarrierResult(acquired=acquired, minibatches_to_acquire=mbs,
                         steps=steps, consistent_cut=consistent,
                         issue_counts=counts)

"""Replica splicing: semantics-aware time-slicing of DP ranks on one device (§5).

This is the buffer-level executable model of the paper's mechanism.  One
physical device hosts several logical ranks of the same data-parallel
group.  Each rank has its OWN device address space (its view through the
device proxy, bookkept by a per-rank bidirectional allocator from
``core/buffers.py``); the address spaces overlay one physical memory, and
only the resident rank's content is live.  Context switches happen at the
gradient sync point; the engine implements:

- §5.1 semantics-aware time-slicing: one rank executes at a time; gradients
  are accumulated locally in a proxy scratch buffer and a single cross-
  device allreduce is issued by the last resident rank ("NCCL sees one rank
  per GPU").
- §5.2.1 checksum-based dynamic dedup: conditional swap-out (skip if host
  already holds the content) and conditional swap-in (skip if the device
  already holds it at that address; D2D move if elsewhere).
- §5.2.2 consistent allocations: per-rank bidirectional allocators give
  stable buffers (P, O) identical addresses across ranks whenever their
  stable allocation sequences match — even when variable-sized transient
  allocations diverge.  With identical addresses, a squashed rank simply
  *sees* the root rank's update in physical memory.
- §5.2.3 squashing: parameter/optimizer-update ops execute only on the root
  rank and are omitted on the others — protected by conservative validation
  (``core/validation.py``).

The JAX hot path plays this role inside the compiled step
(``core/elastic.py``); this model is what the checkpoint/migration layers
and the paper-reproduction benchmarks (Fig 4 structure) run against.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.buffers import DeviceMemory
from repro.utils.hashing import buffer_checksum


@dataclasses.dataclass
class SpliceMetrics:
    swapout_bytes: int = 0
    swapin_bytes: int = 0
    elided_swapouts: int = 0
    elided_swapins: int = 0
    d2d_moves: int = 0
    squashed_ops: int = 0
    executed_update_ops: int = 0
    context_switches: int = 0
    allreduces_issued: int = 0

    def add(self, other: "SpliceMetrics") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))


class HostStore:
    """Host memory as a content-addressed cache (checksum -> bytes)."""

    def __init__(self):
        self.by_checksum: Dict[str, np.ndarray] = {}

    def has(self, cs: str) -> bool:
        return cs in self.by_checksum

    def put(self, data: np.ndarray) -> str:
        cs = buffer_checksum(data)
        if cs not in self.by_checksum:
            self.by_checksum[cs] = np.array(data, copy=True)
        return cs

    def get(self, cs: str) -> np.ndarray:
        return self.by_checksum[cs]


@dataclasses.dataclass
class RankView:
    """One logical rank's device view: its allocator + name->addr map and the
    expected (host-side) content checksums of its non-resident buffers."""
    rank: int
    mem: DeviceMemory
    buffers: Dict[str, Tuple[int, bool]] = dataclasses.field(default_factory=dict)
    expected: Dict[str, str] = dataclasses.field(default_factory=dict)


class SplicedDevice:
    """One physical device time-slicing several logical DP ranks."""

    def __init__(self, capacity: int, ranks: List[int], device_id: int = 0):
        self.capacity = capacity
        self.device_id = device_id
        self.views = {r: RankView(r, DeviceMemory(capacity)) for r in ranks}
        # physical device content: addr -> ndarray (the resident overlay)
        self.physical: Dict[int, np.ndarray] = {}
        self.host = HostStore()
        self.active_rank: Optional[int] = ranks[0]
        self.metrics = SpliceMetrics()

    # ------------------------------------------------------------------ alloc
    def alloc(self, rank: int, name: str, nbytes: int, stable: bool) -> int:
        view = self.views[rank]
        buf = view.mem.alloc(nbytes, stable)
        view.buffers[name] = (buf.addr, stable)
        return buf.addr

    def free(self, rank: int, name: str) -> None:
        view = self.views[rank]
        addr, _ = view.buffers.pop(name)
        view.mem.free(addr)
        if rank == self.active_rank:
            self.physical.pop(addr, None)

    def addr_of(self, rank: int, name: str) -> int:
        return self.views[rank].buffers[name][0]

    # ---------------------------------------------------------------- content
    def write(self, rank: int, name: str, data: np.ndarray) -> None:
        assert rank == self.active_rank, "only the resident rank executes"
        self.physical[self.addr_of(rank, name)] = np.array(data, copy=True)

    def read(self, rank: int, name: str) -> np.ndarray:
        """Read a buffer: from physical memory if resident content matches the
        rank's view, else from the host store."""
        view = self.views[rank]
        addr, _ = view.buffers[name]
        if rank == self.active_rank and addr in self.physical:
            return self.physical[addr]
        want = view.expected.get(name)
        if want is not None and addr in self.physical \
                and buffer_checksum(self.physical[addr]) == want:
            return self.physical[addr]
        if want is not None:
            return self.host.get(want)
        return self.physical[addr]

    # ---------------------------------------------------------------- switch
    def context_switch(self, to_rank: int) -> None:
        """Conditional swap-out of the resident rank, conditional swap-in of
        ``to_rank`` (§5.2.1)."""
        from_rank = self.active_rank
        if from_rank == to_rank:
            return
        self.metrics.context_switches += 1
        fv = self.views[from_rank]
        for name, (addr, stable) in list(fv.buffers.items()):
            if addr not in self.physical:
                continue
            data = self.physical[addr]
            cs = buffer_checksum(data)
            fv.expected[name] = cs
            if self.host.has(cs):
                self.metrics.elided_swapouts += 1
            else:
                self.host.put(data)
                self.metrics.swapout_bytes += data.nbytes
            # buffer marked unused; lazily GC'd — content stays resident so
            # the incoming rank can elide its swap-in (paper §5.2.1)

        tv = self.views[to_rank]
        for name, (addr, stable) in tv.buffers.items():
            want = tv.expected.get(name)
            if want is None:
                continue
            cur = self.physical.get(addr)
            if cur is not None and buffer_checksum(cur) == want:
                self.metrics.elided_swapins += 1           # same content, same addr
                continue
            moved = False
            for a2, d2 in self.physical.items():
                if a2 != addr and buffer_checksum(d2) == want:
                    self.physical[addr] = np.array(d2, copy=True)
                    self.metrics.d2d_moves += 1
                    self.metrics.elided_swapins += 1       # avoided host swap-in
                    moved = True
                    break
            if not moved:
                data = self.host.get(want)
                self.physical[addr] = np.array(data, copy=True)
                self.metrics.swapin_bytes += data.nbytes
        self.active_rank = to_rank


class SplicedTrainer:
    """A DP training job spliced onto one device — the end-to-end choreography.

    The workload is a real (numpy) model: params P, momentum O, per-rank
    gradients from rank-specific data shards.  Each mini-batch:

      for each resident rank (time-slice):
          variable-sized transient allocs (exercise §5.2.2)
          compute grads on the rank's shard; accumulate into proxy scratch
          sync point -> context switch
      last rank: allreduce(accumulated) [engine-level], optimizer update
                 (squashed on all but the root rank)
    """

    def __init__(self, n_ranks: int, dim: int = 64, capacity: int = 1 << 22,
                 seed: int = 0, squash: bool = True,
                 update_fn: Optional[Callable] = None):
        self.n = n_ranks
        self.dim = dim
        self.squash = squash
        self.squash_disabled_reason: Optional[str] = None
        self.device = SplicedDevice(capacity, list(range(n_ranks)))
        self.rng = np.random.Generator(np.random.Philox(seed))
        self.lr = 0.05
        self.momentum = 0.9
        self.update_fn = update_fn or self._sgd_momentum_update
        self.minibatch_idx = 0

        p0 = self.rng.standard_normal(dim).astype(np.float32)
        o0 = np.zeros(dim, np.float32)
        self.target = self.rng.standard_normal(dim).astype(np.float32)
        cs_p, cs_o = buffer_checksum(p0), buffer_checksum(o0)
        self.device.host.put(p0)
        self.device.host.put(o0)
        for r in range(n_ranks):
            self.device.alloc(r, "P", p0.nbytes, stable=True)
            self.device.alloc(r, "O", o0.nbytes, stable=True)
            self.device.views[r].expected["P"] = cs_p
            self.device.views[r].expected["O"] = cs_o
        # make rank 0 resident with initial content
        self.device.physical[self.device.addr_of(0, "P")] = p0.copy()
        self.device.physical[self.device.addr_of(0, "O")] = o0.copy()
        self.scratch = np.zeros(dim, np.float32)     # proxy-owned accumulator

    # -- workload pieces ------------------------------------------------------
    def _grad(self, rank: int) -> np.ndarray:
        g = np.random.Generator(np.random.Philox(
            key=7, counter=[0, 0, self.minibatch_idx, rank]))
        x = g.standard_normal(self.dim).astype(np.float32)
        p = self.device.read(rank, "P")
        return (p - self.target) * 0.5 + 0.01 * x

    def _sgd_momentum_update(self, p, o, g, rank):
        o = self.momentum * o + g
        return p - self.lr * o, o

    # -- one mini-batch ---------------------------------------------------------
    def run_minibatch(self, validate: bool = False) -> Dict:
        dev = self.device
        squash = self.squash and not validate \
            and self.squash_disabled_reason is None
        self.scratch[:] = 0
        mutation_records: Dict[int, Dict[str, Tuple[int, str]]] = {}

        for r in range(self.n):
            dev.context_switch(r)
            act_elems = 64 * (1 + int(self.rng.integers(0, 4)) + r % 3)
            dev.alloc(r, "act", act_elems * 4, stable=False)
            dev.write(r, "act", np.zeros(act_elems, np.float32))
            g = self._grad(r)
            self.scratch += g                        # proxy-local accumulation
            dev.free(r, "act")

        dev.metrics.allreduces_issued += 1           # one real allreduce/device
        g_avg = self.scratch / self.n

        root = self.n - 1                            # currently resident
        update_ranks = [root] if squash else list(range(self.n))
        for r in update_ranks:
            dev.context_switch(r)
            before = {name: buffer_checksum(dev.read(r, name))
                      for name in ("P", "O")}
            p, o = dev.read(r, "P"), dev.read(r, "O")
            new_p, new_o = self.update_fn(p, o, g_avg, r)
            dev.write(r, "P", new_p)
            dev.write(r, "O", new_o)
            dev.metrics.executed_update_ops += 1
            after = {name: (dev.addr_of(r, name),
                            buffer_checksum(dev.read(r, name)))
                     for name in ("P", "O")}
            mutation_records[r] = {
                name: after[name] for name in after if after[name][1] != before[name]}
        if squash:
            dev.metrics.squashed_ops += self.n - 1
            # squashed ranks see the root's update through shared addresses:
            # their expected content IS the root's new content (§5.2.3 (a),(b))
            for r in range(self.n):
                for name in ("P", "O"):
                    dev.views[r].expected[name] = buffer_checksum(
                        dev.read(root, name))
        else:
            for r in range(self.n):
                for name in ("P", "O"):
                    dev.views[r].expected[name] = buffer_checksum(
                        dev.read(r, name))

        self.minibatch_idx += 1
        return {"mutations": mutation_records,
                "grad_norm": float(np.linalg.norm(g_avg))}

    # -- views ------------------------------------------------------------------
    def params(self, rank: int) -> np.ndarray:
        return np.asarray(self.device.read(rank, "P"))

    def stable_addresses(self, rank: int) -> Dict[str, int]:
        return {n: a for n, (a, st) in self.device.views[rank].buffers.items()
                if st}

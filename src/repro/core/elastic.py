"""Elastic runtime: transparent resize of a live job (§5).

To the job, the world size W never changes.  The runtime maps W logical
ranks onto P physical devices; resizing swaps the splice factor s = W/P in
the compiled step — the training state is untouched (work-conserving), the
data pipeline cursor is untouched, and the trajectory is invariant (tested).

ZeRO partial sharding (§5.4): a job whose optimizer state is sharded
``zero_shard_factor``-way can only be spliced up to W / shard_factor — the
runtime enforces the paper's placement rule (only replicas of the same
shard are spliced together).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.barrier_jax import BarrierDriver
from repro.data.pipeline import DataPipeline
from repro.models.frontend import synth_extra_inputs
from repro.optim.zero import validate_partial_sharding
from repro.training.state import TrainState, init_train_state
from repro.training.step import build_train_step


class ElasticRuntime:
    """Host-side elastic training driver (CPU-scale; the production path
    lowers the same spliced step onto the pod mesh via launch/)."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, world_size: int,
                 physical_devices: int, global_batch: int, seq_len: int,
                 seed: int = 0, state: Optional[TrainState] = None,
                 pipeline_state: Optional[Dict] = None):
        assert world_size % physical_devices == 0
        self.cfg = cfg
        self.tcfg = tcfg
        self.world_size = world_size
        self.physical = physical_devices
        validate_partial_sharding(world_size, tcfg.zero_shard_factor,
                                  world_size // physical_devices)
        self.pipeline = DataPipeline(cfg.vocab_size, seq_len, global_batch,
                                     world_size, seed=tcfg.seed)
        if pipeline_state:
            self.pipeline.restore(pipeline_state)
        key = jax.random.PRNGKey(tcfg.seed)
        self.state = state if state is not None else init_train_state(
            cfg, tcfg, key)
        self.barrier = BarrierDriver(n_shards=1)
        self._extra_key = jax.random.PRNGKey(tcfg.seed + 1)
        self._steps: Dict[int, any] = {}
        self.history: List[Dict] = []
        self.compile_seconds = 0.0

    # ------------------------------------------------------------------ step
    @property
    def splice(self) -> int:
        return self.world_size // self.physical

    def _step_fn(self):
        s = self.splice
        if s not in self._steps:
            t0 = time.time()
            fn = jax.jit(build_train_step(self.cfg, self.tcfg, splice=s,
                                          with_barrier=True))
            self._steps[s] = fn
            self.compile_seconds += time.time() - t0
        return self._steps[s]

    # ----------------------------------------------------- preemption flow
    def request_preemption(self) -> None:
        """Scheduler command: quiesce at the next safe boundary (§4).  The
        (need, ack) payload rides the job's own compiled step — the
        in-graph tandem meta-allreduce."""
        self.barrier.request()

    @property
    def quiesced(self) -> bool:
        return self.barrier.acquired

    def _batch(self) -> Dict:
        tokens, labels = self.pipeline.next_batch()
        batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        batch.update(synth_extra_inputs(self.cfg, tokens.shape[0],
                                        self._extra_key))
        return batch

    def run_steps(self, n: int, stop_on_barrier: bool = False) -> List[Dict]:
        out = []
        fn = self._step_fn()
        for _ in range(n):
            batch = self._batch()
            self.state, metrics = fn(self.state, batch, self.barrier.flags())
            acquired = self.barrier.observe(metrics["barrier"])
            rec = {"step": int(self.state["step"]),
                   "loss": float(metrics["loss"]),
                   "splice": self.splice,
                   "physical": self.physical,
                   "barrier_acquired": acquired}
            out.append(rec)
            self.history.append(rec)
            if acquired and stop_on_barrier:
                break
        return out

    # ---------------------------------------------------------------- resize
    def resize(self, new_physical: int) -> Dict:
        """Transparent resize: same logical world, new physical mapping.

        Work-conserving by construction: state and data cursor unchanged.
        """
        assert self.world_size % new_physical == 0, \
            f"world {self.world_size} not divisible by {new_physical}"
        validate_partial_sharding(self.world_size, self.tcfg.zero_shard_factor,
                                  self.world_size // new_physical)
        old = self.physical
        t0 = time.time()
        self.physical = new_physical
        self._step_fn()     # build/compile the new splice's step
        return {"from": old, "to": new_physical,
                "splice": self.splice,
                "resize_seconds": time.time() - t0,
                "at_step": int(self.state["step"])}

    # ------------------------------------------------------------- snapshots
    def snapshot(self) -> Dict:
        """The complete program state (work-conserving checkpoint payload)."""
        return {
            "state": jax.tree_util.tree_map(np.asarray, self.state),
            "pipeline": self.pipeline.snapshot(),
            "world_size": self.world_size,
        }

    @classmethod
    def from_snapshot(cls, cfg: ModelConfig, tcfg: TrainConfig, snap: Dict,
                      physical_devices: int, global_batch: int, seq_len: int
                      ) -> "ElasticRuntime":
        state = jax.tree_util.tree_map(jnp.asarray, snap["state"])
        return cls(cfg, tcfg, snap["world_size"], physical_devices,
                   global_batch, seq_len, state=state,
                   pipeline_state=snap["pipeline"])

"""In-graph tandem meta-allreduce (§4.3.1) for the JAX training step.

The barrier protocol state — two integers (need_barrier, ack_barrier) —
travels with the job's own collective stream: a tiny ``psum`` over the data
axis fused into the compiled train step.  No out-of-band channel is
introduced (the paper's production constraint), and the steady-state cost
is two integers per step (benchmarked in Table-3 reproduction).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def meta_allreduce(flags: jax.Array, mesh: Optional[Mesh],
                   data_axes: Tuple[str, ...] = ("data",)) -> jax.Array:
    """SUM-allreduce the 2-int (need, ack) payload across data shards.

    flags: (n_data_shards, 2) int32, sharded over the data axis.
    Returns the summed (2,) payload, replicated.
    """
    if mesh is None:
        return jnp.sum(flags, axis=0)
    axes = tuple(a for a in data_axes if a in mesh.axis_names)

    def inner(f):
        s = jnp.sum(f, axis=0)
        return jax.lax.psum(s, axes)

    return jax.shard_map(
        inner, mesh=mesh,
        in_specs=P(axes if len(axes) > 1 else axes[0]),
        out_specs=P())(flags)


class BarrierDriver:
    """Host-side driver of the in-graph protocol.

    Phase 1: each step carries (need, ack) = (0, 0) — free.
    On a preemption command, the next step carries need=1; once the summed
    payload shows need>0 every shard acks; when sum(ack) == n_shards the
    job is quiesced at the step boundary (the natural mini-batch barrier the
    paper uses for model-parallel jobs) and can be checkpointed.
    """

    def __init__(self, n_shards: int):
        self.n = n_shards
        self.need = False
        self.acked = False
        self.acquired = False

    def request(self) -> None:
        self.need = True

    def flags(self) -> jnp.ndarray:
        f = jnp.zeros((self.n, 2), jnp.int32)
        if self.need:
            f = f.at[:, 0].set(1)
        if self.acked:
            f = f.at[:, 1].set(1)
        return f

    def observe(self, summed) -> bool:
        """Feed the summed payload from the step output; returns True when
        the barrier is acquired (safe to checkpoint)."""
        need, ack = int(summed[0]), int(summed[1])
        if need > 0:
            self.acked = True
        if ack >= self.n:
            self.acquired = True
        return self.acquired

    def reset(self) -> None:
        """Release after the checkpoint is taken (resume normal running)."""
        self.need = self.acked = self.acquired = False

"""Singularity core mechanisms: the paper's contribution.

- ``barrier``       — tandem meta-allreduce distributed barrier (§4.3.1)
- ``barrier_jax``   — the same 2-int protocol fused into the jitted step
- ``buffers``       — bidirectional allocator / device memory model (§5.2.2)
- ``device_proxy``  — interception, handle virtualization, log/replay (§3, §4.2)
- ``splicing``      — replica splicing engine (§5.1-§5.2)
- ``validation``    — conservative squash validation (§5.2.3)
- ``checkpoint``    — content-deduped consistent checkpoints (§4, §4.6)
- ``elastic``       — transparent elastic runtime over the spliced step (§5)
- ``migration``     — preempt -> dump -> transfer -> restore flow (§4.5)
- ``sla``           — GPU-fraction SLA tiers and accounting (§2.5):
                      scalar per-job accounts + the vectorized
                      fleet-wide struct-of-arrays ledger
"""
import importlib

from repro.core.barrier import (  # noqa: F401
    BarrierResult,
    BarrierWorker,
    CollectiveEngine,
    run_barrier_simulation,
)
from repro.core.buffers import Buffer, DeviceMemory, OutOfMemory  # noqa: F401
from repro.core.device_proxy import (  # noqa: F401
    DeviceProxyClient,
    DeviceProxyServer,
)
from repro.core.sla import (  # noqa: F401
    TIERS,
    FleetSLAAccounts,
    FleetSlotAccount,
    GpuFractionAccount,
    SLATier,
)
from repro.core.splicing import SplicedDevice, SplicedTrainer, SpliceMetrics  # noqa: F401
from repro.core.validation import (  # noqa: F401
    ValidationReport,
    run_validated_training,
    validate_squashing_window,
)

# barrier_jax / checkpoint / elastic / migration import jax at module
# scope; resolve their names lazily (PEP 562) so the analytic
# scheduler/serving path — which only needs ``sla`` — imports without it.
_LAZY = {
    "BarrierDriver": "barrier_jax",
    "meta_allreduce": "barrier_jax",
    "CheckpointStore": "checkpoint",
    "SnapshotStats": "checkpoint",
    "ElasticRuntime": "elastic",
    "MigrationReport": "migration",
    "checkpoint_job": "migration",
    "migrate": "migration",
}


def __getattr__(name):
    if name in _LAZY:
        mod = importlib.import_module(f"repro.core.{_LAZY[name]}")
        val = getattr(mod, name)
        globals()[name] = val
        return val
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))

"""Transparent, consistent, content-deduplicated checkpointing (§4, §4.6).

The checkpoint of an N-worker job is ``S_G + N * S_pwCr`` (paper §7.2):

- ``S_G``  — device state.  Per-buffer content checksums dedup identical
  buffers ACROSS workers: data-parallel replicas share identical parameter
  and optimizer tensors, so the stored device bytes are independent of the
  DP degree (Table 4's key property).
- ``S_Cr`` — per-worker host program state (CRIU analogue).  In this JAX
  framework the host state is the structured loop state (step counter, data
  cursor, RNG, schedule state); chunk-level content addressing gives the
  paper's page-dedup across workers, and TEMPORAL dedup makes incremental
  snapshots an order of magnitude smaller than the first one.

Chunks are content-addressed (blake2b-128); a snapshot is a manifest of
chunk references.  The store can live in memory or on disk.
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
import pickle
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.utils.hashing import chunk_checksums

CHUNK = 1 << 20     # 1 MiB content chunks (page-dedup granularity)


def _leaf_bytes(leaf) -> bytes:
    arr = np.asarray(leaf)
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _leaf_from_bytes(b: bytes):
    return np.load(io.BytesIO(b), allow_pickle=False)


@dataclasses.dataclass
class SnapshotStats:
    step: int
    device_logical_bytes: int      # sum over all workers (no dedup)
    device_stored_bytes: int       # unique bytes actually stored (S_G)
    host_logical_bytes: int        # sum of per-worker host dumps
    host_stored_bytes: int         # unique new chunks stored this snapshot
    n_workers: int
    wall_seconds: float


class CheckpointStore:
    """Content-addressed chunk store + snapshot manifests."""

    def __init__(self, root: Optional[str] = None):
        self.root = root
        self.chunks: Dict[str, bytes] = {}
        self.manifests: Dict[str, List[Dict]] = {}     # job -> snapshots
        if root:
            os.makedirs(os.path.join(root, "chunks"), exist_ok=True)

    # ---------------------------------------------------------------- chunks
    def _put_chunk(self, data: bytes) -> Tuple[str, bool]:
        cs = chunk_checksums(data, len(data) or 1)[0] if len(data) <= CHUNK \
            else None
        if cs is None:
            raise ValueError("chunk too large")
        new = cs not in self.chunks
        if new:
            self.chunks[cs] = data
            if self.root:
                with open(os.path.join(self.root, "chunks", cs), "wb") as f:
                    f.write(data)
        return cs, new

    def _get_chunk(self, cs: str) -> bytes:
        if cs in self.chunks:
            return self.chunks[cs]
        if self.root:
            with open(os.path.join(self.root, "chunks", cs), "rb") as f:
                data = f.read()
            self.chunks[cs] = data
            return data
        raise KeyError(cs)

    def _put_blob(self, data: bytes) -> Tuple[List[str], int]:
        """Store a blob as content chunks; returns (chunk refs, new bytes)."""
        refs, new_bytes = [], 0
        for i in range(0, max(len(data), 1), CHUNK):
            piece = data[i:i + CHUNK]
            cs, new = self._put_chunk(piece)
            refs.append(cs)
            if new:
                new_bytes += len(piece)
        return refs, new_bytes

    def _get_blob(self, refs: List[str]) -> bytes:
        return b"".join(self._get_chunk(c) for c in refs)

    # -------------------------------------------------------------- snapshot
    def snapshot(self, job_id: str, step: int,
                 device_state_by_worker: Dict[int, Any],
                 host_state_by_worker: Dict[int, Dict],
                 files_by_worker: Optional[Dict[int, Dict[str, bytes]]] = None
                 ) -> SnapshotStats:
        """Take a consistent checkpoint.

        device_state_by_worker: worker -> pytree of arrays (P, O, ...).
        host_state_by_worker:   worker -> picklable host program state.
        files_by_worker:        worker -> {path: content} mutated local files
                                (tracked by the libc SA_Int, §4.4); deduped
                                by content checksum across workers.
        """
        t0 = time.time()
        manifest: Dict = {"job": job_id, "step": step, "workers": {}}
        dev_logical = dev_stored = host_logical = host_stored = 0

        for w, tree in device_state_by_worker.items():
            leaves, treedef = jax.tree_util.tree_flatten(tree)
            entries = []
            for leaf in leaves:
                data = _leaf_bytes(leaf)
                dev_logical += len(data)
                refs, new = self._put_blob(data)
                dev_stored += new
                entries.append(refs)
            manifest["workers"].setdefault(str(w), {})["device"] = entries
            manifest["workers"][str(w)]["treedef"] = pickle.dumps(treedef).hex()

        for w, host in host_state_by_worker.items():
            data = pickle.dumps(host)
            host_logical += len(data)
            refs, new = self._put_blob(data)
            host_stored += new
            manifest["workers"].setdefault(str(w), {})["host"] = refs

        if files_by_worker:
            for w, files in files_by_worker.items():
                fl = {}
                for path, content in files.items():
                    refs, new = self._put_blob(content)
                    host_stored += new
                    fl[path] = refs
                manifest["workers"].setdefault(str(w), {})["files"] = fl

        self.manifests.setdefault(job_id, []).append(manifest)
        if self.root:
            path = os.path.join(self.root, f"{job_id}.manifests.json")
            with open(path, "w") as f:
                json.dump(self.manifests[job_id], f, default=str)
        return SnapshotStats(
            step=step, device_logical_bytes=dev_logical,
            device_stored_bytes=dev_stored, host_logical_bytes=host_logical,
            host_stored_bytes=host_stored,
            n_workers=len(device_state_by_worker),
            wall_seconds=time.time() - t0)

    # --------------------------------------------------------------- restore
    def restore(self, job_id: str, step: Optional[int] = None
                ) -> Tuple[Dict[int, Any], Dict[int, Dict], int]:
        """Returns (device_state_by_worker, host_state_by_worker, step)."""
        snaps = self.manifests[job_id]
        manifest = snaps[-1] if step is None else \
            next(m for m in snaps if m["step"] == step)
        device, host = {}, {}
        for w, entry in manifest["workers"].items():
            treedef = pickle.loads(bytes.fromhex(entry["treedef"]))
            leaves = [_leaf_from_bytes(self._get_blob(refs))
                      for refs in entry["device"]]
            device[int(w)] = jax.tree_util.tree_unflatten(treedef, leaves)
            host[int(w)] = pickle.loads(self._get_blob(entry["host"]))
        return device, host, manifest["step"]

    # ----------------------------------------------------------------- sizes
    def stored_bytes(self) -> int:
        return sum(len(v) for v in self.chunks.values())

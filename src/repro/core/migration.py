"""Transparent migration & resizing flow (§4.5, Table 5).

End-to-end: acquire barrier -> dump (device + host state, deduped) ->
upload -> download -> restore -> fresh rendezvous -> resume.  On this
CPU container the serialize/deserialize times are measured for real; the
blob-store transfer is modelled as bytes / bandwidth (constants in
``utils/constants.py``), mirroring how the paper reports Transfer as the
dominant component.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.barrier import run_barrier_simulation
from repro.core.checkpoint import CheckpointStore, SnapshotStats
from repro.core.elastic import ElasticRuntime
from repro.utils import constants


@dataclasses.dataclass
class MigrationReport:
    job_id: str
    from_physical: int
    to_physical: int
    barrier_seconds: float
    barrier_minibatches: int
    dump_seconds: float
    upload_seconds: float
    download_seconds: float
    restore_seconds: float
    total_seconds: float
    device_stored_bytes: int
    host_stored_bytes: int
    work_conserving: bool       # resumed at exactly the preempted step
    src_region: Optional[str] = None    # region pair the transfer crossed
    dst_region: Optional[str] = None

    def transfer_seconds(self) -> float:
        return self.upload_seconds + self.download_seconds


def checkpoint_job(runtime: ElasticRuntime, store: CheckpointStore,
                   job_id: str) -> SnapshotStats:
    """Consistent checkpoint of all W logical workers.

    DP replicas carry identical params/optimizer state — the content-
    addressed store dedups them so stored device bytes are independent of W
    (Table 4).  Host state (data cursor, step) is per-worker.
    """
    snap = runtime.snapshot()
    device_by_worker = {w: snap["state"] for w in range(runtime.world_size)}
    host_by_worker = {w: {"pipeline": snap["pipeline"],
                          "world_size": snap["world_size"],
                          "rank": w}
                      for w in range(runtime.world_size)}
    return store.snapshot(job_id, int(runtime.state["step"]),
                          device_by_worker, host_by_worker)


def migrate(runtime: ElasticRuntime, store: CheckpointStore, job_id: str,
            to_physical: int, cfg: ModelConfig, tcfg: TrainConfig,
            global_batch: int, seq_len: int,
            per_step_seconds: float = 0.5,
            blob_bandwidth: float = constants.BLOB_STORE_BANDWIDTH,
            barrier_seed: int = 0,
            topology=None, src_region: str = None,
            dst_region: str = None) -> tuple:
    """Preempt ``runtime`` and resume it on ``to_physical`` devices.

    When a ``RegionTopology`` and a (source, destination) region pair are
    given, the modelled blob transfer runs at that pair's link bandwidth
    plus its first-byte latency — the same tiers the scheduler's
    ``CostModel`` charges, so measured reports and fleet-wide pricing
    stay calibrated against each other (``CostModel.from_reports``).

    Returns (new_runtime, MigrationReport).
    """
    step_before = int(runtime.state["step"])
    transfer_latency = 0.0
    if topology is not None:
        blob_bandwidth = topology.bandwidth(src_region, dst_region)
        transfer_latency = topology.latency_seconds(src_region, dst_region)

    # 1. barrier: the distributed-protocol cost in mini-batches (from the
    #    faithful protocol engine), converted to wall time
    bres = run_barrier_simulation(
        world_size=runtime.world_size, n_collectives=4,
        command_at_step=3, schedule_seed=barrier_seed)
    assert bres.acquired and bres.consistent_cut
    barrier_s = bres.minibatches_to_acquire * per_step_seconds

    # 2. dump
    t0 = time.time()
    stats = checkpoint_job(runtime, store, job_id)
    dump_s = time.time() - t0

    # 3. transfer (modelled: the paper uploads to/downloads from blob
    #    store; a cross-region pair pays its slower link + first byte)
    total_bytes = stats.device_stored_bytes + stats.host_stored_bytes
    upload_s = total_bytes / blob_bandwidth
    download_s = total_bytes / blob_bandwidth + transfer_latency

    # 4. restore on the destination (fresh device proxies + replay; here:
    #    fresh runtime + state load + step compile = the rendezvous)
    t0 = time.time()
    device, host, step = store.restore(job_id)
    new_runtime = ElasticRuntime.from_snapshot(
        cfg, tcfg,
        {"state": device[0], "pipeline": host[0]["pipeline"],
         "world_size": host[0]["world_size"]},
        to_physical, global_batch, seq_len)
    new_runtime._step_fn()      # compile at destination
    restore_s = time.time() - t0

    work_conserving = int(new_runtime.state["step"]) == step_before
    report = MigrationReport(
        job_id=job_id, from_physical=runtime.physical,
        to_physical=to_physical, barrier_seconds=barrier_s,
        barrier_minibatches=bres.minibatches_to_acquire,
        dump_seconds=dump_s, upload_seconds=upload_s,
        download_seconds=download_s, restore_seconds=restore_s,
        total_seconds=barrier_s + dump_s + upload_s + download_s + restore_s,
        device_stored_bytes=stats.device_stored_bytes,
        host_stored_bytes=stats.host_stored_bytes,
        work_conserving=work_conserving,
        src_region=src_region, dst_region=dst_region)
    return new_runtime, report

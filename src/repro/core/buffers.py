"""Simulated device memory with the paper's bidirectional allocator (§5.2.2).

Stable buffers (parameters, optimizer state — preserved across mini-batches)
are allocated from the HIGH end of the address space; transient buffers
(activations, workspace — variable-sized across replicas) from the LOW end.
Consequence (the paper's key invariant): as long as two replicas perform the
same *stable* allocation sequence, their stable buffers land at identical
addresses, no matter how the interleaved transient allocations diverge.

This is an executable model used by the splicing engine, the transparent
checkpointer and the property tests; data lives in numpy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.utils.hashing import buffer_checksum


class OutOfMemory(Exception):
    pass


@dataclasses.dataclass
class Buffer:
    addr: int
    size: int
    stable: bool
    data: Optional[np.ndarray] = None     # None => allocated but not written
    freed: bool = False                   # lazily GC'd (paper §5.2.1)

    def checksum(self) -> str:
        assert self.data is not None, "checksum of unwritten buffer"
        return buffer_checksum(self.data)


class DeviceMemory:
    """Bidirectional bump allocator over a fixed-size address space."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.low = 0                      # next transient address (grows up)
        self.high = capacity              # next stable address (grows down)
        self.buffers: Dict[int, Buffer] = {}     # addr -> Buffer (live)
        self.lazy_freed: Dict[int, Buffer] = {}  # addr -> Buffer (GC-pending)

    # -- allocation ----------------------------------------------------------
    def alloc(self, size: int, stable: bool) -> Buffer:
        if self.low + size > self.high:
            self._gc(size)
        if self.low + size > self.high:
            raise OutOfMemory(f"alloc {size} (low={self.low} high={self.high})")
        if stable:
            self.high -= size
            addr = self.high
        else:
            addr = self.low
            self.low += size
        buf = Buffer(addr=addr, size=size, stable=stable)
        self.buffers[addr] = buf
        return buf

    def free(self, addr: int, lazy: bool = False) -> None:
        buf = self.buffers.pop(addr)
        buf.freed = True
        if lazy:
            # keep content resident so a later swap-in may be elided
            self.lazy_freed[addr] = buf
        self._maybe_shrink()

    def _maybe_shrink(self) -> None:
        """Bump pointers back when the frontier buffers are freed (simple
        bump-allocator reclamation; sufficient for the mini-batch allocation
        patterns this models)."""
        moved = True
        while moved:
            moved = False
            live_low = [a for a, b in self.buffers.items() if not b.stable]
            top = max((a + self.buffers[a].size for a in live_low), default=0)
            if top < self.low:
                self.low = top
                moved = True
            live_high = [a for a, b in self.buffers.items() if b.stable]
            bottom = min(live_high, default=self.capacity)
            if bottom > self.high:
                self.high = bottom
                moved = True

    def _gc(self, need: int) -> None:
        """Drop lazily-freed cached buffers to make room (paper: GC happens
        lazily on demand for fresh allocations)."""
        self.lazy_freed.clear()
        self._maybe_shrink()

    # -- content -------------------------------------------------------------
    def write(self, addr: int, data: np.ndarray) -> None:
        buf = self.buffers[addr]
        assert data.nbytes <= buf.size, (data.nbytes, buf.size)
        buf.data = np.array(data, copy=True)

    def read(self, addr: int) -> np.ndarray:
        buf = self.buffers[addr]
        assert buf.data is not None
        return buf.data

    def find_by_checksum(self, checksum: str) -> Optional[Buffer]:
        """Content lookup across live + lazily-freed buffers (paper §5.2.1:
        opportunistically cache versions on device)."""
        for pool in (self.buffers, self.lazy_freed):
            for buf in pool.values():
                if buf.data is not None and buf.checksum() == checksum:
                    return buf
        return None

    # -- introspection ---------------------------------------------------------
    def live_bytes(self) -> int:
        return sum(b.size for b in self.buffers.values())

    def stable_buffers(self) -> Dict[int, Buffer]:
        return {a: b for a, b in self.buffers.items() if b.stable}

    def transient_buffers(self) -> Dict[int, Buffer]:
        return {a: b for a, b in self.buffers.items() if not b.stable}

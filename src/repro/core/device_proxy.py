"""Device proxy: interception, handle virtualization, log & replay (§3, §4.2).

The proxy decouples a worker's host process from the device:

- ``DeviceProxyServer`` — one per physical device; owns the ``DeviceMemory``
  (so it has full visibility into live buffers) and executes device ops.
  It is (almost) stateless: on migration it is simply restarted and the
  client's replay log rebuilds its state.
- ``DeviceProxyClient`` — one per worker process; intercepts device APIs.
  *Dispatch interceptors* (D_Int) ship the call to the server;
  *semantics-aware interceptors* (SA_Int) add logic: memory allocation,
  collective communication, synchronization (the three HAL categories of
  §3.2), plus host-side file-IO tracking (§3.3).

Handles returned to the worker are VIRTUAL (§4.2.1): the client keeps a
virtual→physical map; state-changing calls are logged; after a restore the
log is replayed against a fresh server and the virtual handles stay valid
while the physical ones change.

This is the executable model of the paper's mechanism; the JAX hot path
(``core/elastic.py``) plays the proxy's role inside the compiled step, and
the checkpoint/migration layers use this model for state management.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.buffers import DeviceMemory

STATE_CHANGING = {"create_stream", "create_event", "create_communicator",
                  "malloc"}


@dataclasses.dataclass
class LogEntry:
    api: str
    args: Tuple
    kwargs: Dict
    virtual_handle: Optional[int]


class DeviceProxyServer:
    """Executes device ops against the simulated device memory."""

    def __init__(self, capacity: int, device_id: int = 0):
        self.device_id = device_id
        # the proxy "hogs the entire GPU memory at startup" (§4.2) — the
        # allocator below owns the whole address space.
        self.memory = DeviceMemory(capacity)
        self._phys_counter = itertools.count(1000)
        self.streams: Dict[int, List] = {}
        self.events: Dict[int, bool] = {}
        self.communicators: Dict[int, Dict] = {}
        self.kernel_launches = 0

    def execute(self, api: str, *args, **kwargs) -> Any:
        return getattr(self, f"_op_{api}")(*args, **kwargs)

    # -- ops -------------------------------------------------------------
    def _op_create_stream(self) -> int:
        h = next(self._phys_counter)
        self.streams[h] = []
        return h

    def _op_create_event(self) -> int:
        h = next(self._phys_counter)
        self.events[h] = False
        return h

    def _op_create_communicator(self, world_size: int, rank: int) -> int:
        h = next(self._phys_counter)
        self.communicators[h] = {"world_size": world_size, "rank": rank, "count": 0}
        return h

    def _op_malloc(self, size: int, stable: bool) -> int:
        return self.memory.alloc(size, stable).addr

    def _op_free(self, addr: int, lazy: bool = False) -> None:
        self.memory.free(addr, lazy=lazy)

    def _op_memcpy_h2d(self, addr: int, data: np.ndarray) -> None:
        self.memory.write(addr, data)

    def _op_memcpy_d2h(self, addr: int) -> np.ndarray:
        return np.array(self.memory.read(addr), copy=True)

    def _op_launch_kernel(self, fn: Callable, in_addrs: Tuple[int, ...],
                          out_addrs: Tuple[int, ...]) -> None:
        self.kernel_launches += 1
        ins = [self.memory.read(a) for a in in_addrs]
        outs = fn(*ins)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        for addr, out in zip(out_addrs, outs):
            self.memory.write(addr, out)

    def _op_record_event(self, event: int) -> None:
        self.events[event] = True

    def _op_stream_wait_event(self, stream: int, event: int) -> None:
        # device-side sync point — the splicing engine hooks this
        pass


class DeviceProxyClient:
    """Per-worker interception layer with virtual handles + replay log."""

    def __init__(self, server: DeviceProxyServer, rank: int = 0):
        self.server = server
        self.rank = rank
        self._virt_counter = itertools.count(1)
        self.v2p: Dict[int, int] = {}          # virtual -> physical handle/addr
        self.log: List[LogEntry] = []          # state-changing call log (§4.2.1)
        self.written_files: List[str] = []     # host SA_Int on libc IO (§3.3)
        self.sync_hooks: List[Callable] = []   # splicing context-switch hooks
        # domain-specific log compaction: freed allocations drop their malloc
        self._freed_virtuals: set = set()

    # -- D_Int dispatch ----------------------------------------------------
    def call(self, api: str, *args, **kwargs) -> Any:
        """Intercept a device API call (the D_Int path)."""
        # client SA_Int: resolve virtual handles in args
        phys_args = tuple(self.v2p.get(a, a) if isinstance(a, int) else a
                          for a in args)
        if api == "stream_wait_event":
            for hook in self.sync_hooks:
                hook(self)
        result = self.server.execute(api, *phys_args, **kwargs)
        if api in STATE_CHANGING:
            vh = next(self._virt_counter)
            self.v2p[vh] = result
            self.log.append(LogEntry(api, args, kwargs, vh))
            return vh
        if api == "free":
            (vaddr,) = args
            self._freed_virtuals.add(vaddr)
            self.v2p.pop(vaddr, None)
        return result

    # -- host SA_Int: file IO tracking (§3.3) -------------------------------
    def open_file(self, path: str, mode: str) -> None:
        if any(m in mode for m in ("w", "a", "+")):
            if path not in self.written_files:
                self.written_files.append(path)

    # -- checkpoint/restore --------------------------------------------------
    def compact_log(self) -> List[LogEntry]:
        """Domain-specific rule: drop mallocs whose buffer was freed."""
        return [e for e in self.log
                if not (e.api == "malloc" and e.virtual_handle in self._freed_virtuals)]

    def snapshot_device_state(self) -> Dict[int, Dict]:
        """Dump live device buffers keyed by VIRTUAL handle.

        Thanks to the malloc SA_Int the proxy knows exactly which regions
        are in use (§4.2) — only those are dumped.
        """
        out = {}
        for vh, phys in self.v2p.items():
            if phys in self.server.memory.buffers:
                buf = self.server.memory.buffers[phys]
                if buf.data is not None:
                    out[vh] = {"data": np.array(buf.data, copy=True),
                               "stable": buf.stable, "addr": phys}
        return out

    def restore(self, new_server: DeviceProxyServer,
                device_state: Dict[int, Dict]) -> None:
        """Respawn against a fresh server: replay the state-changing log,
        then copy tensors back.  Virtual handles keep their values; the
        physical handles change underneath (§4.2.1)."""
        self.server = new_server
        self.v2p = {}
        for entry in self.compact_log():
            phys = new_server.execute(entry.api, *entry.args, **entry.kwargs)
            self.v2p[entry.virtual_handle] = phys
        # mmap SA_Int guarantees stable buffers map to the same addresses
        for vh, st in device_state.items():
            if vh not in self.v2p:
                continue
            new_server.execute("memcpy_h2d", self.v2p[vh], st["data"])

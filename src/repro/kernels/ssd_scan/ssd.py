"""Mamba2 SSD intra-chunk kernel (Pallas TPU).

The SSD chunked algorithm (arXiv:2405.21060) splits the linear recurrence
into: (a) a quadratic attention-like computation INSIDE each fixed-size
chunk plus that chunk's input-state contribution — embarrassingly parallel
over (batch x chunk), all-MXU work; and (b) a tiny sequential recurrence
ACROSS chunks.  This kernel is (a); the wrapper in ops.py runs (b) as a
``lax.scan`` over the per-chunk states and adds the inter-chunk output
term.

Tiling: grid = (batch*nchunks); each step holds one chunk in VMEM:
x (Q, H, P), dt (Q, H), B/C (Q, N).  Q = chunk (128 default), P = head_dim,
N = state_dim — Q x N and Q x Q matmuls are MXU-shaped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
            y_ref, state_ref, cum_ref):
    x = x_ref[0].astype(jnp.float32)        # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)      # (Q, H)
    a = a_ref[...].astype(jnp.float32)      # (H,)
    b = b_ref[0].astype(jnp.float32)        # (Q, N)
    c = c_ref[0].astype(jnp.float32)        # (Q, N)
    q = x.shape[0]

    adt = dt * a[None, :]                   # (Q, H) negative decay steps
    cum = jnp.cumsum(adt, axis=0)           # within-chunk cumulative decay

    # intra-chunk attention-like term
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q, Q)
    seg = cum[:, None, :] - cum[None, :, :]                        # (Q, Q, H)
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    mask = (cols <= rows)[:, :, None]
    decay = jnp.where(mask, jnp.exp(jnp.where(mask, seg, 0.0)), 0.0)
    m = cb[:, :, None] * decay * dt[None, :, :]                    # (Q, Q, H)
    y = jnp.einsum("tsh,shp->thp", m, x)                           # (Q, H, P)

    # chunk input-state contribution: S_c = sum_s exp(total - cum_s) dt_s B_s x_s
    total = cum[-1]                                                # (H,)
    w = jnp.exp(total[None, :] - cum) * dt                         # (Q, H)
    state = jnp.einsum("sh,sn,shp->hpn", w, b, x)                  # (H, P, N)

    y_ref[0] = y
    state_ref[0] = state
    cum_ref[0] = cum


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(x: jax.Array, dt: jax.Array, a: jax.Array,
                    b: jax.Array, c: jax.Array,
                    interpret: bool = True):
    """x: (BC, Q, H, P), dt: (BC, Q, H), a: (H,), b/c: (BC, Q, N).

    Returns (y_intra (BC,Q,H,P) f32, states (BC,H,P,N) f32, cum (BC,Q,H) f32).
    """
    bc, q, h, p = x.shape
    n = b.shape[-1]
    return pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((bc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bc, h, p, n), jnp.float32),
            jax.ShapeDtypeStruct((bc, q, h), jnp.float32),
        ),
        grid=(bc,),
        in_specs=[
            pl.BlockSpec((1, q, h, p), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, q, h), lambda i: (i, 0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, q, h, p), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, q, h), lambda i: (i, 0, 0)),
        ),
        interpret=interpret,
    )(x, dt, a, b, c)

"""Oracle for the SSD kernel: the pure-jnp chunked scan from models/ssm.py
(itself validated against the O(L) sequential recurrence in tests)."""
from repro.models.ssm import ssd_chunked as ssd_chunked_ref  # noqa: F401


def ssd_sequential_ref(x, dt, a, b, c):
    """O(L) sequential recurrence — the ground-truth semantics."""
    import jax
    import jax.numpy as jnp

    bs, l, h, p = x.shape
    n = b.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp          # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * a[None, :])                      # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
        state = state * decay[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    s0 = jnp.zeros((bs, h, p, n), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          b.transpose(1, 0, 2).astype(jnp.float32),
          c.transpose(1, 0, 2).astype(jnp.float32))
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), final

"""Public SSD op: Pallas intra-chunk kernel + inter-chunk recurrence."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd import ssd_intra_chunk


def ssd_chunked_pallas(x: jax.Array, dt: jax.Array, a: jax.Array,
                       b: jax.Array, c: jax.Array, chunk: int,
                       initial_state: Optional[jax.Array] = None,
                       interpret: bool = True
                       ) -> Tuple[jax.Array, jax.Array]:
    """Drop-in replacement for ``models.ssm.ssd_chunked`` backed by the
    Pallas kernel.  Shapes as there: x (B,L,H,P), dt (B,L,H), a (H,),
    b/c (B,L,N) -> (y (B,L,H,P), final_state (B,H,P,N))."""
    bs, l, h, p = x.shape
    n = b.shape[-1]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk

    xc = x.reshape(bs * nc, chunk, h, p)
    dtc = dt.reshape(bs * nc, chunk, h)
    bc_ = b.reshape(bs * nc, chunk, n)
    cc = c.reshape(bs * nc, chunk, n)

    y_intra, states, cum = ssd_intra_chunk(
        xc, dtc, a.astype(jnp.float32), bc_, cc, interpret=interpret)
    y_intra = y_intra.reshape(bs, nc, chunk, h, p)
    states = states.reshape(bs, nc, h, p, n)
    cum = cum.reshape(bs, nc, chunk, h)

    # inter-chunk recurrence (tiny, sequential over nc)
    total = cum[:, :, -1, :]                      # (B, nc, H)
    decay_chunk = jnp.exp(total)

    def step(s_prev, inp):
        dc, sc = inp
        return s_prev * dc[:, :, None, None] + sc, s_prev

    s0 = (jnp.zeros((bs, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    final, s_before = jax.lax.scan(
        step, s0, (decay_chunk.transpose(1, 0, 2),
                   states.transpose(1, 0, 2, 3, 4)))
    s_before = s_before.transpose(1, 0, 2, 3, 4)   # (B,nc,H,P,N)

    outw = jnp.exp(cum)
    y_inter = jnp.einsum("bqtn,bqhpn,bqth->bqthp",
                         cc.reshape(bs, nc, chunk, n).astype(jnp.float32),
                         s_before, outw)
    y = (y_intra + y_inter).reshape(bs, nc * chunk, h, p)[:, :l]
    return y.astype(x.dtype), final

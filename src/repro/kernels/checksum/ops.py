"""Public fingerprint op: arbitrary-array content digest via the Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.checksum.fingerprint import LANES, ROWS, fingerprint_u32


def _as_words(arr: jax.Array) -> jax.Array:
    """Bit-exact view of any array as padded (N, 128) uint32 words."""
    a = jnp.ravel(arr)
    if a.dtype == jnp.bfloat16 or a.dtype == jnp.float16:
        a = a.view(jnp.uint16).astype(jnp.uint32)
    elif a.dtype.itemsize == 4:
        a = a.view(jnp.uint32)
    elif a.dtype.itemsize == 8:
        a = a.view(jnp.uint32)
    elif a.dtype.itemsize == 1:
        a = a.view(jnp.uint8).astype(jnp.uint32)
    else:
        a = a.astype(jnp.float32).view(jnp.uint32)
    block = ROWS * LANES
    pad = (-a.shape[0]) % block
    a = jnp.pad(a, (0, pad))
    return a.reshape(-1, LANES)


def fingerprint(arr: jax.Array, interpret: bool = True) -> jax.Array:
    """128-bit content digest of an array, computed on-device.

    Equal contents (same dtype/shape) always produce equal digests;
    distinct contents collide with probability ~2^-128 under the
    position-weighted modular-sum family.
    """
    return fingerprint_u32(_as_words(arr), interpret=interpret)


def digest_hex(arr) -> str:
    """Host-side convenience: hex string of the digest."""
    d = np.asarray(fingerprint(jnp.asarray(arr)))
    return "".join(f"{int(x):08x}" for x in d)

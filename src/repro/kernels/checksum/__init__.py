from repro.kernels.checksum.ops import fingerprint  # noqa: F401

"""Pure-jnp oracle for the fingerprint kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.checksum.fingerprint import P1, P2, P3, P4


def fingerprint_u32_ref(words: jax.Array) -> jax.Array:
    """words: (N, 128) uint32 -> (4,) uint32 digest (same math, no tiling)."""
    x = words.reshape(-1)
    pos = jnp.arange(x.shape[0], dtype=jnp.uint32)
    w = pos * P1 + P2
    l0 = jnp.sum(x * w, dtype=jnp.uint32)
    l1 = jnp.sum((x ^ P3) * (w ^ P4), dtype=jnp.uint32)
    l2 = jnp.sum((x * x + P4) * w, dtype=jnp.uint32)
    l3 = jnp.sum((x + pos) * (pos * P3 + P1), dtype=jnp.uint32)
    return jnp.stack([l0, l1, l2, l3])

"""On-device content-fingerprint kernel (Pallas TPU).

The paper's checksum-based dedup (§4.6 checkpoint compression, §5.2.1
conditional swap) fingerprints EVERY live device buffer at every context
switch and checkpoint — on TPU this must run at HBM bandwidth on-device so
only the 128-bit digest crosses to the host.

Digest: four uint32 lanes of position-weighted modular sums.  Per-position
weights make the digest permutation-sensitive; per-block partial digests
combine by wrapping addition, so the grid reduction is embarrassingly
parallel.  Block shape (ROWS, 128): last dim matches the TPU lane width,
ROWS*128*4B per block sized well under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

ROWS = 256                    # 256 x 128 x 4B = 128 KiB per input block
LANES = 128

# numpy scalars embed as jaxpr literals (Pallas kernels must not capture
# traced constants, and python ints > int32-max overflow weak typing)
P1 = np.uint32(2654435761)    # Knuth multiplicative
P2 = np.uint32(0x9E3779B9)    # golden ratio
P3 = np.uint32(0x85EBCA6B)    # murmur3 c1
P4 = np.uint32(0xC2B2AE35)    # murmur3 c2


def _digest_block(x: jax.Array, pos: jax.Array) -> jax.Array:
    """4-lane partial digest of a uint32 block with global positions."""
    w = pos * P1 + P2
    l0 = jnp.sum(x * w, dtype=jnp.uint32)
    l1 = jnp.sum((x ^ P3) * (w ^ P4), dtype=jnp.uint32)
    l2 = jnp.sum((x * x + P4) * w, dtype=jnp.uint32)
    l3 = jnp.sum((x + pos) * (pos * P3 + P1), dtype=jnp.uint32)
    return jnp.stack([l0, l1, l2, l3])


def _kernel(x_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...]
    rows = jax.lax.broadcasted_iota(jnp.uint32, (ROWS, LANES), 0)
    lanes = jax.lax.broadcasted_iota(jnp.uint32, (ROWS, LANES), 1)
    base = jnp.uint32(i) * jnp.uint32(ROWS * LANES)
    pos = base + rows * jnp.uint32(LANES) + lanes
    o_ref[0, :] = _digest_block(x, pos)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fingerprint_u32(words: jax.Array, interpret: bool = True) -> jax.Array:
    """words: (n_blocks*ROWS, LANES) uint32 -> (4,) uint32 digest."""
    n, l = words.shape
    assert l == LANES and n % ROWS == 0, (n, l)
    nblocks = n // ROWS
    partials = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((nblocks, 4), jnp.uint32),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 4), lambda i: (i, 0)),
        interpret=interpret,
    )(words)
    return jnp.sum(partials, axis=0, dtype=jnp.uint32)

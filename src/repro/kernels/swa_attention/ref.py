"""Pure-jnp oracle: naive masked softmax attention (full materialization)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def swa_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      window: int = 0) -> jax.Array:
    """q,k,v: (BH, S, D) (same seq); causal + optional sliding window."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    sq, sk = q.shape[1], k.shape[1]
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = kp <= qp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)

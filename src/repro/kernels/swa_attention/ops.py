"""Public sliding-window attention op: padding + head layout around the kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.swa_attention.swa import BKV, BQ, swa_flash


def swa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  window: int = 0, interpret: bool = True) -> jax.Array:
    """Causal (optionally sliding-window) attention.

    q, k, v: (B, S, H, D) — kv heads already repeated to H (GQA handled by
    the caller).  Returns (B, S, H, D).
    """
    b, s, h, d = q.shape
    skv = k.shape[1]

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], d)

    qf, kf, vf = to_bh(q), to_bh(k), to_bh(v)
    pq = (-s) % BQ
    pk = (-skv) % BKV
    if pq:
        qf = jnp.pad(qf, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kf = jnp.pad(kf, ((0, 0), (0, pk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pk), (0, 0)))
    out = swa_flash(qf, kf, vf, window=window, seq_kv=skv,
                    interpret=interpret)
    out = out[:, :s]
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)

"""Sliding-window flash attention kernel (Pallas TPU).

Online-softmax attention with an explicit sliding window: query block i
visits only the kv blocks inside its window, so work and VMEM are
O(window) per query block instead of O(seq) — the kernel behind the
sub-quadratic ``long_500k`` decode variant and the SWA training path
(h2o-danube, zamba2 shared blocks).

Tiling: grid = (batch*heads, n_q_blocks, n_kv_steps); blocks (BQ, D) for q
and (BKV, D) for k/v live in VMEM; f32 accumulators (m, l, acc) persist in
VMEM scratch across the kv-step dimension (TPU grids iterate the last axis
innermost/sequentially).  MXU-aligned: BQ = BKV = 128, D = head_dim.
For full-causal (window=0) the kv-step count equals the kv block count and
off-diagonal blocks are skipped via ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128
BKV = 128
NEG_INF = -1e30


def _steps(window: int, n_kv: int) -> int:
    """KV blocks each query block must visit."""
    if window <= 0:
        return n_kv
    return min(n_kv, (window + BQ - 1) // BKV + 1)


def _kv_index(q_i, j, steps: int):
    """KV block index for (q block, step): trailing `steps` blocks ending at
    the diagonal; clamped (skipped in-body when negative)."""
    return jnp.maximum(q_i - (steps - 1) + j, 0)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, steps: int, window: int, seq_kv: int, scale: float):
    q_i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_j = q_i - (steps - 1) + j

    @pl.when(kv_j >= 0)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (BQ, D)
        k = k_ref[0].astype(jnp.float32)                  # (BKV, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_i * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BKV), 0)
        k_pos = kv_j * BKV + jax.lax.broadcasted_iota(jnp.int32, (BQ, BKV), 1)
        mask = (k_pos <= q_pos) & (k_pos < seq_kv)
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "seq_kv", "interpret"))
def swa_flash(q: jax.Array, k: jax.Array, v: jax.Array, *, window: int,
              seq_kv: int, interpret: bool = True) -> jax.Array:
    """q: (BH, Sq, D), k/v: (BH, Skv, D), padded to block multiples.

    Returns (BH, Sq, D).  ``seq_kv`` is the unpadded kv length (masking).
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    assert sq % BQ == 0 and skv % BKV == 0, (sq, skv)
    nq, nkv = sq // BQ, skv // BKV
    steps = _steps(window, nkv)
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(_kernel, steps=steps, window=window,
                               seq_kv=seq_kv, scale=scale)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        grid=(bh, nq, steps),
        in_specs=[
            pl.BlockSpec((1, BQ, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BKV, d),
                         lambda b, i, j, s=steps: (b, _kv_index(i, j, s), 0)),
            pl.BlockSpec((1, BKV, d),
                         lambda b, i, j, s=steps: (b, _kv_index(i, j, s), 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, d), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((BQ, 1), jnp.float32),
            pltpu.VMEM((BQ, 1), jnp.float32),
            pltpu.VMEM((BQ, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

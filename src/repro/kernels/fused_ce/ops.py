"""Public fused-CE op: padding + masking around the kernel."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.fused_ce.ce import BT, fused_ce_stats


def fused_cross_entropy(hidden: jax.Array, head: jax.Array,
                        labels: jax.Array, interpret: bool = True
                        ) -> Tuple[jax.Array, jax.Array]:
    """Token-level CE without materializing logits.

    hidden: (T, d); head: (d, V); labels: (T,) int32, < 0 = ignore.
    Returns (sum loss, token count) — same contract as
    ``models.model.chunked_cross_entropy`` on flattened inputs.
    """
    t = hidden.shape[0]
    pad = (-t) % BT
    if pad:
        hidden = jnp.pad(hidden, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad), constant_values=-1)
    safe = jnp.maximum(labels, 0)
    lse, pick = fused_ce_stats(hidden, head, safe, interpret=interpret)
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum((lse[:, 0] - pick[:, 0]) * mask)
    return loss, jnp.sum(mask)

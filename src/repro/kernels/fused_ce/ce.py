"""Fused cross-entropy kernel (Pallas TPU).

The LM loss is the other memory hot spot besides attention: naive lowering
materializes (tokens, vocab) logits in HBM (yi-9b train: 1M x 64k x 4 B
per step).  This kernel streams vocab TILES through VMEM with an online
logsumexp, so per token only the running (max, sumexp, label-logit)
statistics ever leave the core — the logits matrix never exists in HBM.

Tiling: grid = (token_blocks, vocab_blocks); per step the (BT, d) hidden
tile and the (d, BV) head tile produce a (BT, BV) logit tile on the MXU;
f32 running stats persist in VMEM scratch across the vocab dimension
(innermost, sequential).  MXU-aligned: BT = 128, BV = 512.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BT = 128
BV = 512
NEG_INF = -1e30


def _kernel(h_ref, w_ref, lab_ref, lse_ref, pick_ref,
            m_ref, l_ref, p_ref, *, n_vocab: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        p_ref[...] = jnp.full_like(p_ref, NEG_INF)

    h = h_ref[...].astype(jnp.float32)               # (BT, d)
    w = w_ref[...].astype(jnp.float32)               # (d, BV)
    logits = jax.lax.dot_general(h, w, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    vpos = j * BV + jax.lax.broadcasted_iota(jnp.int32, (BT, BV), 1)
    valid = vpos < n_vocab
    logits = jnp.where(valid, logits, NEG_INF)

    # online logsumexp
    m_prev = m_ref[...]                              # (BT, 1)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
    l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) \
        + jnp.sum(jnp.exp(logits - m_new), axis=1, keepdims=True)
    m_ref[...] = m_new

    # label-logit pick: the label lands in exactly one vocab tile
    lab = lab_ref[...]                               # (BT, 1) int32
    hit = (vpos == lab) & valid
    p_ref[...] = jnp.maximum(
        p_ref[...], jnp.max(jnp.where(hit, logits, NEG_INF),
                            axis=1, keepdims=True))

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        lse_ref[...] = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
        pick_ref[...] = p_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_ce_stats(hidden: jax.Array, head: jax.Array, labels: jax.Array,
                   interpret: bool = True):
    """hidden (T, d) x head (d, V), labels (T,) -> (lse (T,1), pick (T,1)).

    T must be a multiple of BT; V is padded internally to BV multiples.
    Negative labels return pick = -inf (masked by the wrapper).
    """
    t, d = hidden.shape
    v = head.shape[1]
    assert t % BT == 0, t
    pv = (-v) % BV
    if pv:
        head = jnp.pad(head, ((0, 0), (0, pv)))
    nv = head.shape[1] // BV
    lab2 = labels.reshape(t, 1)

    kernel = functools.partial(_kernel, n_vocab=v)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((t, 1), jnp.float32),
            jax.ShapeDtypeStruct((t, 1), jnp.float32),
        ),
        grid=(t // BT, nv),
        in_specs=[
            pl.BlockSpec((BT, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, BV), lambda i, j: (0, j)),
            pl.BlockSpec((BT, 1), lambda i, j: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((BT, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((BT, 1), lambda i, j: (i, 0)),
        ),
        scratch_shapes=[
            pltpu.VMEM((BT, 1), jnp.float32),
            pltpu.VMEM((BT, 1), jnp.float32),
            pltpu.VMEM((BT, 1), jnp.float32),
        ],
        interpret=interpret,
    )(hidden, head, lab2)

"""Pure-jnp oracle: full-logits cross entropy."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy_ref(hidden: jax.Array, head: jax.Array,
                      labels: jax.Array):
    """Returns (sum loss over labels >= 0, count)."""
    logits = (hidden.astype(jnp.float32) @ head.astype(jnp.float32))
    lse = jax.nn.logsumexp(logits, axis=-1)
    pick = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[:, None], axis=1)[:, 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - pick) * mask), jnp.sum(mask)

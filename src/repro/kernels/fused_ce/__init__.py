from repro.kernels.fused_ce.ops import fused_cross_entropy  # noqa: F401

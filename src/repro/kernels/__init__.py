"""Pallas TPU kernels for the framework's compute hot spots.

- ``checksum``      — on-device content fingerprint (the hot loop of the
  paper's checksum-based dedup, §4.6/§5.2.1: every context switch and every
  checkpoint fingerprints all live buffers).
- ``swa_attention`` — sliding-window flash attention (sub-quadratic decode
  for the long_500k shape; also the dense-arch training hot spot).
- ``ssd_scan``      — Mamba2 SSD intra-chunk kernel (ssm/hybrid archs).
- ``fused_ce``      — streaming-vocab cross entropy: online logsumexp over
  vocab tiles so the (tokens, vocab) logits never exist in HBM (the other
  memory hot spot the roofline analysis exposed).

Each kernel directory has: the ``pl.pallas_call`` kernel with explicit
BlockSpec VMEM tiling, ``ops.py`` (jit'd public wrapper), ``ref.py``
(pure-jnp oracle).  Kernels are validated in interpret mode on CPU; TPU is
the target.
"""

"""Modality frontend STUBS (the one allowed carve-out).

[audio] and [vlm] architectures specify the transformer backbone only; the
mel-spectrogram+conv codec / ViT vision encoder are stubbed: these helpers
produce (or spec) precomputed frame/patch embeddings of the right shape.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def extra_inputs_spec(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    """ShapeDtypeStructs for modality inputs consumed by the backbone."""
    if cfg.arch_type == "vlm":
        return {"image_embeds": jax.ShapeDtypeStruct(
            (batch, cfg.vlm.num_image_tokens, cfg.vlm.image_embed_dim), dtype)}
    if cfg.arch_type == "audio":
        return {"encoder_frames": jax.ShapeDtypeStruct(
            (batch, cfg.encdec.encoder_seq, cfg.d_model), dtype)}
    return {}


def synth_extra_inputs(cfg: ModelConfig, batch: int, key: jax.Array,
                       dtype=jnp.float32) -> Dict:
    """Concrete synthetic embeddings for smoke tests / examples."""
    specs = extra_inputs_spec(cfg, batch, dtype)
    out = {}
    for name, spec in specs.items():
        key, sub = jax.random.split(key)
        out[name] = (0.02 * jax.random.normal(sub, spec.shape, jnp.float32)
                     ).astype(dtype)
    return out

"""Mixture-of-Experts layer: top-k router + expert-parallel dispatch.

TPU-native layout (DESIGN.md §5): token activations are sharded over the
batch axes and replicated over "model"; expert weights are sharded over
"model".  Dispatch runs inside ``shard_map``: each model shard selects the
tokens routed to ITS experts, scatters them into local capacity buffers
(purely local — no SPMD scatter partitioning), runs the expert FFN, and the
per-shard partial outputs combine with one ``psum`` over "model" — the MoE
collective the roofline tracks.  Outside a mesh the same code runs with a
single shard (CPU smoke tests).

Router load-balance auxiliary loss follows Switch/Mixtral practice.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.models.common import dense_init
from repro.models.mlp import init_mlp, mlp_forward
from repro.parallel.constraints import BATCH, constrain, current_mesh

EXPERT_PAD = 16   # pad expert count to a multiple of the model-axis size so
                  # expert weights shard expert-parallel (granite: 40->48)

# §Perf toggle: fuse the wi/wg up-projections into one matmul over
# concatenated weights — the capacity buffer is then read ONCE instead of
# twice per expert FFN (memory-bound MoE lever).
FUSED_GATE = False


def init_moe(key, d_model: int, d_ff: int, kind: str, moe: MoEConfig,
             dtype=jnp.float32) -> Dict:
    kr, ke, ks = jax.random.split(key, 3)
    e, f = moe.num_experts, d_ff
    out_scale = 0.02 / math.sqrt(2.0)
    keys = jax.random.split(ke, 3)
    params = {
        "router": dense_init(kr, (d_model, e), dtype=dtype),
        # stacked expert weights: (E, d, f) / (E, f, d)
        "wi": dense_init(keys[0], (e, d_model, f), dtype=dtype),
        "wo": dense_init(keys[2], (e, f, d_model), scale=out_scale, dtype=dtype),
    }
    if kind == "swiglu":
        params["wg"] = dense_init(keys[1], (e, d_model, f), dtype=dtype)
    if moe.shared_expert_ff:
        params["shared"] = init_mlp(ks, d_model, moe.shared_expert_ff, kind, dtype)
    return params


def router_topk(logits: jax.Array, top_k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """logits (T, E) -> (weights (T,k), indices (T,k), aux load-balance loss)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e f_e * p_e
    e = logits.shape[-1]
    me = jnp.mean(probs, axis=0)                                   # mean router prob
    onehot = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)       # top-1 assignment
    ce = jnp.mean(onehot, axis=0)                                  # fraction of tokens
    aux = e * jnp.sum(me * ce)
    return w, idx, aux


def _local_expert_ffn(xf, idx, weights, wi, wg, wo, *, k: int,
                      capacity: int, kind: str, e_offset,
                      axis_name: Optional[str]):
    """Per-shard dispatch + FFN + combine contribution.

    xf: (tl, d) local tokens; idx/weights: (tl, k) GLOBAL expert routing;
    wi/wg/wo: this shard's experts (e_loc, ...).  Returns (tl, d) partial
    output (sum over local experts); caller psums over the model axis.
    """
    tl, d = xf.shape
    e_loc = wi.shape[0]
    flat_idx = idx.reshape(-1) - e_offset                     # (tl*k,) local
    mine = (flat_idx >= 0) & (flat_idx < e_loc)
    safe_idx = jnp.where(mine, flat_idx, 0)
    onehot = jax.nn.one_hot(safe_idx, e_loc, dtype=jnp.int32) \
        * mine[:, None].astype(jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                              safe_idx[:, None], axis=1)[:, 0]
    keep = mine & (pos < capacity)
    safe_e = jnp.where(keep, safe_idx, 0)
    safe_p = jnp.where(keep, pos, 0)

    xk = jnp.repeat(xf, k, axis=0)                            # (tl*k, d)
    contrib = jnp.where(keep[:, None], xk, 0)
    buf = jnp.zeros((e_loc, capacity, d), xf.dtype)
    buf = buf.at[safe_e, safe_p].add(contrib)                 # local scatter

    if kind == "swiglu" and FUSED_GATE:
        wcat = jnp.concatenate([wi, wg], axis=-1).astype(xf.dtype)
        hg = jnp.einsum("ecd,edf->ecf", buf, wcat)
        f = wi.shape[-1]
        h = jax.nn.silu(hg[..., f:]) * hg[..., :f]
    else:
        h = jnp.einsum("ecd,edf->ecf", buf, wi.astype(xf.dtype))
        if kind == "swiglu":
            g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(xf.dtype))
            h = jax.nn.silu(g) * h
        else:
            h = jax.nn.gelu(h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, wo.astype(xf.dtype))

    gathered = out_buf[safe_e, safe_p]                        # (tl*k, d)
    wk = (weights.reshape(-1) * keep).astype(xf.dtype)
    out = jnp.sum((gathered * wk[:, None]).reshape(tl, k, d), axis=1)
    if axis_name is not None:
        out = jax.lax.psum(out, axis_name)                    # combine experts
    return out


def moe_forward(params: Dict, x: jax.Array, kind: str, moe: MoEConfig
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    e, k = moe.num_experts, moe.top_k
    epad = (-e) % EXPERT_PAD
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf, params["router"].astype(x.dtype))
    if epad:
        # padded experts: -inf router logits — never selected, zero flow
        logits = jnp.pad(logits, ((0, 0), (0, epad)), constant_values=-1e30)
    e_tot = e + epad
    weights, idx, aux = router_topk(logits, k)
    weights = weights.astype(x.dtype)

    def padw(name):
        w = params[name]
        if epad:
            w = jnp.pad(w, ((0, epad),) + ((0, 0),) * (w.ndim - 1))
        return w

    wi, wo = padw("wi"), padw("wo")
    wg = padw("wg") if "wg" in params else wi  # unused for gelu

    mesh = current_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}
    m = sizes.get("model", 1)
    batch_axes = tuple(a for a in BATCH if sizes.get(a, 1) > 1)
    n_batch = 1
    for a in batch_axes:
        n_batch *= sizes[a]

    if mesh is not None and m > 1 and e_tot % m == 0 and t % n_batch == 0:
        tl = t // n_batch
        capacity = max(int(math.ceil(tl * k / e_tot * moe.capacity_factor)), k)
        bspec = batch_axes if len(batch_axes) > 1 else \
            (batch_axes[0] if batch_axes else None)

        def shard_fn(xf_l, idx_l, w_l, wi_l, wg_l, wo_l):
            e_loc = wi_l.shape[0]
            e_off = jax.lax.axis_index("model") * e_loc
            out = _local_expert_ffn(
                xf_l, idx_l, w_l, wi_l, wg_l, wo_l, k=k,
                capacity=capacity, kind=kind, e_offset=e_off,
                axis_name="model")
            return out

        out = jax.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(bspec, None), P(bspec, None), P(bspec, None),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=P(bspec, None))(xf, idx, weights, wi, wg, wo)
    else:
        capacity = max(int(math.ceil(t * k / e_tot * moe.capacity_factor)), k)
        out = _local_expert_ffn(xf, idx, weights, wi, wg, wo, k=k,
                                capacity=capacity, kind=kind,
                                e_offset=jnp.int32(0), axis_name=None)

    out = constrain(out, BATCH, None)
    if "shared" in params:
        out = out + mlp_forward(params["shared"], xf[None], kind)[0]
    return out.reshape(b, s, d), aux * moe.router_aux_weight

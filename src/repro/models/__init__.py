from repro.models.model import (  # noqa: F401
    decode_step_fn,
    init_decode_state,
    init_params,
    loss_fn,
    model_forward,
    prefill_fn,
)

"""Shared layer primitives: norms, RoPE, initializers."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def rmsnorm(x: jax.Array, scale: Optional[jax.Array], eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    return x.astype(dtype)


def layernorm(x: jax.Array, scale: Optional[jax.Array], bias: Optional[jax.Array],
              eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dtype)


def apply_norm(kind: str, x: jax.Array, params: Optional[dict]) -> jax.Array:
    """Dispatch on the config's norm kind.

    ``nonparametric_ln`` (olmo, arXiv:2402.00838) is LayerNorm with no
    learned scale/bias — params is None.
    """
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"] if params else None)
    if kind == "layernorm":
        return layernorm(x, params["scale"] if params else None,
                         params.get("bias") if params else None)
    if kind == "nonparametric_ln":
        return layernorm(x, None, None)
    raise ValueError(f"unknown norm {kind!r}")


def norm_param(kind: str, dim: int, dtype=jnp.float32) -> Optional[dict]:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    if kind == "nonparametric_ln":
        return None
    raise ValueError(kind)


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta), dtype=jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    angles = angles[..., None, :]                              # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return rotated.astype(x.dtype)


def dense_init(key: jax.Array, shape, scale: float = 0.02, dtype=jnp.float32) -> jax.Array:
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)

"""Mamba2 / SSD (state-space duality) block, arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
computation inside fixed-size chunks, linear recurrence across chunks
(``lax.scan``).  Decode is the O(1) recurrent update on (B, H, P, N) state.

This pure-jnp implementation is also the oracle basis for the Pallas
``ssd_scan`` kernel.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.common import dense_init, rmsnorm
from repro.parallel.constraints import BATCH, MODEL, constrain


def init_ssm(key, d_model: int, cfg: SSMConfig, dtype=jnp.float32) -> Dict:
    d_in = cfg.expand * d_model
    nheads = d_in // cfg.head_dim
    n = cfg.state_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    conv_ch = d_in + 2 * n
    return {
        # in_proj -> [z (d_in), x (d_in), B (N), C (N), dt (H)]
        "in_proj": dense_init(k1, (d_model, 2 * d_in + 2 * n + nheads), dtype=dtype),
        "conv_w": dense_init(k2, (cfg.conv_width, conv_ch), scale=0.1, dtype=dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((nheads,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.full((nheads,), -2.0, jnp.float32),  # softplus(-2) ~ 0.13
        "norm_scale": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(k3, (d_in, d_model), dtype=dtype),
    }


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, chunk: int,
                initial_state: jax.Array | None = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x:  (B, L, H, P)   inputs per head
    dt: (B, L, H)      positive step sizes (already softplus'd)
    a:  (H,)           negative decay rates (A = -exp(A_log))
    b:  (B, L, N)      input projection (single group, broadcast over heads)
    c:  (B, L, N)      output projection
    Returns (y (B, L, H, P), final_state (B, H, P, N)).
    """
    bs, l, h, p = x.shape
    n = b.shape[-1]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)) + ((0, 0),))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    # chunked views: (B, nc, Q, ...)
    xq = x.reshape(bs, nc, chunk, h, p)
    dtq = dt.reshape(bs, nc, chunk, h)
    bq = b.reshape(bs, nc, chunk, n)
    cq = c.reshape(bs, nc, chunk, n)

    adt = dtq * a[None, None, None, :]                     # (B,nc,Q,H) decay log-steps
    cum = jnp.cumsum(adt, axis=2)                          # within-chunk cumulative
    total = cum[:, :, -1, :]                               # (B,nc,H)

    # --- intra-chunk (quadratic within chunk) ---
    # M[t,s] = (C_t . B_s) * exp(cum_t - cum_s) * dt_s   for s <= t
    cb = jnp.einsum("bqtn,bqsn->bqts", cq, bq,
                    preferred_element_type=jnp.float32)    # (B,nc,Q,Q)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,Q,Q,H) cum_t - cum_s
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.exp(jnp.where(mask[None, None, :, :, None], seg, -jnp.inf))
    m = cb[..., None] * decay * dtq[:, :, None, :, :]      # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bqtsh,bqshp->bqthp", m, xq.astype(jnp.float32))

    # --- chunk states ---
    # S_c = sum_s exp(total - cum_s) dt_s B_s (x) x_s  -> (B,nc,H,P,N)
    w = jnp.exp(total[:, :, None, :] - cum) * dtq          # (B,nc,Q,H)
    state_c = jnp.einsum("bqsh,bqsn,bqshp->bqhpn",
                         w, bq.astype(jnp.float32), xq.astype(jnp.float32))

    # --- inter-chunk recurrence over chunks ---
    decay_chunk = jnp.exp(total)                           # (B,nc,H)

    def step(s_prev, inp):
        dc, sc = inp                                       # (B,H), (B,H,P,N)
        s_new = s_prev * dc[:, :, None, None] + sc
        return s_new, s_prev

    s0 = (jnp.zeros((bs, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    final, s_before = jax.lax.scan(
        step, s0, (decay_chunk.transpose(1, 0, 2), state_c.transpose(1, 0, 2, 3, 4)))
    s_before = s_before.transpose(1, 0, 2, 3, 4)           # (B,nc,H,P,N) state entering chunk

    # --- inter-chunk contribution ---
    # y_inter[t] = C_t . (exp(cum_t) * S_in)
    outw = jnp.exp(cum)                                    # (B,nc,Q,H)
    y_inter = jnp.einsum("bqtn,bqhpn,bqth->bqthp",
                         cq.astype(jnp.float32), s_before, outw)

    y = (y_intra + y_inter).reshape(bs, nc * chunk, h, p)[:, :l]
    return y.astype(x.dtype), final


def _split_proj(proj: jax.Array, d_in: int, n: int, nheads: int):
    z = proj[..., :d_in]
    xbc = proj[..., d_in:d_in + d_in + 2 * n]
    dt = proj[..., d_in + d_in + 2 * n:]
    assert dt.shape[-1] == nheads
    return z, xbc, dt


def ssm_forward(params: Dict, xin: jax.Array, cfg: SSMConfig) -> jax.Array:
    """Full Mamba2 block: in_proj -> conv -> SSD -> gated norm -> out_proj."""
    bsz, l, d_model = xin.shape
    d_in = cfg.expand * d_model
    nheads = d_in // cfg.head_dim
    n = cfg.state_dim

    proj = jnp.einsum("bld,de->ble", xin, params["in_proj"].astype(xin.dtype))
    z, xbc, dt = _split_proj(proj, d_in, n, nheads)

    # causal depthwise conv over (x, B, C) channels
    w = params["conv_w"].astype(xin.dtype)                 # (W, ch)
    pad = cfg.conv_width - 1
    xp = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    conv = sum(xp[:, i:i + l] * w[i] for i in range(cfg.conv_width))
    conv = jax.nn.silu(conv + params["conv_b"].astype(xin.dtype))

    xs = constrain(conv[..., :d_in].reshape(bsz, l, nheads, cfg.head_dim),
                   BATCH, None, MODEL, None)
    bmat = conv[..., d_in:d_in + n]
    cmat = conv[..., d_in + n:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    y, _ = ssd_chunked(xs, dt, a, bmat, cmat, cfg.chunk_size)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, l, d_in).astype(xin.dtype)

    y = y * jax.nn.silu(z)
    y = rmsnorm(y, params["norm_scale"])
    return jnp.einsum("ble,ed->bld", y, params["out_proj"].astype(xin.dtype))


# ---------------------------------------------------------------------------
# Decode path (recurrent state)
# ---------------------------------------------------------------------------

def init_ssm_state(batch: int, d_model: int, cfg: SSMConfig,
                   dtype=jnp.float32) -> Dict:
    d_in = cfg.expand * d_model
    nheads = d_in // cfg.head_dim
    n = cfg.state_dim
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d_in + 2 * n), dtype),
        "ssm": jnp.zeros((batch, nheads, cfg.head_dim, n), jnp.float32),
    }


def ssm_decode_step(params: Dict, xin: jax.Array, state: Dict, cfg: SSMConfig
                    ) -> Tuple[jax.Array, Dict]:
    """One-token recurrent step.  xin: (B, 1, d_model)."""
    bsz, one, d_model = xin.shape
    d_in = cfg.expand * d_model
    nheads = d_in // cfg.head_dim
    n = cfg.state_dim

    proj = jnp.einsum("bld,de->ble", xin, params["in_proj"].astype(xin.dtype))
    z, xbc, dt = _split_proj(proj[:, 0], d_in, n, nheads)

    # conv ring: state holds previous W-1 inputs
    w = params["conv_w"].astype(xin.dtype)
    hist = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)  # (B, W, ch)
    conv = jnp.einsum("bwc,wc->bc", hist, w)
    conv = jax.nn.silu(conv + params["conv_b"].astype(xin.dtype))
    new_conv_state = hist[:, 1:]

    xs = conv[:, :d_in].reshape(bsz, nheads, cfg.head_dim)
    bmat = conv[:, d_in:d_in + n]
    cmat = conv[:, d_in + n:]

    dtp = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dtp * a[None, :])                                  # (B,H)
    # h' = decay h + dt * B (x) x
    upd = jnp.einsum("bh,bn,bhp->bhpn", dtp, bmat.astype(jnp.float32),
                     xs.astype(jnp.float32))
    h_new = state["ssm"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", cmat.astype(jnp.float32), h_new)
    y = y + params["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, d_in).astype(xin.dtype)

    y = y * jax.nn.silu(z)
    y = rmsnorm(y, params["norm_scale"])
    out = jnp.einsum("be,ed->bd", y, params["out_proj"].astype(xin.dtype))
    return out[:, None], {"conv": new_conv_state, "ssm": h_new}

"""Feed-forward layers: SwiGLU and GELU MLPs."""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.parallel.constraints import BATCH, MODEL, constrain


def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype=jnp.float32) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    out_scale = 0.02 / math.sqrt(2.0)
    if kind == "swiglu":
        return {
            "wi": dense_init(k1, (d_model, d_ff), dtype=dtype),
            "wg": dense_init(k2, (d_model, d_ff), dtype=dtype),
            "wo": dense_init(k3, (d_ff, d_model), scale=out_scale, dtype=dtype),
        }
    if kind == "gelu":
        return {
            "wi": dense_init(k1, (d_model, d_ff), dtype=dtype),
            "wo": dense_init(k3, (d_ff, d_model), scale=out_scale, dtype=dtype),
        }
    raise ValueError(f"unknown mlp kind {kind!r}")


def mlp_forward(params: Dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(x.dtype))
        g = jnp.einsum("bsd,df->bsf", x, params["wg"].astype(x.dtype))
        h = jax.nn.silu(g) * constrain(h, BATCH, None, MODEL)
    else:  # gelu
        h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(x.dtype))
        h = jax.nn.gelu(constrain(h, BATCH, None, MODEL))
    return constrain(
        jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(x.dtype)),
        BATCH, None, None)

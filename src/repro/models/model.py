"""Generic model assembly for all assigned architecture families.

One parameter/function pair covers dense, MoE, SSM, hybrid, audio (enc-dec)
and VLM families, driven entirely by ``ModelConfig``:

- ``init_params``     — parameter pytree (layers stacked for lax.scan)
- ``model_forward``   — training forward -> (loss, metrics)
- ``prefill_fn``      — prompt processing -> (last logits, decode state)
- ``decode_step_fn``  — one-token decode with KV/SSM caches
- ``init_decode_state`` — cache allocation (shape source for dry-runs)

Layers are scanned with stacked weights (small HLO, fast compiles, remat
per block).  Heterogeneous extras (zamba2 shared attention block, VLM
cross-attention every k layers) use GROUP SCANS — an outer scan over
groups of ``every`` layers with the extra block applied once per group —
rather than ``lax.cond``, so the lowered HLO has no conditionals on the
hot path (exact roofline accounting, cheaper compile).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.common import apply_norm, dense_init, norm_param, rmsnorm
from repro.parallel.constraints import BATCH, MODEL, constrain

LOSS_CHUNK = 128   # sequence chunk for the memory-bounded CE loss


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_attn_block(cfg: ModelConfig, key, dtype) -> Dict:
    ka, km = jax.random.split(key)
    hd = cfg.resolved_head_dim()
    return {
        "ln1": norm_param(cfg.norm, cfg.d_model, dtype),
        "attn": attn_lib.init_attention(ka, cfg.d_model, cfg.num_heads,
                                        cfg.num_kv_heads, hd, dtype),
        "ln2": norm_param(cfg.norm, cfg.d_model, dtype),
        "mlp": mlp_lib.init_mlp(km, cfg.d_model, cfg.d_ff, cfg.mlp, dtype),
    }


def _init_moe_block(cfg: ModelConfig, key, dtype) -> Dict:
    ka, km = jax.random.split(key)
    hd = cfg.resolved_head_dim()
    return {
        "ln1": norm_param(cfg.norm, cfg.d_model, dtype),
        "attn": attn_lib.init_attention(ka, cfg.d_model, cfg.num_heads,
                                        cfg.num_kv_heads, hd, dtype),
        "ln2": norm_param(cfg.norm, cfg.d_model, dtype),
        "moe": moe_lib.init_moe(km, cfg.d_model, cfg.d_ff, cfg.mlp, cfg.moe, dtype),
    }


def _init_ssm_block(cfg: ModelConfig, key, dtype) -> Dict:
    return {
        "ln1": norm_param(cfg.norm, cfg.d_model, dtype),
        "ssm": ssm_lib.init_ssm(key, cfg.d_model, cfg.ssm, dtype),
    }


def _init_cross_block(cfg: ModelConfig, key, dtype) -> Dict:
    hd = cfg.resolved_head_dim()
    return {
        "ln": norm_param(cfg.norm, cfg.d_model, dtype),
        "attn": attn_lib.init_attention(key, cfg.d_model, cfg.num_heads,
                                        cfg.num_kv_heads, hd, dtype),
        "gate": jnp.zeros((), jnp.float32),   # zero-init cross-attn gate
    }


def _stack_init(fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def group_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, layers_per_group, tail_layers) for group-scan archs."""
    if cfg.arch_type == "hybrid":
        every = cfg.attn_every
    elif cfg.arch_type == "vlm":
        every = cfg.vlm.cross_attn_every
    else:
        return (0, 0, cfg.num_layers)
    n = cfg.num_layers // every
    return (n, every, cfg.num_layers - n * every)


def num_shared_attn(cfg: ModelConfig) -> int:
    return group_layout(cfg)[0] if cfg.arch_type == "hybrid" else 0


def num_cross_layers(cfg: ModelConfig) -> int:
    return group_layout(cfg)[0] if cfg.arch_type == "vlm" else 0


def _split_groups(blocks, n: int, per: int):
    """Stacked (L, ...) params -> ((n, per, ...), tail (L-n*per, ...))."""
    grouped = jax.tree_util.tree_map(
        lambda a: a[:n * per].reshape((n, per) + a.shape[1:]), blocks)
    tailb = jax.tree_util.tree_map(lambda a: a[n * per:], blocks)
    return grouped, tailb


def _merge_groups(grouped, tailt):
    flat = jax.tree_util.tree_map(
        lambda a: a.reshape((-1,) + a.shape[2:]), grouped)
    return jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b], axis=0), flat, tailt)


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 8)
    params: Dict = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype=dtype),
        "final_norm": norm_param(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dtype=dtype)

    if cfg.arch_type in ("dense", "vlm", "audio"):
        block_fn = functools.partial(_init_attn_block, cfg, dtype=dtype)
    elif cfg.arch_type == "moe":
        block_fn = functools.partial(_init_moe_block, cfg, dtype=dtype)
    elif cfg.arch_type in ("ssm", "hybrid"):
        block_fn = functools.partial(_init_ssm_block, cfg, dtype=dtype)
    else:
        raise ValueError(cfg.arch_type)
    params["blocks"] = _stack_init(block_fn, ks[2], cfg.num_layers)

    if cfg.arch_type == "hybrid":
        # zamba2: ONE shared attention block applied every attn_every layers
        params["shared_attn"] = _init_attn_block(cfg, ks[3], dtype)

    if cfg.arch_type == "vlm":
        params["cross"] = _stack_init(
            functools.partial(_init_cross_block, cfg, dtype=dtype),
            ks[4], num_cross_layers(cfg))
        params["projector"] = dense_init(
            ks[5], (cfg.vlm.image_embed_dim, cfg.d_model), dtype=dtype)

    if cfg.arch_type == "audio":
        enc = cfg.encdec
        params["encoder"] = {
            "blocks": _stack_init(
                functools.partial(_init_attn_block, cfg, dtype=dtype),
                ks[6], enc.encoder_layers),
            "final_norm": norm_param(cfg.norm, cfg.d_model, dtype),
        }
        params["cross"] = _stack_init(
            functools.partial(_init_cross_block, cfg, dtype=dtype),
            ks[7], cfg.num_layers)
    return params


# ---------------------------------------------------------------------------
# Forward building blocks
# ---------------------------------------------------------------------------

def _self_attn(cfg: ModelConfig, block: Dict, x: jax.Array) -> jax.Array:
    h = apply_norm(cfg.norm, x, block["ln1"])
    h = attn_lib.attention_forward(
        block["attn"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        rope_theta=cfg.rope_theta, window=cfg.sliding_window)
    return x + h


def _mlp_res(cfg: ModelConfig, block: Dict, x: jax.Array) -> jax.Array:
    h = apply_norm(cfg.norm, x, block["ln2"])
    return x + mlp_lib.mlp_forward(block["mlp"], h, cfg.mlp)


def _dense_block(cfg: ModelConfig, block: Dict, x: jax.Array) -> jax.Array:
    return _mlp_res(cfg, block, _self_attn(cfg, block, x))


def _moe_block(cfg: ModelConfig, block: Dict, x: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    x = _self_attn(cfg, block, x)
    h = apply_norm(cfg.norm, x, block["ln2"])
    out, aux = moe_lib.moe_forward(block["moe"], h, cfg.mlp, cfg.moe)
    return x + out, aux


def _ssm_block(cfg: ModelConfig, block: Dict, x: jax.Array) -> jax.Array:
    h = apply_norm(cfg.norm, x, block["ln1"])
    return x + ssm_lib.ssm_forward(block["ssm"], h, cfg.ssm)


def _cross_block(cfg: ModelConfig, cblock: Dict, x: jax.Array,
                 kv_src: jax.Array) -> jax.Array:
    h = apply_norm(cfg.norm, x, cblock["ln"])
    h = attn_lib.attention_forward(
        cblock["attn"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        rope_theta=0.0, kv=kv_src, causal=False)
    gate = jnp.tanh(cblock["gate"]).astype(x.dtype) if "gate" in cblock else 1.0
    return x + gate * h


def _audio_block(cfg: ModelConfig, block: Dict, cross: Dict, x: jax.Array,
                 cross_src: jax.Array) -> jax.Array:
    h = apply_norm(cfg.norm, x, block["ln1"])
    h = attn_lib.attention_forward(
        block["attn"], h, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, rope_theta=cfg.rope_theta)
    x = x + h
    x = _cross_block(cfg, cross, x, cross_src)
    return _mlp_res(cfg, block, x)


def _encoder_forward(cfg: ModelConfig, params: Dict, frames: jax.Array) -> jax.Array:
    """Whisper-style bidirectional encoder over stub frame embeddings."""
    enc = params["encoder"]
    pos = jnp.arange(frames.shape[1])
    freqs = jnp.exp(-jnp.arange(0, cfg.d_model, 2) / cfg.d_model * 9.21)
    ang = pos[:, None] * freqs[None, :]
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[None]
    x = frames + pe.astype(frames.dtype)

    def body(x, block):
        h = apply_norm(cfg.norm, x, block["ln1"])
        h = attn_lib.attention_forward(
            block["attn"], h, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, rope_theta=0.0, causal=False)
        x = x + h
        return _mlp_res(cfg, block, x), None

    x, _ = jax.lax.scan(body, x, enc["blocks"])
    return apply_norm(cfg.norm, x, enc["final_norm"])


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------

def _remat_wrapper(remat, policy: str = "full"):
    if not remat:
        return lambda f: f
    if policy == "dots":
        return functools.partial(
            jax.checkpoint, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint


def _scan_blocks(cfg: ModelConfig, params: Dict, x: jax.Array,
                 cross_src: Optional[jax.Array], remat: bool = True,
                 remat_policy: str = "full"
                 ) -> Tuple[jax.Array, jax.Array]:
    """Run all layers; returns (hidden, aux_loss_sum)."""
    ckpt = _remat_wrapper(remat, remat_policy)
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.arch_type in ("dense", "ssm"):
        def layer(carry, block):
            x, aux = carry
            x = (_dense_block if cfg.arch_type == "dense" else _ssm_block)(
                cfg, block, x)
            return (x, aux), None
        (x, aux), _ = jax.lax.scan(ckpt(layer), (x, aux0), params["blocks"])
        return x, aux

    if cfg.arch_type == "moe":
        def layer(carry, block):
            x, aux = carry
            x, a = _moe_block(cfg, block, x)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(ckpt(layer), (x, aux0), params["blocks"])
        return x, aux

    if cfg.arch_type == "audio":
        def layer(carry, inp):
            x, aux = carry
            block, cross = inp
            x = _audio_block(cfg, block, cross, x, cross_src)
            return (x, aux), None
        (x, aux), _ = jax.lax.scan(ckpt(layer), (x, aux0),
                                   (params["blocks"], params["cross"]))
        return x, aux

    # group-scan archs
    n, per, tail = group_layout(cfg)
    grouped, tailb = _split_groups(params["blocks"], n, per)

    if cfg.arch_type == "hybrid":
        sa = params["shared_attn"]

        def group(carry, gblocks):
            x, aux = carry
            def inner(c, blk):
                return _ssm_block(cfg, blk, c), None
            x, _ = jax.lax.scan(inner, x, gblocks)
            x = _mlp_res(cfg, sa, _self_attn(cfg, sa, x))
            return (x, aux), None

        (x, aux), _ = jax.lax.scan(ckpt(group), (x, aux0), grouped)
        if tail:
            def tail_layer(carry, blk):
                x, aux = carry
                return (_ssm_block(cfg, blk, x), aux), None
            (x, aux), _ = jax.lax.scan(ckpt(tail_layer), (x, aux), tailb)
        return x, aux

    if cfg.arch_type == "vlm":
        def group(carry, inp):
            x, aux = carry
            gblocks, cross = inp
            def inner(c, blk):
                return _dense_block(cfg, blk, c), None
            x, _ = jax.lax.scan(inner, x, gblocks)
            x = _cross_block(cfg, cross, x, cross_src)
            return (x, aux), None

        (x, aux), _ = jax.lax.scan(ckpt(group), (x, aux0),
                                   (grouped, params["cross"]))
        assert tail == 0, "vlm layers must divide cross_attn_every"
        return x, aux

    raise ValueError(cfg.arch_type)


def _lm_head(cfg: ModelConfig, params: Dict) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


def chunked_cross_entropy(hidden: jax.Array, head: jax.Array,
                          labels: jax.Array, chunk: int = LOSS_CHUNK
                          ) -> Tuple[jax.Array, jax.Array]:
    """CE over sequence chunks so (B,S,V) logits are never materialized.

    labels < 0 are ignored.  Returns (sum_loss, token_count).
    """
    b, s, d = hidden.shape
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = hidden.shape[1] // chunk
    hc = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(acc, inp):
        loss_sum, count = acc
        h, l = inp
        logits = jnp.einsum("bsd,dv->bsv", h, head.astype(h.dtype),
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, BATCH, None, MODEL)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(l, 0)[..., None], axis=-1)[..., 0]
        mask = (l >= 0).astype(jnp.float32)
        loss_sum = loss_sum + jnp.sum((lse - ll) * mask)
        count = count + jnp.sum(mask)
        return (loss_sum, count), None

    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hc, lc))
    return loss_sum, count


def model_forward(params: Dict, batch: Dict, cfg: ModelConfig,
                  remat: bool = True,
                  remat_policy: str = "full") -> Tuple[jax.Array, Dict]:
    """Training forward.  batch: tokens (B,S), labels (B,S) and, per family,
    image_embeds (B,N,img_dim) [vlm] or encoder_frames (B,F,d_model) [audio].
    Returns (mean loss, metrics dict)."""
    tokens = batch["tokens"]
    compute_dtype = jnp.dtype(cfg.dtype)
    x = constrain(params["embed"].astype(compute_dtype)[tokens],
                  BATCH, None, None)

    cross_src = None
    if cfg.arch_type == "vlm":
        cross_src = jnp.einsum(
            "bnd,de->bne", batch["image_embeds"].astype(compute_dtype),
            params["projector"].astype(compute_dtype))
    elif cfg.arch_type == "audio":
        cross_src = _encoder_forward(
            cfg, params, batch["encoder_frames"].astype(compute_dtype))

    x, aux = _scan_blocks(cfg, params, x, cross_src, remat=remat,
                          remat_policy=remat_policy)
    x = apply_norm(cfg.norm, x, params["final_norm"])
    loss_sum, count = chunked_cross_entropy(x, _lm_head(cfg, params),
                                            batch["labels"])
    ce = loss_sum / jnp.maximum(count, 1.0)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux, "tokens": count}


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------

def cache_length(cfg: ModelConfig, seq_len: int) -> int:
    """KV-cache length: ring buffer of `window` for SWA models, else seq_len."""
    if cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_decode_state(cfg: ModelConfig, batch: int, seq_len: int,
                      dtype=jnp.bfloat16) -> Dict:
    hd = cfg.resolved_head_dim() if cfg.num_heads else 0
    state: Dict = {"pos": jnp.zeros((), jnp.int32)}
    clen = cache_length(cfg, seq_len)

    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        state["kv"] = {
            "k": jnp.zeros((cfg.num_layers, batch, clen, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((cfg.num_layers, batch, clen, cfg.num_kv_heads, hd), dtype),
        }
    if cfg.arch_type in ("ssm", "hybrid"):
        per = ssm_lib.init_ssm_state(batch, cfg.d_model, cfg.ssm)
        state["ssm"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), per)
    if cfg.arch_type == "hybrid":
        n = num_shared_attn(cfg)
        state["kv"] = {
            "k": jnp.zeros((n, batch, clen, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((n, batch, clen, cfg.num_kv_heads, hd), dtype),
        }
    if cfg.arch_type == "vlm":
        n = num_cross_layers(cfg)
        state["cross_kv"] = {
            "k": jnp.zeros((n, batch, cfg.vlm.num_image_tokens,
                            cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((n, batch, cfg.vlm.num_image_tokens,
                            cfg.num_kv_heads, hd), dtype),
        }
    if cfg.arch_type == "audio":
        state["cross_kv"] = {
            "k": jnp.zeros((cfg.num_layers, batch, cfg.encdec.encoder_seq,
                            cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((cfg.num_layers, batch, cfg.encdec.encoder_seq,
                            cfg.num_kv_heads, hd), dtype),
        }
    return state


def decode_step_fn(params: Dict, state: Dict, token: jax.Array,
                   cfg: ModelConfig) -> Tuple[jax.Array, Dict]:
    """One decode step.  token: (B,) int32.  Returns (logits (B,V), state)."""
    compute_dtype = jnp.dtype(cfg.dtype)
    pos = state["pos"]
    x = params["embed"].astype(compute_dtype)[token][:, None]  # (B,1,d)
    new_state = dict(state)
    window = cfg.sliding_window

    def attn_decode(block, x, cache):
        h = apply_norm(cfg.norm, x, block["ln1"])
        h, cache = attn_lib.decode_attention(
            block["attn"], h, cache, pos, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, rope_theta=cfg.rope_theta,
            window=window)
        return x + h, cache

    def cross_decode(cblock, ckv, x):
        h = apply_norm(cfg.norm, x, cblock["ln"])
        h = attn_lib.decode_cross_attention(
            cblock["attn"], h,
            jax.tree_util.tree_map(lambda a: a.astype(compute_dtype), ckv),
            num_heads=cfg.num_heads)
        gate = jnp.tanh(cblock["gate"]).astype(x.dtype)
        return x + gate * h

    if cfg.arch_type in ("dense", "moe", "audio"):
        def layer(carry, inp):
            x = carry
            if cfg.arch_type == "audio":
                block, cache, cross, ckv = inp
            else:
                block, cache = inp
            x, cache = attn_decode(block, x, cache)
            if cfg.arch_type == "moe":
                h = apply_norm(cfg.norm, x, block["ln2"])
                out, _ = moe_lib.moe_forward(block["moe"], h, cfg.mlp, cfg.moe)
                x = x + out
            elif cfg.arch_type == "audio":
                x = cross_decode(cross, ckv, x)
                x = _mlp_res(cfg, block, x)
            else:
                x = _mlp_res(cfg, block, x)
            return x, cache

        xs = (params["blocks"], state["kv"])
        if cfg.arch_type == "audio":
            xs = (params["blocks"], state["kv"], params["cross"],
                  state["cross_kv"])
        x, new_kv = jax.lax.scan(layer, x, xs)
        new_state["kv"] = new_kv

    elif cfg.arch_type == "vlm":
        n, per, _ = group_layout(cfg)
        grouped_blocks, _ = _split_groups(params["blocks"], n, per)
        grouped_kv = jax.tree_util.tree_map(
            lambda a: a.reshape((n, per) + a.shape[1:]), state["kv"])

        def group(x, inp):
            gblocks, gkv, cross, ckv = inp
            def inner(c, blk_kv):
                blk, cache = blk_kv
                c, cache = attn_decode(blk, c, cache)
                return _mlp_res(cfg, blk, c), cache
            x, new_gkv = jax.lax.scan(inner, x, (gblocks, gkv))
            x = cross_decode(cross, ckv, x)
            return x, new_gkv

        x, new_gkv = jax.lax.scan(
            group, x, (grouped_blocks, grouped_kv, params["cross"],
                       state["cross_kv"]))
        new_state["kv"] = jax.tree_util.tree_map(
            lambda a: a.reshape((n * per,) + a.shape[2:]), new_gkv)

    elif cfg.arch_type == "ssm":
        def layer(x, inp):
            block, sstate = inp
            h = apply_norm(cfg.norm, x, block["ln1"])
            h, sstate = ssm_lib.ssm_decode_step(block["ssm"], h, sstate, cfg.ssm)
            return x + h, sstate
        x, new_ssm = jax.lax.scan(layer, x, (params["blocks"], state["ssm"]))
        new_state["ssm"] = new_ssm

    elif cfg.arch_type == "hybrid":
        n, per, tail = group_layout(cfg)
        grouped_blocks, tailb = _split_groups(params["blocks"], n, per)
        grouped_ssm = jax.tree_util.tree_map(
            lambda a: a[:n * per].reshape((n, per) + a.shape[1:]), state["ssm"])
        tail_ssm = jax.tree_util.tree_map(lambda a: a[n * per:], state["ssm"])
        sa = params["shared_attn"]

        def ssm_layer(x, inp):
            block, sstate = inp
            h = apply_norm(cfg.norm, x, block["ln1"])
            h, sstate = ssm_lib.ssm_decode_step(block["ssm"], h, sstate, cfg.ssm)
            return x + h, sstate

        def group(x, inp):
            gblocks, gssm, cache = inp
            x, new_gssm = jax.lax.scan(ssm_layer, x, (gblocks, gssm))
            x, cache = attn_decode(sa, x, cache)
            x = _mlp_res(cfg, sa, x)
            return x, (new_gssm, cache)

        x, (new_gssm, new_kv) = jax.lax.scan(
            group, x, (grouped_blocks, grouped_ssm, state["kv"]))
        if tail:
            x, new_tail = jax.lax.scan(ssm_layer, x, (tailb, tail_ssm))
        else:
            new_tail = tail_ssm
        new_state["ssm"] = _merge_groups(new_gssm, new_tail)
        new_state["kv"] = new_kv
    else:
        raise ValueError(cfg.arch_type)

    x = apply_norm(cfg.norm, x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x,
                        _lm_head(cfg, params).astype(compute_dtype),
                        preferred_element_type=jnp.float32)[:, 0]
    new_state["pos"] = pos + 1
    return logits, new_state


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def _fill_cache(cfg: ModelConfig, block: Dict, h: jax.Array, s: int,
                clen: int, dtype):
    """Compute k/v for all positions; keep the last `clen` in ring layout."""
    k = jnp.einsum("bsd,dhk->bshk", h, block["attn"]["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", h, block["attn"]["wv"].astype(h.dtype))
    pos_ids = jnp.arange(s)[None, :]
    if cfg.rope_theta > 0:
        k = attn_lib.apply_rope(k, pos_ids, cfg.rope_theta)
    if cfg.sliding_window and s > clen:
        # ring layout: position p lives at slot p % clen; after slicing the
        # last clen positions (s-clen .. s-1), original index i holds
        # position s-clen+i, whose slot is (i + s) % clen -> roll by s%clen.
        k, v = k[:, -clen:], v[:, -clen:]
        roll = s % clen
        k = jnp.roll(k, roll, axis=1)
        v = jnp.roll(v, roll, axis=1)
    elif s < clen:
        padw = clen - s
        k = jnp.pad(k, ((0, 0), (0, padw), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, padw), (0, 0), (0, 0)))
    return k.astype(dtype), v.astype(dtype)


def _ssm_prefill_layer(cfg: ModelConfig, block: Dict, x: jax.Array):
    """One Mamba2 layer over the full prompt, returning its decode state."""
    b, s, _ = x.shape
    sp = cfg.ssm
    d_in = sp.expand * cfg.d_model
    nheads = d_in // sp.head_dim
    nst = sp.state_dim
    h = apply_norm(cfg.norm, x, block["ln1"])
    proj = jnp.einsum("bld,de->ble", h, block["ssm"]["in_proj"].astype(h.dtype))
    z = proj[..., :d_in]
    xbc = proj[..., d_in:2 * d_in + 2 * nst]
    dt = proj[..., 2 * d_in + 2 * nst:]
    w = block["ssm"]["conv_w"].astype(h.dtype)
    padn = sp.conv_width - 1
    xp = jnp.pad(xbc, ((0, 0), (padn, 0), (0, 0)))
    conv = sum(xp[:, j:j + s] * w[j] for j in range(sp.conv_width))
    conv = jax.nn.silu(conv + block["ssm"]["conv_b"].astype(h.dtype))
    xs = conv[..., :d_in].reshape(b, s, nheads, sp.head_dim)
    bmat = conv[..., d_in:d_in + nst]
    cmat = conv[..., d_in + nst:]
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + block["ssm"]["dt_bias"])
    a = -jnp.exp(block["ssm"]["A_log"])
    y, fstate = ssm_lib.ssd_chunked(xs, dtp, a, bmat, cmat, sp.chunk_size)
    y = y + block["ssm"]["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, d_in).astype(h.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, block["ssm"]["norm_scale"])
    x = x + jnp.einsum("ble,ed->bld", y, block["ssm"]["out_proj"].astype(h.dtype))
    sstate = {"conv": xp[:, s:], "ssm": fstate}
    return x, sstate


def prefill_fn(params: Dict, batch: Dict, cfg: ModelConfig,
               remat: bool = True,
               cache_len: Optional[int] = None) -> Tuple[jax.Array, Dict]:
    """Process a full prompt; returns (last-token logits (B,V), decode state).

    Caches are filled for subsequent ``decode_step_fn`` calls.  ``cache_len``
    sizes the decode cache (>= prompt length) so generation has headroom;
    default = prompt length (the dry-run convention where decode positions
    stay within seq_len).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    target_len = cache_len if cache_len is not None else s
    assert target_len >= s, (target_len, s)
    compute_dtype = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(compute_dtype)[tokens]
    clen = cache_length(cfg, target_len)
    ckpt = jax.checkpoint if remat else (lambda f: f)

    cross_src = None
    if cfg.arch_type == "vlm":
        cross_src = jnp.einsum(
            "bnd,de->bne", batch["image_embeds"].astype(compute_dtype),
            params["projector"].astype(compute_dtype))
    elif cfg.arch_type == "audio":
        cross_src = _encoder_forward(
            cfg, params, batch["encoder_frames"].astype(compute_dtype))

    state = init_decode_state(cfg, b, target_len, dtype=compute_dtype)
    state["pos"] = jnp.asarray(s, jnp.int32)

    fill = functools.partial(_fill_cache, cfg, s=s, clen=clen,
                             dtype=compute_dtype)

    def cross_kv_of(c):
        return attn_lib.init_cross_cache(c["attn"], cross_src,
                                         num_kv_heads=cfg.num_kv_heads)

    if cfg.arch_type in ("dense", "moe", "audio"):
        def layer(x, inp):
            block = inp[0] if isinstance(inp, tuple) else inp
            hn = apply_norm(cfg.norm, x, block["ln1"])
            kc, vc = fill(block, hn)
            if cfg.arch_type == "moe":
                x, _ = _moe_block(cfg, block, x)
            elif cfg.arch_type == "audio":
                x = _audio_block(cfg, block, inp[1], x, cross_src)
            else:
                x = _dense_block(cfg, block, x)
            return x, {"k": kc, "v": vc}

        xs = params["blocks"] if cfg.arch_type != "audio" \
            else (params["blocks"], params["cross"])
        x, kv = jax.lax.scan(ckpt(layer), x, xs)
        state["kv"] = kv
        if cfg.arch_type == "audio":
            ck = jax.vmap(cross_kv_of)(params["cross"])
            state["cross_kv"] = jax.tree_util.tree_map(
                lambda a: a.astype(compute_dtype), ck)

    elif cfg.arch_type == "vlm":
        n, per, _ = group_layout(cfg)
        grouped_blocks, _ = _split_groups(params["blocks"], n, per)

        def group(x, inp):
            gblocks, cross = inp
            def inner(c, blk):
                hn = apply_norm(cfg.norm, c, blk["ln1"])
                kc, vc = fill(blk, hn)
                return _dense_block(cfg, blk, c), {"k": kc, "v": vc}
            x, gkv = jax.lax.scan(inner, x, gblocks)
            x = _cross_block(cfg, cross, x, cross_src)
            return x, gkv

        x, gkv = jax.lax.scan(ckpt(group), x, (grouped_blocks, params["cross"]))
        state["kv"] = jax.tree_util.tree_map(
            lambda a: a.reshape((n * per,) + a.shape[2:]), gkv)
        ck = jax.vmap(cross_kv_of)(params["cross"])
        state["cross_kv"] = jax.tree_util.tree_map(
            lambda a: a.astype(compute_dtype), ck)

    elif cfg.arch_type == "ssm":
        def layer(x, block):
            return _ssm_prefill_layer(cfg, block, x)
        x, sstates = jax.lax.scan(ckpt(layer), x, params["blocks"])
        state["ssm"] = sstates

    elif cfg.arch_type == "hybrid":
        n, per, tail = group_layout(cfg)
        grouped_blocks, tailb = _split_groups(params["blocks"], n, per)
        sa = params["shared_attn"]

        def group(x, gblocks):
            def inner(c, blk):
                return _ssm_prefill_layer(cfg, blk, c)
            x, gssm = jax.lax.scan(inner, x, gblocks)
            hn = apply_norm(cfg.norm, x, sa["ln1"])
            kc, vc = fill(sa, hn)
            x = _mlp_res(cfg, sa, _self_attn(cfg, sa, x))
            return x, (gssm, {"k": kc, "v": vc})

        x, (gssm, kv) = jax.lax.scan(ckpt(group), x, grouped_blocks)
        if tail:
            def tail_layer(c, blk):
                return _ssm_prefill_layer(cfg, blk, c)
            x, tssm = jax.lax.scan(ckpt(tail_layer), x, tailb)
        else:
            tssm = jax.tree_util.tree_map(
                lambda a: a[:0], jax.tree_util.tree_map(
                    lambda a: a.reshape((-1,) + a.shape[2:]), gssm))
        state["ssm"] = _merge_groups(gssm, tssm)
        state["kv"] = kv
    else:
        raise ValueError(cfg.arch_type)

    x = apply_norm(cfg.norm, x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1],
                        _lm_head(cfg, params).astype(compute_dtype),
                        preferred_element_type=jnp.float32)
    return logits, state


def loss_fn(params: Dict, batch: Dict, cfg: ModelConfig,
            remat: bool = True) -> jax.Array:
    return model_forward(params, batch, cfg, remat=remat)[0]

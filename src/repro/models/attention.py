"""Attention: GQA, sliding-window, flash-style chunked softmax, KV-cache decode.

Training/prefill attention is a pure-JAX blockwise (flash-style) online
softmax: O(block^2) live memory instead of O(seq^2), which is what lets the
32k-prefill and 4k-train shapes fit per-device HBM at compile time.  The
Pallas kernel in ``repro.kernels.swa_attention`` implements the same
computation for the TPU hot path; this module is also its oracle's basis.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init
from repro.parallel.constraints import BATCH, MODEL, constrain

NEG_INF = -1e30


def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, dtype=jnp.float32) -> Dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d_model, num_heads, head_dim), dtype=dtype),
        "wk": dense_init(kk, (d_model, num_kv_heads, head_dim), dtype=dtype),
        "wv": dense_init(kv, (d_model, num_kv_heads, head_dim), dtype=dtype),
        "wo": dense_init(ko, (num_heads, head_dim, d_model),
                         scale=0.02 / math.sqrt(2.0), dtype=dtype),
    }


def _repeat_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B, S, KVH, D) -> (B, S, H, D) by repeating kv heads (GQA)."""
    kvh = k.shape[-2]
    if kvh == num_heads:
        return k
    rep = num_heads // kvh
    return jnp.repeat(k, rep, axis=-2)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True, window: int = 0,
                        q_offset: int = 0, block_q: int = 512,
                        block_kv: int = 512) -> jax.Array:
    """Flash-style online-softmax attention.

    q: (B, Sq, H, D); k, v: (B, Skv, H, D) (kv already head-repeated).
    window: 0 = full; >0 = sliding window (query i attends to keys in
    (i - window, i]).  q_offset: absolute position of q[0] relative to k[0]
    (for cross/prefill-continuation use).
    Returns (B, Sq, H, D).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(d)

    # few-head models (heads % model-axis != 0) fall back to sequence-
    # parallel attention over query blocks; pick block_q so the number of
    # q blocks matches the model axis exactly (whisper 8H, granite-moe 24H)
    from repro.parallel.constraints import current_mesh
    _mesh = current_mesh()
    _msize = dict(zip(_mesh.axis_names, _mesh.devices.shape)).get("model", 1) \
        if _mesh is not None else 1
    # (only for LONG sequences: under AD/remat the scan-over-sharded-blocks
    # re-gathers — measured a net loss at train_4k, a 52x win at 32k prefill)
    if _msize > 1 and h % _msize != 0 and h < _msize:
        nq0 = -(-sq // block_q)
        if nq0 % _msize != 0 and sq % _msize == 0 and sq // _msize >= 1024:
            block_q = sq // _msize

    # pad to block multiples
    pq = (-sq) % block_q
    pkv = (-skv) % block_kv
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    nq, nkv = qp.shape[1] // block_q, kp.shape[1] // block_kv

    # (nq, B, H, bq, D) etc. — pin batch/head sharding through the reshapes
    qb = qp.reshape(b, nq, block_q, h, d).transpose(1, 0, 3, 2, 4) * scale
    kb = kp.reshape(b, nkv, block_kv, h, d).transpose(1, 0, 3, 2, 4)
    vb = vp.reshape(b, nkv, block_kv, h, d).transpose(1, 0, 3, 2, 4)
    from repro.parallel.constraints import current_mesh
    mesh = current_mesh()
    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1) \
        if mesh is not None else 1
    if msize > 1 and h % msize != 0 and nq % msize == 0:
        # few-head models (whisper: 8 heads < 16 shards): sequence-parallel
        # attention — shard QUERY BLOCKS over "model"; each shard scans the
        # full kv for its query blocks.
        qb = constrain(qb, MODEL, BATCH, None, None, None)
        kb = constrain(kb, None, BATCH, None, None, None)
        vb = constrain(vb, None, BATCH, None, None, None)
    else:
        qb = constrain(qb, None, BATCH, MODEL, None, None)
        kb = constrain(kb, None, BATCH, MODEL, None, None)
        vb = constrain(vb, None, BATCH, MODEL, None, None)

    q_pos = jnp.arange(nq * block_q).reshape(nq, block_q) + q_offset
    kv_pos = jnp.arange(nkv * block_kv).reshape(nkv, block_kv)
    kv_valid = kv_pos < skv

    def q_block(carry, xs):
        qi, qpos = xs  # (B,H,bq,D), (bq,)

        def kv_block(acc, ys):
            m, l, o = acc
            ki, vi, kpos, kval = ys
            s = jnp.einsum("bhqd,bhkd->bhqk", qi, ki,
                           preferred_element_type=jnp.float32)
            # additive (bq, bkv) bias instead of a full (b,h,bq,bkv) select:
            # one broadcastable small operand instead of score-sized pred +
            # two score-sized select operands (memory-roofline lever)
            mask = kval[None, :]
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            bias = jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)
            s = s + bias[None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, o_new), None

        init = (constrain(jnp.full((b, h, block_q), NEG_INF, jnp.float32),
                          BATCH, MODEL, None),
                constrain(jnp.zeros((b, h, block_q), jnp.float32),
                          BATCH, MODEL, None),
                constrain(jnp.zeros((b, h, block_q, d), jnp.float32),
                          BATCH, MODEL, None, None))
        (m, l, o), _ = jax.lax.scan(kv_block, init, (kb, vb, kv_pos, kv_valid))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return carry, o.astype(q.dtype)

    _, ob = jax.lax.scan(q_block, None, (qb, q_pos))
    out = ob.transpose(1, 0, 3, 2, 4).reshape(b, nq * block_q, h, d)
    return out[:, :sq]


def attention_forward(params: Dict, x: jax.Array, *, num_heads: int,
                      num_kv_heads: int, rope_theta: float, window: int = 0,
                      positions: Optional[jax.Array] = None,
                      kv: Optional[jax.Array] = None,
                      causal: bool = True) -> jax.Array:
    """Full attention layer (projections + blockwise core).

    kv: optional cross-attention source (B, Skv, d_model); None = self-attn.
    """
    b, s, _ = x.shape
    src = x if kv is None else kv
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"].astype(x.dtype))
    q = constrain(q, BATCH, None, MODEL, None)
    if kv is None and rope_theta > 0:
        if positions is None:
            positions = jnp.arange(s)[None, :]
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    k = constrain(_repeat_kv(k, num_heads), BATCH, None, MODEL, None)
    v = constrain(_repeat_kv(v, num_heads), BATCH, None, MODEL, None)
    # heads not divisible by the model axis (granite-moe: 24H on 16 shards)
    # replicate attention 16x; pad with zero heads to the next multiple —
    # exact (zero v => zero output; sliced off below) and fully sharded
    from repro.parallel.constraints import current_mesh as _cm
    _mesh = _cm()
    _msz = dict(zip(_mesh.axis_names, _mesh.devices.shape)).get("model", 1) \
        if _mesh is not None else 1
    nh = q.shape[2]
    hpad = ((-nh) % _msz) if (_msz > 1 and nh >= _msz) else 0
    if hpad:
        padh = ((0, 0), (0, 0), (0, hpad), (0, 0))
        q = constrain(jnp.pad(q, padh), BATCH, None, MODEL, None)
        k = constrain(jnp.pad(k, padh), BATCH, None, MODEL, None)
        v = constrain(jnp.pad(v, padh), BATCH, None, MODEL, None)
    o = blockwise_attention(q, k, v, causal=causal and kv is None, window=window)
    if hpad:
        o = o[:, :, :nh]
    o = constrain(o, BATCH, None, MODEL, None)
    return constrain(
        jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype)),
        BATCH, None, None)


# ---------------------------------------------------------------------------
# Decode path (KV cache)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, cache_len: int, num_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> Dict:
    return {
        "k": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype),
    }


def decode_attention(params: Dict, x: jax.Array, cache: Dict, pos: jax.Array,
                     *, num_heads: int, num_kv_heads: int, rope_theta: float,
                     window: int = 0) -> Tuple[jax.Array, Dict]:
    """One-token decode: x (B, 1, d_model), cache holds cache_len positions.

    For sliding-window models the cache is a ring buffer of size window;
    ``pos`` is the absolute position of the new token.
    Returns (out (B,1,d_model), updated cache).
    """
    b, one, _ = x.shape
    assert one == 1
    cache_len = cache["k"].shape[1]
    # decode sharding scheme: batch over data, CACHE LENGTH over model
    # (GQA kv heads are too few to shard 16-way); heads stay replicated and
    # the softmax reduces over model-sharded cache segments.
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype)),
                  BATCH, None, None, None)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if rope_theta > 0:
        p = jnp.full((b, 1), pos)
        q = apply_rope(q, p, rope_theta)
        k = apply_rope(k, p, rope_theta)

    slot = (pos % cache_len) if window else jnp.minimum(pos, cache_len - 1)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))

    kk = constrain(_repeat_kv(ck.astype(x.dtype), num_heads),
                   BATCH, MODEL, None, None)
    vv = constrain(_repeat_kv(cv.astype(x.dtype), num_heads),
                   BATCH, MODEL, None, None)
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bshk,bthk->bhst", q * scale, kk,
                   preferred_element_type=jnp.float32)  # (B,H,1,cache)
    s = constrain(s, BATCH, None, None, MODEL)
    idx = jnp.arange(cache_len)
    if window:
        # ring buffer: valid slots are those written within the last `window`
        # absolute positions <= pos.
        age = (slot - idx) % cache_len
        valid = (age < jnp.minimum(pos + 1, cache_len))
    else:
        valid = idx <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhst,bthk->bshk", p, vv)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return out, {"k": ck, "v": cv}


def init_cross_cache(params: Dict, kv_src: jax.Array, *, num_kv_heads: int) -> Dict:
    """Precompute cross-attention K/V from encoder/vision embeddings."""
    k = jnp.einsum("bsd,dhk->bshk", kv_src, params["wk"].astype(kv_src.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, params["wv"].astype(kv_src.dtype))
    return {"k": k, "v": v}


def decode_cross_attention(params: Dict, x: jax.Array, cross: Dict,
                           *, num_heads: int) -> jax.Array:
    """Cross-attn for decode: full (non-causal) attention over cached cross K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    kk = _repeat_kv(cross["k"].astype(x.dtype), num_heads)
    vv = _repeat_kv(cross["v"].astype(x.dtype), num_heads)
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bshk,bthk->bhst", q * scale, kk,
                   preferred_element_type=jnp.float32)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhst,bthk->bshk", p, vv)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes and extract the roofline terms.

Usage:
    python -m repro.launch.dryrun --arch yi-9b --shape train_4k \
        --mesh single --splice 1 --out results/yi-9b.train_4k.single.json

The XLA_FLAGS line above MUST run before any jax import: jax locks the
device count at first init.  Smoke tests and benchmarks do NOT import this
module, so they see the 1 real CPU device.
"""

import argparse
import json
import time
from typing import Dict, Optional

import jax

from repro.analysis.hlo import op_histogram
from repro.analysis.hlo_cost import analyze_hlo
from repro.analysis.roofline import build_report
from repro.configs.base import TrainConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (decode_specs, input_specs, plan_pair,
                                state_specs)
from repro.models import decode_step_fn, prefill_fn
from repro.parallel.sharding import (batch_specs, decode_state_specs,
                                     param_specs, to_shardings)
from repro.training.step import build_train_step


def _cost_dict(compiled) -> Dict:
    try:
        cost = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}


def _memory_stats(compiled) -> Optional[Dict]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    out = {}
    for name in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if hasattr(ma, name):
            out[name] = int(getattr(ma, name))
    out["bytes_per_device"] = (out.get("argument_size_in_bytes", 0)
                               + out.get("output_size_in_bytes", 0)
                               + out.get("temp_size_in_bytes", 0)
                               - out.get("alias_size_in_bytes", 0))
    return out


def lower_pair(arch: str, shape_name: str, multi_pod: bool,
               splice: int = 1, remat: bool = True, donate: bool = False,
               remat_policy: str = "full", shard_profile: str = "default",
               moe_capacity_factor: Optional[float] = None,
               fused_gate: bool = False,
               mesh_override: Optional[tuple] = None,
               extra_tags: Optional[Dict] = None) -> Dict:
    """Lower + compile one pair on one mesh; returns the result record."""
    import dataclasses as _dc

    from repro.models import moe as _moe
    from repro.parallel import constraints as _constraints

    plan = plan_pair(arch, shape_name)
    mesh_name = "multi" if multi_pod else "single"
    if plan.skip_reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": plan.skip_reason}
    cfg, shape = plan.cfg, plan.shape
    if moe_capacity_factor is not None and cfg.moe is not None:
        cfg = _dc.replace(cfg, moe=_dc.replace(
            cfg.moe, capacity_factor=moe_capacity_factor))
    _moe.FUSED_GATE = fused_gate
    _constraints.DISABLE_MODEL_CONSTRAINTS = (shard_profile == "replicate_model")
    if mesh_override is not None:
        mesh = jax.make_mesh(mesh_override, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    tcfg = TrainConfig(remat=remat, remat_policy=remat_policy)
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            state = state_specs(cfg, tcfg)
            batch = input_specs(cfg, shape)
            st_sh = to_shardings(param_specs(state, mesh, shard_profile), mesh)
            b_sh = to_shardings(batch_specs(batch, mesh), mesh)
            step = build_train_step(cfg, tcfg, splice=splice)
            kw = {"donate_argnums": (0,)} if donate else {}
            lowered = jax.jit(
                step, in_shardings=(st_sh, b_sh),
                out_shardings=(st_sh, None), **kw).lower(state, batch)
        elif shape.kind == "prefill":
            state = state_specs(cfg, tcfg)
            params = state["params"]
            batch = input_specs(cfg, shape)
            p_sh = to_shardings(param_specs(params, mesh, shard_profile), mesh)
            b_sh = to_shardings(batch_specs(batch, mesh), mesh)
            dstate = decode_specs(cfg, shape)
            d_sh = to_shardings(
                decode_state_specs(dstate, mesh, shape.global_batch,
                                   shard_profile), mesh)
            def fn(p, b):
                return prefill_fn(p, b, cfg, remat=remat)
            lowered = jax.jit(
                fn, in_shardings=(p_sh, b_sh),
                out_shardings=(None, d_sh)).lower(params, batch)
        else:  # decode
            state = state_specs(cfg, tcfg)
            params = state["params"]
            p_sh = to_shardings(param_specs(params, mesh, shard_profile), mesh)
            dstate = decode_specs(cfg, shape)
            d_sh = to_shardings(
                decode_state_specs(dstate, mesh, shape.global_batch,
                                   shard_profile), mesh)
            tok = input_specs(cfg, shape)["token"]
            t_sh = to_shardings(batch_specs({"t": tok}, mesh), mesh)["t"]
            def fn(p, s, t):
                return decode_step_fn(p, s, t, cfg)
            kw = {"donate_argnums": (1,)} if donate else {}
            lowered = jax.jit(
                fn, in_shardings=(p_sh, d_sh, t_sh),
                out_shardings=(None, d_sh), **kw).lower(params, dstate, tok)

        lower_s = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t1

    _moe.FUSED_GATE = False
    _constraints.DISABLE_MODEL_CONSTRAINTS = False
    cost = _cost_dict(compiled)
    mem = _memory_stats(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    hc = analyze_hlo(hlo)
    report = build_report(arch, shape, mesh_name, chips, cost, hlo, cfg, mem,
                          hlo_cost=hc)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "chips": chips, "splice": splice,
        "swa_variant": plan.swa_variant,
        "lower_seconds": round(lower_s, 2),
        "compile_seconds": round(compile_s, 2),
        "memory": mem,
        "xla_cost_analysis": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))},
        "hlo_cost": hc.as_dict(),
        "roofline": report.row(),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "hlo_ops": {k: v for k, v in sorted(
            op_histogram(hlo).items(), key=lambda kv: -kv[1])[:25]},
    }
    if extra_tags:
        rec.update(extra_tags)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--splice", type=int, default=1)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--donate", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    rec = lower_pair(args.arch, args.shape, multi_pod=(args.mesh == "multi"),
                     splice=args.splice, remat=not args.no_remat,
                     donate=args.donate)
    text = json.dumps(rec, indent=2, default=str)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text)
    if rec.get("status") == "ok":
        print(f"{args.arch} x {args.shape} [{args.mesh}] OK "
              f"chips={rec['chips']} "
              f"compile={rec['compile_seconds']}s "
              f"dominant={rec['roofline']['dominant']}")
        if rec.get("memory"):
            print("memory_analysis:", rec["memory"])
        print("hlo_cost:", {k: f"{v:.3e}" for k, v in
                            rec["hlo_cost"].items()
                            if isinstance(v, float)})
        print("roofline:", {k: (f"{v:.4g}" if isinstance(v, float) else v)
                            for k, v in rec["roofline"].items()
                            if k in ("compute_s", "memory_s", "collective_s",
                                     "dominant", "useful_flop_ratio")})
    else:
        print(f"{args.arch} x {args.shape} [{args.mesh}] SKIPPED: "
              f"{rec['reason']}")
    if not args.out:
        print(text)


if __name__ == "__main__":
    main()

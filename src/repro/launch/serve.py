"""Batched serving driver (CPU-runnable).

Serves a reduced-config model: prefill a batch of prompts, then decode with
the KV/SSM caches — the serve-side workload the scheduler preempts training
jobs for (§1.1 b).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
        --batch 4 --prompt-len 32 --decode-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    engine = ServingEngine(cfg, seed=0)
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)
    t0 = time.time()
    out = engine.generate(prompts, max_new_tokens=args.decode_tokens,
                          temperature=args.temperature)
    wall = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"decode={args.decode_tokens}")
    print("generated token ids (first row):", out[0].tolist())
    print(f"wall {wall:.2f}s  prefill+decode compiled and ran on "
          f"{jax.device_count()} device(s)")


if __name__ == "__main__":
    main()

"""Serving driver: replica-group planning plus a CPU-runnable smoke decode.

Two stages, matching how the scheduler treats a latency-SLO service
(docs/serving.md):

1. **Plan** — derive the replica operating point for the *full* model
   config analytically (``ReplicaProfile.from_config``: memory-fit TP
   degree, decode-roofline batch search against the p99 SLO) and print
   the qps -> replicas curve the scheduler's autoscaler walks.  Pure
   numpy; runs anywhere.
2. **Smoke** — unless ``--plan-only``, generate through the real
   ``ServingEngine`` (prefill + KV/SSM-cache decode) on the reduced smoke
   config so the decode path itself is exercised on CPU.  ``--full`` runs
   the engine on the full config instead (accelerator-sized).

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b \\
        --slo-ms 30 --qps 500
    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \\
        --batch 4 --prompt-len 32 --decode-tokens 16
"""

from __future__ import annotations

import argparse
import time

from repro.configs import get_config, get_smoke_config
from repro.serving.engine import ReplicaProfile


def plan(args) -> None:
    cfg = get_config(args.arch)
    try:
        prof = ReplicaProfile.from_config(
            cfg,
            slo_ms=args.slo_ms,
            tokens_per_request=args.tokens_per_request,
        )
    except ValueError as e:
        print(f"plan: {args.arch} cannot meet p99 <= {args.slo_ms}ms: {e}")
        return
    print(
        f"plan[{cfg.name}]: slo={args.slo_ms}ms -> "
        f"{prof.gpus_per_replica} GPU(s)/replica, batch={prof.batch}, "
        f"p99 decode={prof.p99_decode_seconds * 1e3:.1f}ms, "
        f"{prof.tokens_per_second:.0f} tok/s, "
        f"{prof.qps_per_replica:.1f} qps/replica "
        f"({prof.weight_bytes / 2**30:.1f} GiB weights)"
    )
    for qps in (args.qps * f for f in (0.25, 0.5, 1.0, 1.5, 2.0)):
        n = prof.replicas_for(qps, utilization=args.target_utilization)
        print(
            f"  {qps:10.1f} qps -> {n:4d} replicas "
            f"({n * prof.gpus_per_replica} GPUs at "
            f"rho={args.target_utilization})"
        )


def smoke(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.serving.engine import ServingEngine

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    engine = ServingEngine(cfg, seed=0)
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32
    )
    t0 = time.time()
    out = engine.generate(
        prompts,
        max_new_tokens=args.decode_tokens,
        temperature=args.temperature,
    )
    wall = time.time() - t0
    print(
        f"smoke[{cfg.name}]: batch={args.batch} prompt={args.prompt_len} "
        f"decode={args.decode_tokens}"
    )
    print("generated token ids (first row):", out[0].tolist())
    print(
        f"wall {wall:.2f}s  prefill+decode compiled and ran on "
        f"{jax.device_count()} device(s)"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--slo-ms", type=float, default=50.0)
    ap.add_argument("--tokens-per-request", type=int, default=128)
    ap.add_argument("--qps", type=float, default=1000.0)
    ap.add_argument("--target-utilization", type=float, default=0.75)
    ap.add_argument(
        "--plan-only",
        action="store_true",
        help="print the replica plan and skip the engine smoke decode",
    )
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    plan(args)
    if not args.plan_only:
        smoke(args)


if __name__ == "__main__":
    main()

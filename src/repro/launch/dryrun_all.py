"""Sweep driver: baseline dry-run for every (arch x shape x mesh).

Each combination runs in its own subprocess (jax locks the host-device
count at first init) with bounded parallelism.  Results land in
``results/dryrun/<arch>.<shape>.<mesh>.json``; ``--table`` prints the
roofline summary used by EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.dryrun_all --mesh both -j 4
    PYTHONPATH=src python -m repro.launch.dryrun_all --table
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from concurrent.futures import ThreadPoolExecutor, as_completed
from typing import List, Tuple

from repro.configs import ASSIGNED_ARCHS
from repro.configs.base import INPUT_SHAPES

RESULTS = "results/dryrun"


def result_path(arch: str, shape: str, mesh: str) -> str:
    return os.path.join(RESULTS, f"{arch}.{shape}.{mesh}.json")


def run_one(arch: str, shape: str, mesh: str, timeout: int = 1500,
            force: bool = False) -> Tuple[str, str]:
    out = result_path(arch, shape, mesh)
    if os.path.exists(out) and not force:
        return (out, "cached")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", out]
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
        if proc.returncode != 0:
            err = {"arch": arch, "shape": shape, "mesh": mesh,
                   "status": "error",
                   "stderr": proc.stderr[-4000:]}
            os.makedirs(RESULTS, exist_ok=True)
            with open(out, "w") as f:
                json.dump(err, f, indent=2)
            return (out, "error")
        return (out, "ok")
    except subprocess.TimeoutExpired:
        with open(out, "w") as f:
            json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                       "status": "timeout"}, f)
        return (out, "timeout")


def all_pairs(meshes: List[str]) -> List[Tuple[str, str, str]]:
    return [(a, s.name, m) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES
            for m in meshes]


def print_table() -> None:
    rows = []
    for a in ASSIGNED_ARCHS:
        for s in INPUT_SHAPES:
            for m in ("single", "multi"):
                p = result_path(a, s.name, m)
                if not os.path.exists(p):
                    continue
                r = json.load(open(p))
                if r.get("status") == "skipped":
                    rows.append((a, s.name, m, "SKIP", r["reason"][:40],
                                 "", "", "", ""))
                elif r.get("status") != "ok":
                    rows.append((a, s.name, m, r.get("status", "?").upper(),
                                 "", "", "", "", ""))
                else:
                    rf = r["roofline"]
                    rows.append((
                        a, s.name, m, rf["dominant"],
                        f"{rf['compute_s']:.3g}",
                        f"{rf['memory_s']:.3g}",
                        f"{rf['collective_s']:.3g}",
                        f"{rf['useful_flop_ratio']:.3f}",
                        f"{(r['memory'] or {}).get('temp_size_in_bytes', 0)/1e9:.1f}"))
    hdr = ("arch", "shape", "mesh", "dominant", "compute_s", "memory_s",
           "coll_s", "useful", "tempGB")
    widths = [max(len(str(row[i])) for row in rows + [hdr])
              for i in range(len(hdr))]
    print("  ".join(h.ljust(w) for h, w in zip(hdr, widths)))
    for row in rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(row, widths)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("-j", "--jobs", type=int, default=3)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--table", action="store_true")
    ap.add_argument("--arch", default=None)
    args = ap.parse_args()

    if args.table:
        print_table()
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    pairs = all_pairs(meshes)
    if args.arch:
        pairs = [p for p in pairs if p[0] == args.arch]
    os.makedirs(RESULTS, exist_ok=True)
    done = 0
    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        futs = {ex.submit(run_one, a, s, m, force=args.force): (a, s, m)
                for a, s, m in pairs}
        for fut in as_completed(futs):
            a, s, m = futs[fut]
            out, status = fut.result()
            done += 1
            print(f"[{done}/{len(pairs)}] {a} x {s} [{m}] -> {status}",
                  flush=True)


if __name__ == "__main__":
    main()

"""End-to-end elastic training driver (CPU-runnable).

Trains a reduced-config model for N steps through the FULL Singularity
stack: elastic runtime (logical world size, splice factor), in-graph
barrier, periodic transparent checkpoints, and optional mid-run resizes —
the paper's §2 lifecycle as one command.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b \
        --steps 60 --world 4 --physical 4 --resize 20:2 --resize 40:4
"""
from __future__ import annotations

import argparse
import json
import time

from repro.configs import get_config, get_smoke_config
from repro.configs.base import TrainConfig
from repro.core.checkpoint import CheckpointStore
from repro.core.elastic import ElasticRuntime
from repro.core.migration import checkpoint_job


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--full", action="store_true",
                    help="use the full config (default: reduced smoke)")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--world", type=int, default=4,
                    help="logical world size (constant for the job)")
    ap.add_argument("--physical", type=int, default=4)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resize", action="append", default=[],
                    help="step:new_physical (repeatable)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    tcfg = TrainConfig(total_steps=args.steps, warmup_steps=2,
                       learning_rate=args.lr)
    resizes = {}
    for r in args.resize:
        step, phys = r.split(":")
        resizes[int(step)] = int(phys)

    rt = ElasticRuntime(cfg, tcfg, args.world, args.physical,
                        args.global_batch, args.seq_len)
    store = CheckpointStore()
    t0 = time.time()
    events = []
    while int(rt.state["step"]) < args.steps:
        step = int(rt.state["step"])
        if step in resizes:
            ev = rt.resize(resizes[step])
            print(f"[resize] {ev}")
            events.append({"resize": ev})
        if args.ckpt_every and step and step % args.ckpt_every == 0:
            stats = checkpoint_job(rt, store, f"train-{args.arch}")
            print(f"[ckpt] step={step} stored={stats.device_stored_bytes/1e6:.1f}MB "
                  f"(logical {stats.device_logical_bytes/1e6:.1f}MB, "
                  f"{stats.n_workers} workers)")
        rec = rt.run_steps(1)[0]
        print(f"step {rec['step']:4d} loss={rec['loss']:.4f} "
              f"splice={rec['splice']} physical={rec['physical']}")
    wall = time.time() - t0
    print(f"done: {args.steps} steps in {wall:.1f}s "
          f"(compile {rt.compile_seconds:.1f}s)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"history": rt.history, "events": events,
                       "wall_seconds": wall}, f, indent=2)


if __name__ == "__main__":
    main()

"""Perf hillclimb runner: lower a pair under a named variant and diff the
roofline terms against the baseline.

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch yi-9b --shape decode_32k --variant donate

Variants (the §Perf candidate set):
  baseline          — as the sweep
  donate            — donate the state (input/output buffer aliasing)
  splice2/4/8       — time-slice the step (activation live-set control)
  noremat           — disable activation checkpointing
  donate+spliceN    — combined
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json

from repro.launch.dryrun import lower_pair


def run_variant(arch: str, shape: str, mesh: str, variant: str) -> dict:
    kw = dict(splice=1, remat=True, donate=False, remat_policy="full",
              shard_profile="default", moe_capacity_factor=None,
              fused_gate=False, mesh_override=None)
    for part in variant.split("+"):
        if part.startswith("splice"):
            kw["splice"] = int(part[len("splice"):])
        elif part == "noremat":
            kw["remat"] = False
        elif part == "donate":
            kw["donate"] = True
        elif part == "dotsremat":
            kw["remat_policy"] = "dots"
        elif part == "nomodeltp":
            kw["shard_profile"] = "replicate_model"
        elif part.startswith("cf"):
            kw["moe_capacity_factor"] = float(part[2:]) / 100.0
        elif part == "fusedgate":
            kw["fused_gate"] = True
        elif part.startswith("chips"):
            n = int(part[len("chips"):])
            # right-size the mesh: keep data=16 (batch sharding), shrink TP
            kw["mesh_override"] = (16, n // 16) if n >= 16 else (n, 1)
        elif part == "baseline":
            pass
        else:
            raise ValueError(part)
    return lower_pair(arch, shape, multi_pod=(mesh == "multi"),
                      extra_tags={"variant": variant}, **kw)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", required=True)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rec = run_variant(args.arch, args.shape, args.mesh, args.variant)
    out = args.out or (f"results/perf/{args.arch}.{args.shape}."
                       f"{args.mesh}.{args.variant}.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rec, f, indent=2, default=str)
    rf = rec["roofline"]
    print(f"{args.arch} x {args.shape} [{args.mesh}] variant={args.variant}")
    print(f"  compute={rf['compute_s']:.4g}s memory={rf['memory_s']:.4g}s "
          f"collective={rf['collective_s']:.4g}s dominant={rf['dominant']} "
          f"useful={rf['useful_flop_ratio']:.3f}")
    if rec.get("memory"):
        print(f"  temp {rec['memory']['temp_size_in_bytes']/1e9:.2f} GB "
              f"args {rec['memory']['argument_size_in_bytes']/1e9:.2f} GB "
              f"alias {rec['memory'].get('alias_size_in_bytes',0)/1e9:.2f} GB")


if __name__ == "__main__":
    main()

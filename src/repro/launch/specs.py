"""Abstract input specs (ShapeDtypeStruct) for every (arch x shape) pair.

These are the dry-run stand-ins: weak-type-correct, shardable, and never
allocated.  ``input_specs`` covers the model inputs (tokens/labels plus the
stubbed modality embeddings); ``state_specs``/``decode_specs`` cover the
train/serve state trees via ``jax.eval_shape``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig, get_shape
from repro.models import init_decode_state
from repro.models.frontend import extra_inputs_spec
from repro.training.state import init_train_state

SWA_VARIANT_WINDOW = 4096


@dataclasses.dataclass(frozen=True)
class PairPlan:
    """What a given (arch, shape) pair lowers."""
    cfg: ModelConfig
    shape: ShapeConfig
    kind: str                 # train | prefill | decode
    swa_variant: bool         # dense arch long-context via documented SWA
    skip_reason: Optional[str] = None


def plan_pair(arch: str, shape_name: str) -> PairPlan:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    swa_variant = False
    skip = None
    if shape.name == "long_500k":
        if cfg.arch_type == "audio":
            skip = ("enc-dec decoder semantics cap at encoder-conditioned "
                    "transcription; 524k-token decode is meaningless "
                    "(DESIGN.md §4)")
        elif cfg.arch_type in ("ssm",):
            pass                      # recurrent state: natively O(1)
        elif cfg.sliding_window:
            pass                      # native SWA (danube, zamba2 shared blk)
        else:
            # dense/moe/vlm: documented sliding-window variant
            cfg = dataclasses.replace(cfg, sliding_window=SWA_VARIANT_WINDOW)
            swa_variant = True
    return PairPlan(cfg=cfg, shape=shape, kind=shape.kind,
                    swa_variant=swa_variant, skip_reason=skip)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStructs for the batch consumed by train/prefill steps."""
    g, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((g, s), jnp.int32),
    }
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((g, s), jnp.int32)
    specs.update(extra_inputs_spec(cfg, g, dtype=jnp.bfloat16))
    if shape.kind == "decode":
        # decode consumes one token per sequence + the cache state
        specs = {"token": jax.ShapeDtypeStruct((g,), jnp.int32)}
    return specs


def state_specs(cfg: ModelConfig, tcfg: TrainConfig):
    """Abstract TrainState via eval_shape (no allocation)."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(
        lambda k: init_train_state(cfg, tcfg, k), key)


def decode_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract decode state (KV/SSM caches at seq_len)."""
    return jax.eval_shape(
        lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len,
                                  dtype=jnp.bfloat16))

"""Fleet executor: the hierarchical scheduler driving REAL jobs.

Where ``simulator.py`` models jobs as progress rates, this executor runs a
miniature fleet of actual ``ElasticRuntime`` training jobs (reduced
configs) and applies the ``ElasticPolicy``'s decisions through the REAL
mechanisms: resize -> spliced-step swap; preempt -> in-graph barrier
quiesce + content-deduped checkpoint; re-admit -> restore + resume.
Figure 1's scopes as running code, on one host.

Capacity is counted in "device slots"; each job's logical world size stays
constant while its physical allocation follows the policy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.core.checkpoint import CheckpointStore
from repro.core.elastic import ElasticRuntime
from repro.core.migration import checkpoint_job
from repro.core.sla import TIERS


@dataclasses.dataclass
class ManagedJob:
    id: str
    tier: str
    arch: str
    world_size: int            # logical (constant) = demanded devices
    total_steps: int
    runtime: Optional[ElasticRuntime] = None
    allocated: int = 0
    done: bool = False
    preemptions: int = 0
    resizes: int = 0
    steps_done: int = 0

    def demand(self) -> int:
        return self.world_size


class FleetExecutor:
    """A single-host fleet of real elastic jobs under tiered scheduling."""

    def __init__(self, total_slots: int, seed: int = 0):
        self.total_slots = total_slots
        self.jobs: Dict[str, ManagedJob] = {}
        self.store = CheckpointStore()
        self.log: List[Dict] = []

    # ------------------------------------------------------------ admission
    def submit(self, job: ManagedJob, global_batch: int = 8,
               seq_len: int = 32) -> None:
        cfg = get_smoke_config(job.arch)
        tcfg = TrainConfig(total_steps=job.total_steps, warmup_steps=1,
                           learning_rate=1e-3)
        job.runtime = ElasticRuntime(cfg, tcfg, job.world_size,
                                     job.world_size, global_batch, seq_len)
        job._cfg, job._tcfg = cfg, tcfg
        job._gb, job._sl = global_batch, seq_len
        self.jobs[job.id] = job

    # ------------------------------------------------------------ policy
    def _decide(self) -> Dict[str, int]:
        """Tiered allocation over slot capacity (premium first, FIFO),
        shrink-before-preempt via splice divisors."""
        active = [j for j in self.jobs.values() if not j.done]
        order = sorted(active,
                       key=lambda j: -TIERS[j.tier].preempt_priority)
        alloc: Dict[str, int] = {j.id: 0 for j in active}
        free = self.total_slots
        for j in order:
            give = min(j.demand(), free)
            # physical must divide world size: largest divisor <= give
            while give > 0 and j.world_size % give != 0:
                give -= 1
            alloc[j.id] = give
            free -= give
        return alloc

    def _apply(self, alloc: Dict[str, int]) -> None:
        for jid, target in alloc.items():
            job = self.jobs[jid]
            if job.done:
                continue
            if target == job.allocated:
                continue
            if target == 0 and job.allocated > 0:
                # REAL preemption: in-graph barrier quiesce + checkpoint
                job.runtime.request_preemption()
                job.runtime.run_steps(2, stop_on_barrier=True)
                job.steps_done = int(job.runtime.state["step"])
                checkpoint_job(job.runtime, self.store, jid)
                job.runtime = None
                job.preemptions += 1
                self.log.append({"event": "preempt", "job": jid})
            elif target > 0 and job.allocated == 0 and job.runtime is None:
                # REAL re-admission: restore from the deduped store
                device, host, step = self.store.restore(jid)
                job.runtime = ElasticRuntime.from_snapshot(
                    job._cfg, job._tcfg,
                    {"state": device[0], "pipeline": host[0]["pipeline"],
                     "world_size": host[0]["world_size"]},
                    target, job._gb, job._sl)
                assert int(job.runtime.state["step"]) == job.steps_done
                self.log.append({"event": "restore", "job": jid,
                                 "at_step": step})
            elif target > 0 and job.runtime is not None:
                if job.runtime.physical != target:
                    job.runtime.resize(target)  # REAL transparent resize
                    if job.allocated > 0:       # admission is not a resize
                        job.resizes += 1
                        self.log.append({"event": "resize", "job": jid,
                                         "to": target})
            job.allocated = target

    # ------------------------------------------------------------ run
    def tick(self, steps: int = 1) -> None:
        """One scheduling round: decide, apply, advance running jobs."""
        self._apply(self._decide())
        for job in self.jobs.values():
            if job.done or job.runtime is None or job.allocated == 0:
                continue
            job.runtime.run_steps(steps)
            job.steps_done = int(job.runtime.state["step"])
            if job.steps_done >= job.total_steps:
                job.done = True
                job.allocated = 0
                job.runtime = None
                self.log.append({"event": "done", "job": job.id,
                                 "steps": job.steps_done})

    def run(self, max_ticks: int = 100) -> List[Dict]:
        for _ in range(max_ticks):
            if all(j.done for j in self.jobs.values()):
                break
            self.tick()
        return self.log

"""Fleet executor: the hierarchical scheduler driving REAL jobs.

Where ``simulator.py`` models jobs as progress rates, this executor runs a
miniature fleet of actual ``ElasticRuntime`` training jobs (reduced
configs) and applies the scheduling decisions through the REAL
mechanisms: resize -> spliced-step swap; preempt -> in-graph barrier
quiesce + content-deduped checkpoint; re-admit -> restore + resume.
Figure 1's scopes as running code, on one host.

The decisions come from the SAME ``ElasticPolicy.decide`` the simulator
exercises — the executor adapts its slot capacity to a one-cluster
``Fleet`` and mirrors each managed job as a scheduler ``Job`` (the
workload-scope shadow: arrival order, SLA account, allocation state).
The shadows' SLA accounts live in the same ``FleetSLAAccounts`` ledger
the simulator uses, recorded in one batched call per tick, and the
shadows themselves are adopted into the same fleet ``JobTable`` — the
policy slices identical columns under both back-ends.  One policy, two
mechanism back-ends; simulated results and real-mechanism results can no
longer drift apart.

Capacity is counted in "device slots"; each job's logical world size stays
constant while its physical allocation follows the policy, rounded to the
nearest world-size divisor (the splice constraint s = W/P).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.core.checkpoint import CheckpointStore
from repro.core.elastic import ElasticRuntime
from repro.core.migration import checkpoint_job
from repro.core.sla import FleetSLAAccounts, FleetSlotAccount
from repro.scheduler.costs import CostModel
from repro.scheduler.job_table import TIER_CODE, JobTable, TableJob
from repro.scheduler.node_map import NodeMap
from repro.scheduler.policy import ElasticPolicy
from repro.scheduler.telemetry import (
    C_FAILURE,
    C_NONE,
    C_POLICY,
    C_PREEMPT,
    E_ADMIT,
    E_COMPLETE,
    E_FAILURE,
    E_PREEMPT,
    E_RESIZE,
    E_RESTORE,
    FleetTelemetry,
)
from repro.scheduler.types import Cluster, Fleet, Job, Region


@dataclasses.dataclass
class ManagedJob:
    id: str
    tier: str
    arch: str
    world_size: int  # logical (constant) = demanded devices
    total_steps: int
    runtime: Optional[ElasticRuntime] = None
    allocated: int = 0
    done: bool = False
    preemptions: int = 0
    resizes: int = 0
    steps_done: int = 0

    def demand(self) -> int:
        return self.world_size


def _largest_divisor_leq(world: int, cap: int) -> int:
    """Largest physical device count that divides ``world`` and is <= cap."""
    give = min(world, cap)
    while give > 0 and world % give != 0:
        give -= 1
    return give


class FleetExecutor:
    """A single-host fleet of real elastic jobs under tiered scheduling."""

    def __init__(
        self,
        total_slots: int,
        seed: int = 0,
        policy: Optional[ElasticPolicy] = None,
        tick_seconds: float = 60.0,
        cost_model: Optional[CostModel] = None,
        telemetry: Optional[FleetTelemetry] = None,
    ):
        self.total_slots = total_slots
        self.jobs: Dict[str, ManagedJob] = {}
        self.store = CheckpointStore()
        self.log: List[Dict] = []
        # observability: the same structured event log / profiler bundle
        # the simulator threads (telemetry.py) — pass ``True`` to build a
        # fresh one.  ``self.log``'s human-readable dicts stay; the
        # structured rows add machine-checkable lifecycle events on the
        # REAL-mechanism back-end too.
        if telemetry is True:
            telemetry = FleetTelemetry()
        self.tele: Optional[FleetTelemetry] = telemetry or None
        self._ev = self.tele.events if self.tele is not None else None
        # the same policy object the simulator drives, over a 1-cluster fleet
        self.policy = policy or ElasticPolicy()
        # thread the mechanism cost model into the policy so the executor's
        # decisions price preempt/restore/resize exactly like the simulator
        self.cost_model = cost_model or CostModel()
        if hasattr(self.policy, "bind_costs"):
            self.policy.bind_costs(self.cost_model, tick_seconds)
        if self.tele is not None and hasattr(self.policy, "bind_telemetry"):
            self.policy.bind_telemetry(self.tele)
        # shadow accounts live in a shared fleet ledger, and the shadows
        # themselves in a shared JobTable, like the simulator's — one
        # decide path for both back-ends, column slices included
        self.sla = FleetSLAAccounts()
        self.table = JobTable(clusters=["local"], sla=self.sla)
        self.fleet = Fleet(
            [Region("local", [Cluster("local", "local", total_slots)])],
            sla=self.sla,
            jobs=self.table,
        )
        # shadows carry real node spans: the policy's gang/splice-aware
        # node placement sees the same NodeMap shape the simulator would,
        # so its divisor rounding matches the executor's splice constraint
        self.fleet.node_map = NodeMap.from_fleet(self.fleet)
        self.tick_seconds = tick_seconds
        self.clock = 0.0
        self._shadows: Dict[str, Job] = {}  # workload-scope policy mirrors

    # ------------------------------------------------------------ admission
    def submit(
        self, job: ManagedJob, global_batch: int = 8, seq_len: int = 32
    ) -> None:
        cfg = get_smoke_config(job.arch)
        tcfg = TrainConfig(
            total_steps=job.total_steps, warmup_steps=1, learning_rate=1e-3
        )
        job.runtime = ElasticRuntime(
            cfg, tcfg, job.world_size, job.world_size, global_batch, seq_len
        )
        job._cfg, job._tcfg = cfg, tcfg
        job._gb, job._sl = global_batch, seq_len
        self.jobs[job.id] = job
        # scheduler-facing mirror: demand = logical world, splice floor 1;
        # adopted into the shared JobTable so the policy's decide path
        # slices the same columns it would under the simulator
        shadow = Job(
            id=job.id,
            tier=job.tier,
            demand_gpus=job.world_size,
            gpu_hours=job.total_steps * job.world_size / 3600.0,
            arrival=self.clock,
            min_gpus=1,
            account=FleetSlotAccount(self.sla, job.tier, job.world_size),
        )
        shadow.node_slot = self.table.adopt(shadow)  # NodeMap row == slot
        self._shadows[job.id] = shadow

    def _emit(
        self,
        kind: int,
        jid: str,
        cause: int = C_NONE,
        gpus: int = 0,
        seconds: float = 0.0,
    ) -> None:
        """One structured telemetry row for this managed job (no-op when
        no telemetry is attached).  Jobs are keyed by their stable table
        slot; the one-cluster fleet is cluster index 0.  ``seconds`` is
        the mechanism's modelled cost — the executor measures steps, not
        wall downtime, so FAILURE rows carry lost *steps* instead."""
        if self._ev is None:
            return
        s = self._shadows[jid]
        self._ev.append(
            self.clock,
            kind,
            job=s.node_slot,
            cluster=0,
            tier=TIER_CODE[s.tier],
            cause=cause,
            gpus=gpus,
            seconds=seconds,
        )

    # ------------------------------------------------------------ policy
    def _decide_allocations(self) -> Dict[str, int]:
        """Run the unified ``ElasticPolicy`` over the one-cluster fleet and
        round each target to the splice constraint (divisor of world)."""
        shadows = [self._shadows[jid] for jid, j in self.jobs.items() if not j.done]
        decision = self.policy.decide(self.clock, shadows, self.fleet)
        alloc: Dict[str, int] = {}
        free = self.total_slots
        for s in sorted(shadows, key=lambda s: -decision.alloc[s.id][0]):
            target, _ = decision.alloc[s.id]
            give = _largest_divisor_leq(self.jobs[s.id].world_size, min(target, free))
            alloc[s.id] = give
            free -= give
        return alloc

    def _apply(self, alloc: Dict[str, int]) -> None:
        for jid, target in alloc.items():
            job = self.jobs[jid]
            if job.done:
                continue
            if target == job.allocated:
                continue
            if target == 0 and job.allocated > 0:
                # REAL preemption: in-graph barrier quiesce + checkpoint
                job.runtime.request_preemption()
                job.runtime.run_steps(2, stop_on_barrier=True)
                job.steps_done = int(job.runtime.state["step"])
                checkpoint_job(job.runtime, self.store, jid)
                job.runtime = None
                job.preemptions += 1
                # the shadow carries the preempt cost as restore debt, so
                # the policy's restart gates price this job's re-admission
                # exactly like the simulator would; it also re-enters the
                # queue now, which is when fairness aging starts accruing
                shadow = self._shadows[jid]
                debt = self.cost_model.preempt_seconds(shadow.checkpoint_bytes)
                shadow.restore_debt += debt
                shadow.queued_since = self.clock
                self.log.append({"event": "preempt", "job": jid})
                self._emit(
                    E_PREEMPT,
                    jid,
                    cause=C_POLICY,
                    gpus=job.allocated,
                    seconds=debt,
                )
            elif target > 0 and job.allocated == 0 and job.runtime is None:
                if jid not in self.store.manifests:
                    # failed before any checkpoint existed: fresh restart
                    job.runtime = ElasticRuntime(
                        job._cfg, job._tcfg, job.world_size, target, job._gb, job._sl
                    )
                    job.steps_done = 0
                    shadow = self._shadows[jid]
                    failed = shadow.failed_at is not None
                    shadow.failed_at = None
                    self.log.append({"event": "restart", "job": jid, "at_step": 0})
                    self._emit(
                        E_ADMIT,
                        jid,
                        cause=C_FAILURE if failed else C_NONE,
                        gpus=target,
                    )
                    job.allocated = target
                    shadow.allocated = target
                    shadow.ever_ran = True
                    shadow.cluster = "local"
                    continue
                # REAL re-admission: restore from the deduped store
                failed = self._shadows[jid].failed_at is not None
                self._shadows[jid].restore_debt = 0.0
                self._shadows[jid].failed_at = None
                device, host, step = self.store.restore(jid)
                job.runtime = ElasticRuntime.from_snapshot(
                    job._cfg,
                    job._tcfg,
                    {
                        "state": device[0],
                        "pipeline": host[0]["pipeline"],
                        "world_size": host[0]["world_size"],
                    },
                    target,
                    job._gb,
                    job._sl,
                )
                assert int(job.runtime.state["step"]) == job.steps_done
                self.log.append({"event": "restore", "job": jid, "at_step": step})
                self._emit(
                    E_RESTORE,
                    jid,
                    cause=C_FAILURE if failed else C_PREEMPT,
                    gpus=target,
                    seconds=self.cost_model.restore_seconds(
                        self._shadows[jid].checkpoint_bytes
                    ),
                )
            elif target > 0 and job.runtime is not None:
                if job.runtime.physical != target:
                    job.runtime.resize(target)  # REAL transparent resize
                    if job.allocated > 0:  # admission is not a resize
                        job.resizes += 1
                        self.log.append({"event": "resize", "job": jid, "to": target})
                        self._emit(
                            E_RESIZE,
                            jid,
                            cause=C_POLICY,
                            gpus=target,
                            seconds=self.cost_model.resize_seconds(
                                self._shadows[jid].checkpoint_bytes
                            ),
                        )
                if job.allocated == 0:
                    self._emit(E_ADMIT, jid, gpus=target)
            job.allocated = target
            shadow = self._shadows[jid]
            shadow.allocated = target
            if target > 0:
                shadow.ever_ran = True
                shadow.cluster = "local"
        self._sync_node_spans()

    def _sync_node_spans(self) -> None:
        """Mirror the applied slot allocations into the fleet NodeMap so
        the next decide pass plans against real node spans (row == table
        slot; the one-cluster fleet auto-fits lowest-index first)."""
        nm = self.fleet.node_map
        for s in self._shadows.values():
            if s.done_at is not None:
                continue
            g = int(s.allocated)
            if nm.span_total(s.node_slot) == g:
                continue
            nm.release(s.node_slot)
            if g > 0:
                nm.auto_fit(s.node_slot, 0, g)

    # ------------------------------------------------------------ faults
    def inject_failure(self, jid: str) -> Dict:
        """Unplanned hardware failure under the REAL mechanisms: the
        runtime is dropped with NO graceful checkpoint, so the job loses
        every step since its last durable snapshot in the store and
        restarts from there (or from step 0 if it never checkpointed) at
        the next admission — the paper's reliability claim (§1, §6):
        a failure is just a preemption minus the barrier.
        """
        job = self.jobs[jid]
        assert not job.done, "cannot fail a completed job"
        step_now = job.steps_done
        if job.runtime is not None:
            step_now = int(job.runtime.state["step"])
        if jid in self.store.manifests:
            snap_step = int(self.store.manifests[jid][-1]["step"])
        else:
            snap_step = 0  # never checkpointed: restart from scratch
        job.runtime = None  # the hardware is gone — no quiesce, no dump
        lost_alloc = job.allocated
        job.allocated = 0
        job.steps_done = snap_step
        shadow = self._shadows[jid]
        shadow.allocated = 0
        self.fleet.node_map.release(shadow.node_slot)
        shadow.failures += 1
        shadow.failed_at = self.clock
        shadow.queued_since = self.clock  # fairness aging restarts here
        shadow.restore_debt = 0.0  # no graceful preempt was paid
        event = {
            "event": "failure",
            "job": jid,
            "at_step": step_now,
            "rollback_to": snap_step,
            "lost_steps": step_now - snap_step,
        }
        self.log.append(event)
        self._emit(
            E_FAILURE,
            jid,
            cause=C_FAILURE,
            gpus=lost_alloc,
            seconds=float(step_now - snap_step),  # lost STEPS (see _emit)
        )
        return event

    # ------------------------------------------------------------ run
    def tick(self, steps: int = 1) -> None:
        """One scheduling round: decide, apply, advance running jobs."""
        self._apply(self._decide_allocations())
        # the shadows' SLA accounts see the interval we are about to run —
        # one batched record into the fleet ledger
        live = [s for s in self._shadows.values() if s.done_at is None]
        if live:
            slots = np.array([s.account.ensure_slot() for s in live], np.int64)
            m = len(live)
            self.sla.record_batch(
                slots,
                np.full(m, self.clock),
                np.full(m, self.clock + self.tick_seconds),
                np.array([s.allocated for s in live], np.int64),
            )
        self.clock += self.tick_seconds
        for job in self.jobs.values():
            if job.done or job.runtime is None or job.allocated == 0:
                continue
            job.runtime.run_steps(steps)
            job.steps_done = int(job.runtime.state["step"])
            if job.steps_done >= job.total_steps:
                job.done = True
                self._emit(E_COMPLETE, job.id, gpus=job.allocated)
                job.allocated = 0
                job.runtime = None
                shadow = self._shadows[job.id]
                shadow.done_at = self.clock
                shadow.allocated = 0
                shadow.account.release()
                self.fleet.node_map.release(shadow.node_slot)
                if isinstance(shadow, TableJob):
                    self.table.detach(shadow)  # row freed for reuse
                self.log.append(
                    {"event": "done", "job": job.id, "steps": job.steps_done}
                )

    def run(self, max_ticks: int = 100) -> List[Dict]:
        for _ in range(max_ticks):
            if all(j.done for j in self.jobs.values()):
                break
            self.tick()
        return self.log

"""Fleet-wide node-granular placement state (the NodeMap).

Placement used to stop at cluster granularity: a job carried a
``cluster_idx`` scalar, and everything below it — which nodes the
replicas actually sit on — was approximated.  Partial-domain failures
picked victims by (arrival, id) packing order, gang/splice constraints
were invisible to placement, and fragmentation could not even be
measured.  The NodeMap makes the node layer real, with the same
struct-of-arrays recipe as ``JobTable``/``FleetSLAAccounts``:

**Node axis** (one entry per node, laid out cluster-contiguously in
``fleet.clusters()`` order; a trailing partial node keeps its TRUE
smaller capacity):

- ``node_cap``      — GPUs physically on the node
- ``node_cluster``  — owning cluster index
- ``node_free``     — GPUs idle and healthy
- ``node_used``     — GPUs held by live job spans
- ``node_out``      — UNCLAMPED sum of outstanding failure claims; dead
  capacity is ``min(cap, out)`` so overlapping failures never resurrect
  capacity when the shorter one repairs first (the cluster-level
  ``_outstanding`` rule, per node)

The invariant ``free + used + min(cap, out) == cap`` holds per node at
every tick and is asserted by :meth:`NodeMap.check`.

**Row axis** (one row per job, row index == the driver's table slot /
trace index): ``row_off``/``row_len`` address a piece pool
(``span_node``/``span_gpus``/``span_row``) holding the job's node span —
the list of (node, gpus) pieces it occupies.  Rows grow by doubling and
are reused after release; the pool is bump-allocated and compacted when
more than half of it is garbage.

**Gang/splice compatibility.**  A job that demands ``D`` GPUs can only
run at world sizes the device-proxy splice supports: divisors of ``D``
(time-sliced shrink) or multiples of ``D`` (scale-out).  ``gang_down``
rounds an arbitrary grant to the largest compatible value below it; the
placement overlay only ever fits compatible gangs, shaped as ``w`` full
nodes plus one remainder piece ``r = g % gpus_per_node`` on a best-fit
partial node (smallest sufficient free count, lowest index on ties).

**Fragmentation.**  A free GPU is *stranded* when it sits in a hole too
small to host the smallest single-node piece any queued gang could use
(``min_piece``).  ``stranded_gpus`` is the fleet-wide count, reported
time-averaged in ``SimResult.fragmentation_stranded_gpus``; the
simulator's defragmentation pass consolidates such holes when the freed
capacity is worth the charged migration downtime (``costs.defrag_worthwhile``).
"""
from __future__ import annotations

import heapq
from functools import lru_cache
from typing import TYPE_CHECKING, List, Optional, Sequence, Tuple

import numpy as np

if TYPE_CHECKING:  # avoid the import cycle: types builds the NodeMap
    from repro.scheduler.types import Fleet


# --------------------------------------------------------- gang arithmetic
@lru_cache(maxsize=None)
def splice_divisors(demand: int) -> Tuple[int, ...]:
    """Ascending divisors of ``demand`` — the shrink-side world sizes the
    splice mechanism supports (§5.4)."""
    d = max(1, int(demand))
    return tuple(k for k in range(1, d + 1) if d % k == 0)


def gang_down(g: int, demand: int) -> int:
    """Largest splice-compatible world size at or below ``g`` (0 if none):
    a multiple of ``demand`` when ``g >= demand``, else the largest
    divisor of ``demand`` below it."""
    if g <= 0:
        return 0
    if g >= demand:
        return g - g % demand
    divs = splice_divisors(demand)
    lo = 0
    for d in divs:
        if d > g:
            break
        lo = d
    return lo


def gang_down_vec(galloc: np.ndarray, demand: np.ndarray) -> np.ndarray:
    """Vector ``gang_down`` over per-job grants: multiples round in one
    modulo pass; sub-demand grants loop over the (few) unique demands,
    each resolved with one searchsorted against its divisor table."""
    out = galloc.copy()
    pos = galloc > 0
    ge = pos & (galloc >= demand)
    if ge.any():
        out[ge] = galloc[ge] - galloc[ge] % demand[ge]
    lt = pos & ~ge
    if lt.any():
        for d in np.unique(demand[lt]):
            m = lt & (demand == d)
            divs = np.asarray(splice_divisors(int(d)), np.int64)
            out[m] = divs[np.searchsorted(divs, galloc[m], side="right") - 1]
    return out


@lru_cache(maxsize=None)
def gang_values(demand: int, lo: int, hi: int) -> Tuple[int, ...]:
    """Splice-compatible world sizes in ``[lo, hi]``, descending — the
    candidate ladder for shrink-to-hole placement."""
    vals = [d for d in splice_divisors(demand) if lo <= d <= hi and d < demand]
    m = demand
    while m <= hi:
        if m >= lo:
            vals.append(m)
        m += demand
    return tuple(sorted(vals, reverse=True))


@lru_cache(maxsize=None)
def floor_gang(demand: int, min_gpus: int) -> int:
    """Smallest splice-compatible world size at or above ``min_gpus``
    (0 if none) — the smallest gang a queued job could be admitted at,
    the shape the defragmentation pass tries to unblock.  A floor above
    the demand itself is degenerate: admission grants are capped at the
    demand before placement, so no admissible world size exists and the
    answer is 0, never a multiple the job could not be granted."""
    d = max(1, int(demand))
    lo = max(1, int(min_gpus))
    if lo > d:
        return 0
    vals = gang_values(d, lo, d)
    return vals[-1] if vals else 0


@lru_cache(maxsize=None)
def min_piece(demand: int, min_gpus: int, gpus_per_node: int) -> int:
    """Smallest single-node piece any admissible gang of this job could
    occupy: over every compatible world size ``g >= min_gpus``, the
    smallest of its node pieces (``g`` itself below a node, else the
    remainder ``g % gpus_per_node`` or a full node).  Free capacity in a
    hole smaller than this can never serve the job — it is stranded.
    A degenerate floor above the demand admits no gang at all, so no
    sub-node hole is ever usable: the answer saturates at a full node."""
    gpn = max(1, int(gpus_per_node))
    d = max(1, int(demand))
    lo = max(1, int(min_gpus))
    best = gpn
    if lo > d:
        return best
    for g in gang_values(d, lo, 2 * d):
        if g < gpn:
            piece = g
        else:
            r = g % gpn
            piece = r if r else gpn
        if piece < best:
            best = piece
    return best


# ---------------------------------------------------------------- NodeMap
class NodeMap:
    """Simulator-owned SoA of per-node capacity and per-job node spans."""

    def __init__(
        self,
        node_cap: np.ndarray,
        node_cluster: np.ndarray,
        cluster_lo: np.ndarray,
        cluster_hi: np.ndarray,
        cluster_gpn: np.ndarray,
        capacity_rows: int = 64,
    ):
        self.node_cap = node_cap.astype(np.int64)
        self.node_cluster = node_cluster.astype(np.int64)
        self.node_free = self.node_cap.copy()
        self.node_used = np.zeros_like(self.node_cap)
        self.node_out = np.zeros_like(self.node_cap)
        self.cluster_lo = cluster_lo.astype(np.int64)
        self.cluster_hi = cluster_hi.astype(np.int64)
        self.cluster_gpn = cluster_gpn.astype(np.int64)
        self.n_clusters = int(cluster_lo.size)
        rows = max(1, int(capacity_rows))
        self.row_off = np.zeros(rows, np.int64)
        self.row_len = np.zeros(rows, np.int64)
        self.row_total = np.zeros(rows, np.int64)
        self.row_k = np.full(rows, -1, np.int64)
        pool = max(4, 2 * rows)
        self.span_node = np.zeros(pool, np.int64)
        self.span_gpus = np.zeros(pool, np.int64)
        self.span_row = np.full(pool, -1, np.int64)
        self._pool_n = 0
        self._garbage = 0

    @classmethod
    def from_fleet(cls, fleet: "Fleet", capacity_rows: int = 64) -> "NodeMap":
        caps: List[int] = []
        owner: List[int] = []
        lo: List[int] = []
        hi: List[int] = []
        gpn: List[int] = []
        for k, c in enumerate(fleet.clusters()):
            nc = c.node_capacities()
            lo.append(len(caps))
            caps.extend(nc)
            hi.append(len(caps))
            owner.extend([k] * len(nc))
            gpn.append(max(1, c.gpus_per_node))
        return cls(
            np.asarray(caps, np.int64),
            np.asarray(owner, np.int64),
            np.asarray(lo, np.int64),
            np.asarray(hi, np.int64),
            np.asarray(gpn, np.int64),
            capacity_rows=capacity_rows,
        )

    # ---------------------------------------------------------- row spans
    def _ensure_row(self, row: int) -> None:
        n = self.row_len.size
        if row < n:
            return
        m = max(64, n)
        while m <= row:
            m *= 2
        grow = m - n
        self.row_off = np.concatenate([self.row_off, np.zeros(grow, np.int64)])
        self.row_len = np.concatenate([self.row_len, np.zeros(grow, np.int64)])
        self.row_total = np.concatenate([self.row_total, np.zeros(grow, np.int64)])
        self.row_k = np.concatenate([self.row_k, np.full(grow, -1, np.int64)])

    def _pool_reserve(self, extra: int) -> None:
        need = self._pool_n + extra
        cap = self.span_node.size
        if need <= cap:
            return
        if self._garbage > self._pool_n // 2:
            self._compact()
            need = self._pool_n + extra
            if need <= self.span_node.size:
                return
            cap = self.span_node.size
        m = max(4, cap)
        while m < need:
            m *= 2
        pad = m - cap
        self.span_node = np.concatenate([self.span_node, np.zeros(pad, np.int64)])
        self.span_gpus = np.concatenate([self.span_gpus, np.zeros(pad, np.int64)])
        self.span_row = np.concatenate([self.span_row, np.full(pad, -1, np.int64)])

    def _compact(self) -> None:
        pn = self._pool_n
        keep = self.span_gpus[:pn] > 0
        node = self.span_node[:pn][keep]
        gpus = self.span_gpus[:pn][keep]
        rows = self.span_row[:pn][keep]
        live = int(node.size)
        self.span_node[:live] = node
        self.span_gpus[:live] = gpus
        self.span_row[:live] = rows
        self.span_gpus[live:pn] = 0
        self.span_row[live:pn] = -1
        self._pool_n = live
        self._garbage = 0
        # pieces of one row stay contiguous under a stable filter; each
        # live row owns exactly one run, so boundaries are value changes
        if live:
            change = np.flatnonzero(np.diff(rows) != 0) + 1
            starts = np.concatenate(([0], change))
            self.row_off[rows[starts]] = starts

    def has_span(self, row: int) -> bool:
        return 0 <= row < self.row_len.size and self.row_len[row] > 0

    def span_total(self, row: int) -> int:
        if not self.has_span(row):
            return 0
        return int(self.row_total[row])

    def span_cluster(self, row: int) -> int:
        if not self.has_span(row):
            return -1
        return int(self.row_k[row])

    def row_pieces(self, row: int) -> Tuple[np.ndarray, np.ndarray]:
        if not self.has_span(row):
            return np.empty(0, np.int64), np.empty(0, np.int64)
        sl = slice(int(self.row_off[row]), int(self.row_off[row] + self.row_len[row]))
        return self.span_node[sl], self.span_gpus[sl]

    def row_state(
        self, rows: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(has_span, span_cluster, span_total) gathered for many rows at
        once — the decide path's zero-Python span lookup."""
        safe = (rows >= 0) & (rows < self.row_len.size)
        rr = np.where(safe, rows, 0)
        has = safe & (self.row_len[rr] > 0)
        tot = np.where(has, self.row_total[rr], 0)
        k = np.where(has, self.row_k[rr], -1)
        return has, k, tot

    def assign(self, row: int, nodes: Sequence[int], gpus: Sequence[int]) -> None:
        """Install a span (one piece per distinct node).  ``release`` any
        previous span first."""
        self._ensure_row(row)
        assert self.row_len[row] == 0, f"row {row} already holds a span"
        nodes = np.asarray(nodes, np.int64)
        gpus = np.asarray(gpus, np.int64)
        n = int(nodes.size)
        assert n > 0 and (gpus > 0).all()
        self._pool_reserve(n)
        off = self._pool_n
        self.span_node[off : off + n] = nodes
        self.span_gpus[off : off + n] = gpus
        self.span_row[off : off + n] = row
        self._pool_n = off + n
        self.row_off[row] = off
        self.row_len[row] = n
        self.row_total[row] = int(gpus.sum())
        self.row_k[row] = int(self.node_cluster[nodes[0]])
        self.node_free[nodes] -= gpus
        self.node_used[nodes] += gpus
        assert (self.node_free[nodes] >= 0).all(), (
            f"node over-subscribed placing row {row}"
        )

    def release(self, row: int) -> None:
        if not self.has_span(row):
            return
        ln = int(self.row_len[row])
        sl = slice(int(self.row_off[row]), int(self.row_off[row]) + ln)
        nodes = self.span_node[sl]
        gpus = self.span_gpus[sl]
        self.node_free[nodes] += gpus
        self.node_used[nodes] -= gpus
        self.span_gpus[sl] = 0
        self.span_row[sl] = -1
        self._garbage += ln
        self.row_len[row] = 0
        self.row_total[row] = 0
        self.row_k[row] = -1

    def live_rows(self) -> np.ndarray:
        return np.flatnonzero(self.row_len > 0)

    def auto_fit(self, row: int, k: int, gpus: int) -> None:
        """Lowest-index greedy fill ignoring gang shape — the fallback
        span for policies that do not plan node placement (the static
        gang baseline, hand-written policies).  Asserts the cluster can
        hold the grant: per-node conservation rejects over-allocation
        even for planless policies."""
        lo, hi = int(self.cluster_lo[k]), int(self.cluster_hi[k])
        seg = self.node_free[lo:hi]
        nodes: List[int] = []
        take: List[int] = []
        rem = int(gpus)
        for j in np.flatnonzero(seg > 0):
            t = min(rem, int(seg[j]))
            nodes.append(lo + int(j))
            take.append(t)
            rem -= t
            if rem == 0:
                break
        assert rem == 0, (
            f"cluster {k} over-allocated: no node capacity for {gpus} GPUs"
        )
        self.assign(row, nodes, take)

    def move_piece(self, row: int, from_node: int, to_node: int) -> int:
        """Defragmentation move: relocate this row's piece off
        ``from_node`` onto ``to_node`` (merging with an existing piece
        there).  Returns the GPUs moved."""
        nodes, gpus = self.row_pieces(row)
        pieces = {int(n): int(g) for n, g in zip(nodes, gpus)}
        g = pieces.pop(int(from_node))
        pieces[int(to_node)] = pieces.get(int(to_node), 0) + g
        self.release(row)
        self.assign(row, list(pieces.keys()), list(pieces.values()))
        return g

    # ------------------------------------------------------ failure claims
    def fail_claims(self, k: int, want: int) -> List[Tuple[int, int]]:
        """Per-node claim list for a failure of ``want`` GPUs on cluster
        ``k``.  A whole-domain failure claims every node's full capacity
        UNCLAMPED (so it owns the capacity regardless of prior claims);
        a partial failure claims currently-claimable capacity ascending
        by node index, any unclaimable leftover landing on the first
        node for bookkeeping symmetry."""
        lo, hi = int(self.cluster_lo[k]), int(self.cluster_hi[k])
        caps = self.node_cap[lo:hi]
        if want >= int(caps.sum()):
            return [(lo + i, int(caps[i])) for i in range(hi - lo)]
        claims: List[Tuple[int, int]] = []
        remaining = int(want)
        for i in range(lo, hi):
            if remaining <= 0:
                break
            cap = int(self.node_cap[i])
            avail = cap - min(cap, int(self.node_out[i]))
            take = min(avail, remaining)
            if take > 0:
                claims.append((i, take))
                remaining -= take
        if remaining > 0:
            claims.append((lo, remaining))
        return claims

    def apply_claims(self, claims: List[Tuple[int, int]]) -> List[int]:
        """Kill capacity per the claim list.  Each node's effective dead
        increase eats free GPUs first, then kills jobs with pieces on the
        node in ascending row order (the whole gang dies; its span is
        released everywhere).  Returns the victim rows."""
        victims: List[int] = []
        for node, take in claims:
            cap = int(self.node_cap[node])
            old = min(cap, int(self.node_out[node]))
            self.node_out[node] += take
            e = min(cap, int(self.node_out[node])) - old
            x = min(int(self.node_free[node]), e)
            self.node_free[node] -= x
            e -= x
            while e > 0:
                r = self._lowest_row_on(node)
                assert r >= 0, f"node {node}: dead exceeds free+used"
                self.release(r)
                victims.append(r)
                x = min(int(self.node_free[node]), e)
                self.node_free[node] -= x
                e -= x
        return victims

    def repair_claims(self, claims: List[Tuple[int, int]]) -> None:
        """Undo a failure's claims: capacity returns only down to the
        other claims still outstanding on each node."""
        for node, take in claims:
            cap = int(self.node_cap[node])
            old = min(cap, int(self.node_out[node]))
            self.node_out[node] = max(0, int(self.node_out[node]) - take)
            self.node_free[node] += old - min(cap, int(self.node_out[node]))

    def _lowest_row_on(self, node: int) -> int:
        pn = self._pool_n
        m = (self.span_node[:pn] == node) & (self.span_gpus[:pn] > 0)
        rows = self.span_row[:pn][m]
        return int(rows.min()) if rows.size else -1

    def rows_on_node(self, node: int) -> np.ndarray:
        pn = self._pool_n
        m = (self.span_node[:pn] == node) & (self.span_gpus[:pn] > 0)
        return np.unique(self.span_row[:pn][m])

    def cluster_dead(self, k: int) -> int:
        lo, hi = int(self.cluster_lo[k]), int(self.cluster_hi[k])
        return int(
            np.minimum(self.node_cap[lo:hi], self.node_out[lo:hi]).sum()
        )

    def cluster_free_vector(self) -> np.ndarray:
        return np.add.reduceat(self.node_free, self.cluster_lo)

    # ------------------------------------------------------ batched commit
    def release_many(self, rows: np.ndarray) -> None:
        """Batched ``release``: one span-pool gather for many rows at
        once.  Rows without a live span are skipped, like ``release``."""
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return
        rows = rows[(rows >= 0) & (rows < self.row_len.size)]
        rows = rows[self.row_len[rows] > 0]
        if rows.size == 0:
            return
        lens = self.row_len[rows]
        offs = self.row_off[rows]
        total = int(lens.sum())
        ends = np.cumsum(lens)
        sl = np.repeat(offs - (ends - lens), lens) + np.arange(total)
        nodes = self.span_node[sl]
        gpus = self.span_gpus[sl]
        # several rows can hold pieces on one node: aggregate first
        un, inv = np.unique(nodes, return_inverse=True)
        add = np.zeros(un.size, np.int64)
        np.add.at(add, inv, gpus)
        self.node_free[un] += add
        self.node_used[un] -= add
        self.span_gpus[sl] = 0
        self.span_row[sl] = -1
        self._garbage += total
        self.row_len[rows] = 0
        self.row_total[rows] = 0
        self.row_k[rows] = -1

    def assign_many(
        self, assigns: Sequence[Tuple[int, Sequence[int], Sequence[int]]]
    ) -> None:
        """Batched ``assign``: install many spans with one pool append,
        laid out exactly as the equivalent sequence of ``assign`` calls
        (pieces of each row contiguous, rows in list order)."""
        if not assigns:
            return
        na = len(assigns)
        rows = np.fromiter((a[0] for a in assigns), np.int64, na)
        counts = np.fromiter((len(a[1]) for a in assigns), np.int64, na)
        total = int(counts.sum())
        nodes = np.fromiter((x for a in assigns for x in a[1]), np.int64, total)
        gpus = np.fromiter((x for a in assigns for x in a[2]), np.int64, total)
        self._ensure_row(int(rows.max()))
        assert np.unique(rows).size == na, "duplicate rows in one plan"
        assert (self.row_len[rows] == 0).all(), "assign_many over live rows"
        assert (counts > 0).all() and (gpus > 0).all()
        self._pool_reserve(total)
        off = self._pool_n
        self.span_node[off : off + total] = nodes
        self.span_gpus[off : off + total] = gpus
        self.span_row[off : off + total] = np.repeat(rows, counts)
        self._pool_n = off + total
        starts = np.cumsum(counts) - counts
        self.row_off[rows] = off + starts
        self.row_len[rows] = counts
        self.row_total[rows] = np.add.reduceat(gpus, starts)
        self.row_k[rows] = self.node_cluster[nodes[starts]]
        un, inv = np.unique(nodes, return_inverse=True)
        take = np.zeros(un.size, np.int64)
        np.add.at(take, inv, gpus)
        self.node_free[un] -= take
        self.node_used[un] += take
        assert (self.node_free[un] >= 0).all(), (
            "node over-subscribed in assign_many"
        )

    # ------------------------------------------------------- fragmentation
    def stranded_gpus(self, queued_shapes: Sequence[Tuple[int, int]]) -> int:
        """Free GPUs sitting in holes no queued gang can use: for each
        cluster, free capacity on nodes with ``0 < free < min_piece``
        where ``min_piece`` is the smallest single-node piece any queued
        (demand, min_gpus) shape admits at that cluster's node size."""
        if not queued_shapes:
            return 0
        total = 0
        for k in range(self.n_clusters):
            gpn = int(self.cluster_gpn[k])
            mp = min(min_piece(d, m, gpn) for d, m in queued_shapes)
            seg = self.node_free[int(self.cluster_lo[k]) : int(self.cluster_hi[k])]
            total += int(seg[(seg > 0) & (seg < mp)].sum())
        return total

    # ----------------------------------------------------------- invariant
    def check(self) -> None:
        dead = np.minimum(self.node_cap, self.node_out)
        assert (self.node_free >= 0).all(), "negative node free count"
        assert (self.node_used >= 0).all(), "negative node used count"
        assert (self.node_free + self.node_used + dead == self.node_cap).all(), (
            "per-node conservation violated (free + used + dead != cap)"
        )
        pn = self._pool_n
        live = self.span_gpus[:pn] > 0
        used = np.zeros(self.node_cap.size, np.int64)
        np.add.at(used, self.span_node[:pn][live], self.span_gpus[:pn][live])
        assert (used == self.node_used).all(), "span pool != node_used"

    def overlay(self) -> "PlacementOverlay":
        return PlacementOverlay(self)


# ------------------------------------------------------- placement overlay
class PlacementOverlay:
    """A decide-pass view of node free counts: the policy releases and
    fits spans against the overlay without touching the NodeMap, and the
    accumulated plan (``released`` rows + ``assigns`` pieces) is committed
    by the simulator's ``_apply``.

    Per-cluster gang-feasibility stats (empty-node count, largest partial
    hole) are maintained *incrementally*: ``_hist[k][f]`` counts cluster
    ``k``'s nodes holding exactly ``f`` free GPUs, built with one bincount
    at overlay creation and bumped as every fit/release lands.  That makes
    ``feasible``/``_stats`` O(1) reads instead of per-query segment
    rescans — the property the batched decide core leans on to test a
    placement per changed job per tick.

    Two more structures keep the per-fit cost scalar instead of
    array-sized:

    * **Free-size buckets** — ``_buck[(k, f)]`` lazily materializes the
      index-ordered list of cluster-``k`` nodes holding exactly ``f``
      free GPUs (a sorted snapshot plus a heap of nodes pushed as their
      free count changes).  Entries are validated against ``free`` at
      pop time, so stale ones cost one discard instead of eager
      maintenance, and ``fit`` becomes a handful of list/heap ops.
    * **Lazy cluster max-heap** — ``pick_cluster`` answers the batched
      core's per-job ``argmax(cfree)``-over-feasible-clusters query
      from ``_cheap``, a heap of ``(-cfree, cluster)`` entries pushed
      on every capacity change and validated against the live mirror at
      pop time (stale entries cost one discard).  Heap order is exactly
      argmax order — cfree descending, index ascending on ties — so the
      first feasible head is the oracle's answer, usually after one or
      two probes; infeasible heads are stashed and pushed back.

    The python list ``_cfree`` is the authoritative per-cluster free
    count (the hot paths only touch lists); ``cfree`` is a property that
    lazily re-syncs a numpy view of it on read, so the loop oracle,
    phase A/C of the batched core, the defragmentation pass, and the
    tests still consume it vectorized."""

    __slots__ = (
        "nm",
        "free",
        "_cfree_np",
        "_dirty",
        "_cfree",
        "_cheap",
        "_gpn",
        "_bkey",
        "_hist",
        "_empty",
        "_maxp",
        "_buck",
        "released",
        "assigns",
    )

    def __init__(self, nm: NodeMap):
        self.nm = nm
        self.free = nm.node_free.copy()
        self._cfree_np = nm.cluster_free_vector().astype(np.int64)
        self._dirty = False
        k = nm.n_clusters
        gmax = int(nm.cluster_gpn.max()) if k else 0
        self._bkey = gmax + 1
        hist = np.bincount(
            nm.node_cluster * (gmax + 1) + self.free,
            minlength=k * (gmax + 1),
        ).reshape(k, gmax + 1)
        self._hist = [row.tolist() for row in hist]
        self._gpn = nm.cluster_gpn.tolist()
        self._empty = [self._hist[i][self._gpn[i]] for i in range(k)]
        self._maxp = [0] * k
        for kk in range(k):
            self._retally(kk)
        self._cfree = self._cfree_np.tolist()
        self._cheap = [(-v, c) for c, v in enumerate(self._cfree)]
        heapq.heapify(self._cheap)
        self._buck: dict = {}
        self.released: List[int] = []
        self.assigns: List[Optional[Tuple[int, List[int], List[int]]]] = []

    # ------------------------------------------------ incremental stats
    def _retally(self, k: int) -> None:
        """Largest partial hole from the histogram row — one
        O(gpus_per_node) scan, needed only when the bin holding the
        previous maximum empties."""
        h = self._hist[k]
        m = 0
        for f in range(1, self._gpn[k]):
            if h[f]:
                m = f
        self._maxp[k] = m

    def _move(self, k: int, j: int, old: int, new: int, popped: bool = False) -> None:
        """Node ``j`` moves ``old → new`` free GPUs: histogram bins, the
        empty/max-partial stats, and (when the buckets involved have
        already been built) a push into the ``new`` bucket so later fits
        can pop it in index order, plus a stale count on the ``old``
        bucket unless the caller obtained ``j`` by popping it (an
        unpopped leaver's entry lingers until a pop discards it)."""
        h = self._hist[k]
        h[old] -= 1
        h[new] += 1
        gpn = self._gpn[k]
        if old == gpn:
            self._empty[k] -= 1
        if new == gpn:
            self._empty[k] += 1
        if 0 < new < gpn and new > self._maxp[k]:
            self._maxp[k] = new
        elif 0 < old < gpn and old == self._maxp[k] and h[old] == 0:
            self._retally(k)
        buck = self._buck
        if not popped and old > 0:
            bo = buck.get(k * self._bkey + old)
            if bo is not None:
                bo[3] += 1
        if new > 0:
            b = buck.get(k * self._bkey + new)
            if b is not None:
                heapq.heappush(b[2], j)

    # --------------------------------------------- cluster capacity mirror
    @property
    def cfree(self) -> np.ndarray:
        """Per-cluster free GPUs as a numpy vector, re-synced from the
        authoritative python list on read when a fit/release dirtied it.
        The array object is stable across the overlay's lifetime."""
        arr = self._cfree_np
        if self._dirty:
            arr[:] = self._cfree
            self._dirty = False
        return arr

    def _cfree_dec(self, k: int, d: int) -> None:
        """Consume ``d`` free GPUs on cluster ``k`` and push the new
        value onto the pick heap."""
        v = self._cfree[k] = self._cfree[k] - d
        self._dirty = True
        heapq.heappush(self._cheap, (-v, k))

    def _cfree_inc(self, k: int, d: int) -> None:
        """Return ``d`` free GPUs to cluster ``k``."""
        v = self._cfree[k] = self._cfree[k] + d
        self._dirty = True
        heapq.heappush(self._cheap, (-v, k))

    # ------------------------------------------------- free-size buckets
    def _bucket(self, k: int, f: int) -> list:
        key = k * self._bkey + f
        b = self._buck.get(key)
        if b is None:
            nm = self.nm
            lo = int(nm.cluster_lo[k])
            hi = int(nm.cluster_hi[k])
            arr = np.flatnonzero(self.free[lo:hi] == f) + lo
            # [sorted base snapshot, base ptr, late-push heap,
            #  stale count, base snapshot as an array (for view writes)]
            b = [arr.tolist(), 0, [], 0, arr]
            self._buck[key] = b
        return b

    def _pop_node(self, k: int, f: int) -> int:
        """Pop the lowest-index cluster-``k`` node currently holding
        exactly ``f`` free GPUs (-1 if none).  Candidates are validated
        lazily against ``free``: a popped entry whose free count moved
        on since it was recorded costs one discard, which keeps pushes
        unconditional and the snapshot base maintenance-free.  The
        bucket's stale count tracks discards-to-come exactly, so a
        zero-stale bucket can be consumed by slicing (see ``fit``)."""
        b = self._bucket(k, f)
        base, extra = b[0], b[2]
        free = self.free
        nb = len(base)
        while True:
            p = b[1]
            if p < nb:
                j = base[p]
                if extra and extra[0] < j:
                    j = heapq.heappop(extra)
                else:
                    b[1] = p + 1
            elif extra:
                j = heapq.heappop(extra)
            else:
                return -1
            if free[j] == f:
                return j
            b[3] -= 1

    # -------------------------------------------------- release and undo
    def release_row(self, row: int) -> None:
        nm = self.nm
        nodes, gpus = nm.row_pieces(row)
        if nodes.size:
            free = self.free
            ks = nm.node_cluster[nodes]
            cadd: dict = {}
            for j, kk, g in zip(nodes.tolist(), ks.tolist(), gpus.tolist()):
                old = int(free[j])
                free[j] = old + g
                cadd[kk] = cadd.get(kk, 0) + g
                self._move(kk, j, old, old + g)
            for kk, g in cadd.items():
                self._cfree_inc(kk, g)
        self.released.append(int(row))

    def release_rows(self, rows: np.ndarray) -> None:
        """Release many rows with one span-pool gather, appending to
        ``released`` in input order — the batched decide core's
        replacement for a per-row ``release_row`` loop."""
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return
        nm = self.nm
        lens = nm.row_len[rows]
        offs = nm.row_off[rows]
        total = int(lens.sum())
        if total:
            ends = np.cumsum(lens)
            sl = np.repeat(offs - (ends - lens), lens) + np.arange(total)
            nodes = nm.span_node[sl]
            gpus = nm.span_gpus[sl]
            # one node can carry pieces of several rows: aggregate first
            un, inv = np.unique(nodes, return_inverse=True)
            add = np.zeros(un.size, np.int64)
            np.add.at(add, inv, gpus)
            free = self.free
            ks = nm.node_cluster[un]
            cadd: dict = {}
            for j, kk, g in zip(un.tolist(), ks.tolist(), add.tolist()):
                old = int(free[j])
                free[j] = old + g
                cadd[kk] = cadd.get(kk, 0) + g
                self._move(kk, j, old, old + g)
            for kk, g in cadd.items():
                self._cfree_inc(kk, g)
        self.released.extend(int(r) for r in rows)

    def undo(self, idx: int) -> None:
        """Reverse a fit made earlier this pass (the entry is tombstoned;
        the caller filters ``assigns`` before committing)."""
        row, nodes, gpus = self.assigns[idx]
        free = self.free
        ncl = self.nm.node_cluster
        for j, g in zip(nodes, gpus):
            old = int(free[j])
            free[j] = old + g
            kk = int(ncl[j])
            self._cfree_inc(kk, g)
            self._move(kk, j, old, old + g)
        self.assigns[idx] = None

    # ------------------------------------------------ feasibility queries
    def _stats(self, k: int) -> Tuple[int, int]:
        return self._empty[k], self._maxp[k]

    def feasible(self, k: int, g: int) -> bool:
        """Can cluster ``k`` host a gang of ``g`` as ``w`` full nodes plus
        one remainder piece?"""
        gpn = self._gpn[k]
        w, r = divmod(int(g), gpn)
        empty = self._empty[k]
        if empty < w:
            return False
        return r == 0 or self._maxp[k] >= r or empty >= w + 1

    def feasible_vec(self, g: int) -> np.ndarray:
        """``feasible`` for every cluster at once — one vector expression
        over the maintained stats.  The batched core walks
        ``pick_cluster`` instead; this remains the loop oracle's (and
        the tests') view."""
        gpn = self.nm.cluster_gpn
        w = g // gpn
        r = g - w * gpn
        empty = np.asarray(self._empty, np.int64)
        maxp = np.asarray(self._maxp, np.int64)
        return (empty >= w) & ((r == 0) | (maxp >= r) | (empty >= w + 1))

    def best_value(self, k: int, demand: int, lo: int, hi: int) -> int:
        """Largest splice-compatible world size in ``[lo, hi]`` that
        cluster ``k`` can host (0 if none)."""
        for v in gang_values(int(demand), int(lo), int(hi)):
            if self.feasible(k, v):
                return v
        return 0

    # --------------------------------------------------- cluster picking
    def best_cluster(self) -> int:
        """``argmax(cfree)`` (lowest index on ties)."""
        best = -1
        bestv = -1
        for c, v in enumerate(self._cfree):
            if v > bestv:
                best, bestv = c, v
        return best

    def best_healthy(self, drain: Sequence[bool]) -> int:
        """``argmax(cfree)`` over non-draining clusters (lowest index on
        ties); -1 when every cluster is draining."""
        best = -1
        bestv = -1
        for c, v in enumerate(self._cfree):
            if v > bestv and not drain[c]:
                best, bestv = c, v
        return best

    def pick_cluster(
        self,
        g: int,
        drain: Optional[Sequence[bool]] = None,
        want_region: int = -1,
        creg: Optional[Sequence[int]] = None,
    ) -> int:
        """The batched core's pool pick: the max-``cfree`` cluster
        (lowest index on ties) passing the oracle's pool filters.

        Stage 1 considers gang-feasible clusters; stage 2 (when no
        cluster is gang-feasible) accepts aggregate capacity
        ``cfree >= g``.  ``drain`` soft-excludes draining clusters when
        a non-draining candidate exists; ``want_region`` (with ``creg``,
        cluster→region codes) soft-prefers a running job's current
        region within whatever pool survives the drain filter.  Each
        preference is dropped, not enforced, when it can't be met —
        byte-for-byte the oracle's nested ``pool``-masking followed by
        ``argmax(where(pool, cfree, -1))``, whose ties break to the
        lowest index.  Returns -1 when even aggregate capacity is
        missing everywhere.

        The unfiltered query pops the lazy max-heap: heads whose entry
        no longer matches the live ``cfree`` mirror are discarded, the
        first feasible valid head is the answer, and valid-but-
        infeasible heads are stashed and pushed back — so the usual
        pick costs one or two probes, not a K-cluster scan."""
        g = int(g)
        if drain is not None or want_region >= 0:
            k = self._pick_filtered(g, drain, want_region, creg, True)
            if k >= 0:
                return k
            return self._pick_filtered(g, drain, want_region, creg, False)
        cf = self._cfree
        heap = self._cheap
        empty = self._empty
        maxp = self._maxp
        gpnl = self._gpn
        found = -1
        stash = None
        while heap:
            v, c = heap[0]
            if cf[c] != -v:
                heapq.heappop(heap)  # stale (or duplicate) entry
                continue
            gpn = gpnl[c]
            w = g // gpn
            r = g - w * gpn
            e = empty[c]
            if e >= w and (r == 0 or maxp[c] >= r or e > w):
                found = c
                break
            if stash is None:
                stash = []
            stash.append(heapq.heappop(heap))
        if stash:
            for e in stash:
                heapq.heappush(heap, e)
        if found >= 0:
            return found
        # stage 2: scattered fill wherever aggregate capacity fits
        best = -1
        bestv = g - 1
        for c, v in enumerate(cf):
            if v > bestv:
                best, bestv = c, v
        return best

    def _pick_filtered(
        self,
        g: int,
        drain: Optional[Sequence[bool]],
        want_region: int,
        creg: Optional[Sequence[int]],
        gang: bool,
    ) -> int:
        """One filtered scan: the argmax candidate under each surviving
        preference combination, resolved exactly as the oracle's pool
        masking does."""
        feasible = self.feasible
        best = b_nd = b_sr = b_sr_nd = -1
        bv = b_nd_v = b_sr_v = b_sr_nd_v = -1
        for c, v in enumerate(self._cfree):
            if gang:
                if not feasible(c, g):
                    continue
            elif v < g:
                continue
            if v > bv:
                best, bv = c, v
            nd = drain is None or not drain[c]
            if nd and v > b_nd_v:
                b_nd, b_nd_v = c, v
            if want_region >= 0 and creg[c] == want_region:
                if v > b_sr_v:
                    b_sr, b_sr_v = c, v
                if nd and v > b_sr_nd_v:
                    b_sr_nd, b_sr_nd_v = c, v
        if best < 0:
            return -1
        if drain is not None and b_nd >= 0:
            if want_region >= 0 and b_sr_nd >= 0:
                return b_sr_nd
            return b_nd
        if want_region >= 0 and b_sr >= 0:
            return b_sr
        return best

    # --------------------------------------------------------------- fits
    def fit_any(self, row: int, k: int, g: int) -> None:
        """Place a gang that fits the cluster's aggregate free capacity:
        the clean shape (``fit``) when feasible, else a scattered fill —
        largest holes first (lowest node index on ties, pinned by a
        stable sort), which minimizes the piece count.  The device-proxy
        makes scattered placement legal; it is merely the low-locality
        fallback the defragmentation pass exists to avoid."""
        g = int(g)
        gpn = self._gpn[k]
        w = g // gpn
        r = g - w * gpn
        empty = self._empty[k]
        if empty >= w and (r == 0 or self._maxp[k] >= r or empty > w):
            self._fit_shaped(row, k, g, gpn, w, r)
            return
        nm = self.nm
        lo, hi = int(nm.cluster_lo[k]), int(nm.cluster_hi[k])
        seg = self.free[lo:hi]
        order = np.argsort(-seg, kind="stable")
        nodes: List[int] = []
        gpus: List[int] = []
        rem = int(g)
        for j in order:
            take = min(rem, int(seg[j]))
            if take <= 0:
                break
            nodes.append(lo + int(j))
            gpus.append(take)
            old = int(seg[j])
            seg[j] -= take
            self._move(k, lo + int(j), old, old - take)
            rem -= take
            if rem == 0:
                break
        assert rem == 0, "fit_any() without aggregate capacity"
        self._cfree_dec(k, int(g))
        self.assigns.append((row, nodes, gpus))

    def fit(self, row: int, k: int, g: int) -> None:
        """Place a feasible gang: full pieces on the lowest-index empty
        nodes, the remainder best-fit into the smallest sufficient
        partial hole (lowest index on ties; the next empty node when no
        partial hole fits).  The best-fit hole size comes straight from
        the histogram, and each node comes from a bucket pop — no
        candidate scan over the segment."""
        g = int(g)
        gpn = self._gpn[k]
        w = g // gpn
        self._fit_shaped(row, k, g, gpn, w, g - w * gpn)

    def _fit_shaped(
        self, row: int, k: int, g: int, gpn: int, w: int, r: int
    ) -> None:
        free = self.free
        nodes: List[int] = []
        gpus: List[int] = []
        h = self._hist[k]
        if w:
            # inline bulk pop: drain the empty-node bucket in index
            # order with one bucket fetch for the whole gang
            b = self._buck.get(k * self._bkey + gpn)
            if b is None:
                b = self._bucket(k, gpn)
            base, extra = b[0], b[2]
            p = b[1]
            if not extra and not b[3] and len(base) - p >= w:
                # exact bucket, no late pushes: the next w base entries
                # ARE the w lowest-index empties — consume by slice and
                # zero their free counts in one array-view fancy write
                nodes = base[p : p + w]
                b[1] = p + w
                free[b[4][p : p + w]] = 0
            else:
                nb = len(base)
                take = 0
                while take < w:
                    p = b[1]
                    if extra and (p >= nb or extra[0] < base[p]):
                        j = heapq.heappop(extra)
                    else:
                        assert p < nb, "fit() without feasibility"
                        j = base[p]
                        b[1] = p + 1
                    if free[j] == gpn:
                        free[j] = 0
                        nodes.append(j)
                        take += 1
                    else:
                        b[3] -= 1
            gpus = [gpn] * w
            h[gpn] -= w
            h[0] += w
            self._empty[k] -= w
        if r:
            f = 0
            for b in range(r, gpn):
                if h[b]:
                    f = b
                    break
            if f:
                j = self._pop_node(k, f)
                assert j >= 0, "fit() without feasibility"
                free[j] = f - r
                self._move(k, j, f, f - r, popped=True)
            else:
                j = self._pop_node(k, gpn)
                assert j >= 0, "fit() without feasibility"
                free[j] = gpn - r
                self._move(k, j, gpn, gpn - r, popped=True)
            nodes.append(j)
            gpus.append(r)
        self._cfree_dec(k, int(g))
        self.assigns.append((row, nodes, gpus))

    def fit_batch(self, rows: np.ndarray, ks: np.ndarray, gs: np.ndarray) -> None:
        """Sequentially-equivalent batch fit: exactly one ``fit_any`` per
        item, in order, appending one assign each — but runs of identical
        (cluster, whole-node gang) items collapse into a single
        empty-node slice.  Consecutive shaped whole-node fits each take
        the next lowest-index empties, so the slice IS the sequential
        answer; items past the run's empty budget fall back to the
        per-item path (scattered fill), exactly as the loop would."""
        n = len(rows)
        i = 0
        while i < n:
            k = int(ks[i])
            g = int(gs[i])
            gpn = self._gpn[k]
            w, r = divmod(g, gpn)
            if r == 0 and w > 0:
                j = i + 1
                while j < n and int(ks[j]) == k and int(gs[j]) == g:
                    j += 1
                m = min(j - i, self._empty[k] // w)
                if m > 0:
                    lo = int(self.nm.cluster_lo[k])
                    hi = int(self.nm.cluster_hi[k])
                    seg = self.free[lo:hi]
                    empt = np.flatnonzero(seg == gpn)[: m * w]
                    seg[empt] = 0
                    bb = self._buck.get(k * self._bkey + gpn)
                    if bb is not None:
                        # consumed without popping: their bucket entries
                        # (if the bucket predates this call) linger
                        bb[3] += m * w
                    h = self._hist[k]
                    h[gpn] -= m * w
                    h[0] += m * w
                    self._empty[k] -= m * w
                    self._cfree_dec(k, m * g)
                    whole = [gpn] * w
                    for t in range(m):
                        ns = [lo + int(x) for x in empt[t * w : (t + 1) * w]]
                        self.assigns.append((int(rows[i + t]), ns, list(whole)))
                for t in range(i + m, j):
                    self.fit_any(int(rows[t]), k, int(gs[t]))
                i = j
            else:
                self.fit_any(int(rows[i]), k, g)
                i += 1

"""Fleet-wide node-granular placement state (the NodeMap).

Placement used to stop at cluster granularity: a job carried a
``cluster_idx`` scalar, and everything below it — which nodes the
replicas actually sit on — was approximated.  Partial-domain failures
picked victims by (arrival, id) packing order, gang/splice constraints
were invisible to placement, and fragmentation could not even be
measured.  The NodeMap makes the node layer real, with the same
struct-of-arrays recipe as ``JobTable``/``FleetSLAAccounts``:

**Node axis** (one entry per node, laid out cluster-contiguously in
``fleet.clusters()`` order; a trailing partial node keeps its TRUE
smaller capacity):

- ``node_cap``      — GPUs physically on the node
- ``node_cluster``  — owning cluster index
- ``node_free``     — GPUs idle and healthy
- ``node_used``     — GPUs held by live job spans
- ``node_out``      — UNCLAMPED sum of outstanding failure claims; dead
  capacity is ``min(cap, out)`` so overlapping failures never resurrect
  capacity when the shorter one repairs first (the cluster-level
  ``_outstanding`` rule, per node)

The invariant ``free + used + min(cap, out) == cap`` holds per node at
every tick and is asserted by :meth:`NodeMap.check`.

**Row axis** (one row per job, row index == the driver's table slot /
trace index): ``row_off``/``row_len`` address a piece pool
(``span_node``/``span_gpus``/``span_row``) holding the job's node span —
the list of (node, gpus) pieces it occupies.  Rows grow by doubling and
are reused after release; the pool is bump-allocated and compacted when
more than half of it is garbage.

**Gang/splice compatibility.**  A job that demands ``D`` GPUs can only
run at world sizes the device-proxy splice supports: divisors of ``D``
(time-sliced shrink) or multiples of ``D`` (scale-out).  ``gang_down``
rounds an arbitrary grant to the largest compatible value below it; the
placement overlay only ever fits compatible gangs, shaped as ``w`` full
nodes plus one remainder piece ``r = g % gpus_per_node`` on a best-fit
partial node (smallest sufficient free count, lowest index on ties).

**Fragmentation.**  A free GPU is *stranded* when it sits in a hole too
small to host the smallest single-node piece any queued gang could use
(``min_piece``).  ``stranded_gpus`` is the fleet-wide count, reported
time-averaged in ``SimResult.fragmentation_stranded_gpus``; the
simulator's defragmentation pass consolidates such holes when the freed
capacity is worth the charged migration downtime (``costs.defrag_worthwhile``).
"""
from __future__ import annotations

from functools import lru_cache
from typing import TYPE_CHECKING, List, Optional, Sequence, Tuple

import numpy as np

if TYPE_CHECKING:  # avoid the import cycle: types builds the NodeMap
    from repro.scheduler.types import Fleet


# --------------------------------------------------------- gang arithmetic
@lru_cache(maxsize=None)
def splice_divisors(demand: int) -> Tuple[int, ...]:
    """Ascending divisors of ``demand`` — the shrink-side world sizes the
    splice mechanism supports (§5.4)."""
    d = max(1, int(demand))
    return tuple(k for k in range(1, d + 1) if d % k == 0)


def gang_down(g: int, demand: int) -> int:
    """Largest splice-compatible world size at or below ``g`` (0 if none):
    a multiple of ``demand`` when ``g >= demand``, else the largest
    divisor of ``demand`` below it."""
    if g <= 0:
        return 0
    if g >= demand:
        return g - g % demand
    divs = splice_divisors(demand)
    lo = 0
    for d in divs:
        if d > g:
            break
        lo = d
    return lo


def gang_down_vec(galloc: np.ndarray, demand: np.ndarray) -> np.ndarray:
    """Vector ``gang_down`` over per-job grants: multiples round in one
    modulo pass; sub-demand grants loop over the (few) unique demands,
    each resolved with one searchsorted against its divisor table."""
    out = galloc.copy()
    pos = galloc > 0
    ge = pos & (galloc >= demand)
    if ge.any():
        out[ge] = galloc[ge] - galloc[ge] % demand[ge]
    lt = pos & ~ge
    if lt.any():
        for d in np.unique(demand[lt]):
            m = lt & (demand == d)
            divs = np.asarray(splice_divisors(int(d)), np.int64)
            out[m] = divs[np.searchsorted(divs, galloc[m], side="right") - 1]
    return out


@lru_cache(maxsize=None)
def gang_values(demand: int, lo: int, hi: int) -> Tuple[int, ...]:
    """Splice-compatible world sizes in ``[lo, hi]``, descending — the
    candidate ladder for shrink-to-hole placement."""
    vals = [d for d in splice_divisors(demand) if lo <= d <= hi and d < demand]
    m = demand
    while m <= hi:
        if m >= lo:
            vals.append(m)
        m += demand
    return tuple(sorted(vals, reverse=True))


@lru_cache(maxsize=None)
def floor_gang(demand: int, min_gpus: int) -> int:
    """Smallest splice-compatible world size at or above ``min_gpus``
    (0 if none) — the smallest gang a queued job could be admitted at,
    the shape the defragmentation pass tries to unblock."""
    d = max(1, int(demand))
    lo = max(1, int(min_gpus))
    hi = d * -(-lo // d)  # first multiple of demand at or above the floor
    vals = gang_values(d, lo, max(hi, lo))
    return vals[-1] if vals else 0


@lru_cache(maxsize=None)
def min_piece(demand: int, min_gpus: int, gpus_per_node: int) -> int:
    """Smallest single-node piece any admissible gang of this job could
    occupy: over every compatible world size ``g >= min_gpus``, the
    smallest of its node pieces (``g`` itself below a node, else the
    remainder ``g % gpus_per_node`` or a full node).  Free capacity in a
    hole smaller than this can never serve the job — it is stranded."""
    gpn = max(1, int(gpus_per_node))
    lo = max(1, int(min_gpus))
    best = gpn
    for g in gang_values(int(demand), lo, 2 * max(int(demand), lo)):
        if g < gpn:
            piece = g
        else:
            r = g % gpn
            piece = r if r else gpn
        if piece < best:
            best = piece
    return best


# ---------------------------------------------------------------- NodeMap
class NodeMap:
    """Simulator-owned SoA of per-node capacity and per-job node spans."""

    def __init__(
        self,
        node_cap: np.ndarray,
        node_cluster: np.ndarray,
        cluster_lo: np.ndarray,
        cluster_hi: np.ndarray,
        cluster_gpn: np.ndarray,
        capacity_rows: int = 64,
    ):
        self.node_cap = node_cap.astype(np.int64)
        self.node_cluster = node_cluster.astype(np.int64)
        self.node_free = self.node_cap.copy()
        self.node_used = np.zeros_like(self.node_cap)
        self.node_out = np.zeros_like(self.node_cap)
        self.cluster_lo = cluster_lo.astype(np.int64)
        self.cluster_hi = cluster_hi.astype(np.int64)
        self.cluster_gpn = cluster_gpn.astype(np.int64)
        self.n_clusters = int(cluster_lo.size)
        rows = max(1, int(capacity_rows))
        self.row_off = np.zeros(rows, np.int64)
        self.row_len = np.zeros(rows, np.int64)
        self.row_total = np.zeros(rows, np.int64)
        self.row_k = np.full(rows, -1, np.int64)
        pool = max(4, 2 * rows)
        self.span_node = np.zeros(pool, np.int64)
        self.span_gpus = np.zeros(pool, np.int64)
        self.span_row = np.full(pool, -1, np.int64)
        self._pool_n = 0
        self._garbage = 0

    @classmethod
    def from_fleet(cls, fleet: "Fleet", capacity_rows: int = 64) -> "NodeMap":
        caps: List[int] = []
        owner: List[int] = []
        lo: List[int] = []
        hi: List[int] = []
        gpn: List[int] = []
        for k, c in enumerate(fleet.clusters()):
            nc = c.node_capacities()
            lo.append(len(caps))
            caps.extend(nc)
            hi.append(len(caps))
            owner.extend([k] * len(nc))
            gpn.append(max(1, c.gpus_per_node))
        return cls(
            np.asarray(caps, np.int64),
            np.asarray(owner, np.int64),
            np.asarray(lo, np.int64),
            np.asarray(hi, np.int64),
            np.asarray(gpn, np.int64),
            capacity_rows=capacity_rows,
        )

    # ---------------------------------------------------------- row spans
    def _ensure_row(self, row: int) -> None:
        n = self.row_len.size
        if row < n:
            return
        m = max(64, n)
        while m <= row:
            m *= 2
        grow = m - n
        self.row_off = np.concatenate([self.row_off, np.zeros(grow, np.int64)])
        self.row_len = np.concatenate([self.row_len, np.zeros(grow, np.int64)])
        self.row_total = np.concatenate([self.row_total, np.zeros(grow, np.int64)])
        self.row_k = np.concatenate([self.row_k, np.full(grow, -1, np.int64)])

    def _pool_reserve(self, extra: int) -> None:
        need = self._pool_n + extra
        cap = self.span_node.size
        if need <= cap:
            return
        if self._garbage > self._pool_n // 2:
            self._compact()
            need = self._pool_n + extra
            if need <= self.span_node.size:
                return
            cap = self.span_node.size
        m = max(4, cap)
        while m < need:
            m *= 2
        pad = m - cap
        self.span_node = np.concatenate([self.span_node, np.zeros(pad, np.int64)])
        self.span_gpus = np.concatenate([self.span_gpus, np.zeros(pad, np.int64)])
        self.span_row = np.concatenate([self.span_row, np.full(pad, -1, np.int64)])

    def _compact(self) -> None:
        pn = self._pool_n
        keep = self.span_gpus[:pn] > 0
        node = self.span_node[:pn][keep]
        gpus = self.span_gpus[:pn][keep]
        rows = self.span_row[:pn][keep]
        live = int(node.size)
        self.span_node[:live] = node
        self.span_gpus[:live] = gpus
        self.span_row[:live] = rows
        self.span_gpus[live:pn] = 0
        self.span_row[live:pn] = -1
        self._pool_n = live
        self._garbage = 0
        # pieces of one row stay contiguous under a stable filter; each
        # live row owns exactly one run, so boundaries are value changes
        if live:
            change = np.flatnonzero(np.diff(rows) != 0) + 1
            starts = np.concatenate(([0], change))
            self.row_off[rows[starts]] = starts

    def has_span(self, row: int) -> bool:
        return 0 <= row < self.row_len.size and self.row_len[row] > 0

    def span_total(self, row: int) -> int:
        if not self.has_span(row):
            return 0
        return int(self.row_total[row])

    def span_cluster(self, row: int) -> int:
        if not self.has_span(row):
            return -1
        return int(self.row_k[row])

    def row_pieces(self, row: int) -> Tuple[np.ndarray, np.ndarray]:
        if not self.has_span(row):
            return np.empty(0, np.int64), np.empty(0, np.int64)
        sl = slice(int(self.row_off[row]), int(self.row_off[row] + self.row_len[row]))
        return self.span_node[sl], self.span_gpus[sl]

    def row_state(
        self, rows: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(has_span, span_cluster, span_total) gathered for many rows at
        once — the decide path's zero-Python span lookup."""
        safe = (rows >= 0) & (rows < self.row_len.size)
        rr = np.where(safe, rows, 0)
        has = safe & (self.row_len[rr] > 0)
        tot = np.where(has, self.row_total[rr], 0)
        k = np.where(has, self.row_k[rr], -1)
        return has, k, tot

    def assign(self, row: int, nodes: Sequence[int], gpus: Sequence[int]) -> None:
        """Install a span (one piece per distinct node).  ``release`` any
        previous span first."""
        self._ensure_row(row)
        assert self.row_len[row] == 0, f"row {row} already holds a span"
        nodes = np.asarray(nodes, np.int64)
        gpus = np.asarray(gpus, np.int64)
        n = int(nodes.size)
        assert n > 0 and (gpus > 0).all()
        self._pool_reserve(n)
        off = self._pool_n
        self.span_node[off : off + n] = nodes
        self.span_gpus[off : off + n] = gpus
        self.span_row[off : off + n] = row
        self._pool_n = off + n
        self.row_off[row] = off
        self.row_len[row] = n
        self.row_total[row] = int(gpus.sum())
        self.row_k[row] = int(self.node_cluster[nodes[0]])
        self.node_free[nodes] -= gpus
        self.node_used[nodes] += gpus
        assert (self.node_free[nodes] >= 0).all(), (
            f"node over-subscribed placing row {row}"
        )

    def release(self, row: int) -> None:
        if not self.has_span(row):
            return
        ln = int(self.row_len[row])
        sl = slice(int(self.row_off[row]), int(self.row_off[row]) + ln)
        nodes = self.span_node[sl]
        gpus = self.span_gpus[sl]
        self.node_free[nodes] += gpus
        self.node_used[nodes] -= gpus
        self.span_gpus[sl] = 0
        self.span_row[sl] = -1
        self._garbage += ln
        self.row_len[row] = 0
        self.row_total[row] = 0
        self.row_k[row] = -1

    def live_rows(self) -> np.ndarray:
        return np.flatnonzero(self.row_len > 0)

    def auto_fit(self, row: int, k: int, gpus: int) -> None:
        """Lowest-index greedy fill ignoring gang shape — the fallback
        span for policies that do not plan node placement (the static
        gang baseline, hand-written policies).  Asserts the cluster can
        hold the grant: per-node conservation rejects over-allocation
        even for planless policies."""
        lo, hi = int(self.cluster_lo[k]), int(self.cluster_hi[k])
        seg = self.node_free[lo:hi]
        nodes: List[int] = []
        take: List[int] = []
        rem = int(gpus)
        for j in np.flatnonzero(seg > 0):
            t = min(rem, int(seg[j]))
            nodes.append(lo + int(j))
            take.append(t)
            rem -= t
            if rem == 0:
                break
        assert rem == 0, (
            f"cluster {k} over-allocated: no node capacity for {gpus} GPUs"
        )
        self.assign(row, nodes, take)

    def move_piece(self, row: int, from_node: int, to_node: int) -> int:
        """Defragmentation move: relocate this row's piece off
        ``from_node`` onto ``to_node`` (merging with an existing piece
        there).  Returns the GPUs moved."""
        nodes, gpus = self.row_pieces(row)
        pieces = {int(n): int(g) for n, g in zip(nodes, gpus)}
        g = pieces.pop(int(from_node))
        pieces[int(to_node)] = pieces.get(int(to_node), 0) + g
        self.release(row)
        self.assign(row, list(pieces.keys()), list(pieces.values()))
        return g

    # ------------------------------------------------------ failure claims
    def fail_claims(self, k: int, want: int) -> List[Tuple[int, int]]:
        """Per-node claim list for a failure of ``want`` GPUs on cluster
        ``k``.  A whole-domain failure claims every node's full capacity
        UNCLAMPED (so it owns the capacity regardless of prior claims);
        a partial failure claims currently-claimable capacity ascending
        by node index, any unclaimable leftover landing on the first
        node for bookkeeping symmetry."""
        lo, hi = int(self.cluster_lo[k]), int(self.cluster_hi[k])
        caps = self.node_cap[lo:hi]
        if want >= int(caps.sum()):
            return [(lo + i, int(caps[i])) for i in range(hi - lo)]
        claims: List[Tuple[int, int]] = []
        remaining = int(want)
        for i in range(lo, hi):
            if remaining <= 0:
                break
            cap = int(self.node_cap[i])
            avail = cap - min(cap, int(self.node_out[i]))
            take = min(avail, remaining)
            if take > 0:
                claims.append((i, take))
                remaining -= take
        if remaining > 0:
            claims.append((lo, remaining))
        return claims

    def apply_claims(self, claims: List[Tuple[int, int]]) -> List[int]:
        """Kill capacity per the claim list.  Each node's effective dead
        increase eats free GPUs first, then kills jobs with pieces on the
        node in ascending row order (the whole gang dies; its span is
        released everywhere).  Returns the victim rows."""
        victims: List[int] = []
        for node, take in claims:
            cap = int(self.node_cap[node])
            old = min(cap, int(self.node_out[node]))
            self.node_out[node] += take
            e = min(cap, int(self.node_out[node])) - old
            x = min(int(self.node_free[node]), e)
            self.node_free[node] -= x
            e -= x
            while e > 0:
                r = self._lowest_row_on(node)
                assert r >= 0, f"node {node}: dead exceeds free+used"
                self.release(r)
                victims.append(r)
                x = min(int(self.node_free[node]), e)
                self.node_free[node] -= x
                e -= x
        return victims

    def repair_claims(self, claims: List[Tuple[int, int]]) -> None:
        """Undo a failure's claims: capacity returns only down to the
        other claims still outstanding on each node."""
        for node, take in claims:
            cap = int(self.node_cap[node])
            old = min(cap, int(self.node_out[node]))
            self.node_out[node] = max(0, int(self.node_out[node]) - take)
            self.node_free[node] += old - min(cap, int(self.node_out[node]))

    def _lowest_row_on(self, node: int) -> int:
        pn = self._pool_n
        m = (self.span_node[:pn] == node) & (self.span_gpus[:pn] > 0)
        rows = self.span_row[:pn][m]
        return int(rows.min()) if rows.size else -1

    def rows_on_node(self, node: int) -> np.ndarray:
        pn = self._pool_n
        m = (self.span_node[:pn] == node) & (self.span_gpus[:pn] > 0)
        return np.unique(self.span_row[:pn][m])

    def cluster_dead(self, k: int) -> int:
        lo, hi = int(self.cluster_lo[k]), int(self.cluster_hi[k])
        return int(
            np.minimum(self.node_cap[lo:hi], self.node_out[lo:hi]).sum()
        )

    def cluster_free_vector(self) -> np.ndarray:
        return np.add.reduceat(self.node_free, self.cluster_lo)

    # ------------------------------------------------------- fragmentation
    def stranded_gpus(self, queued_shapes: Sequence[Tuple[int, int]]) -> int:
        """Free GPUs sitting in holes no queued gang can use: for each
        cluster, free capacity on nodes with ``0 < free < min_piece``
        where ``min_piece`` is the smallest single-node piece any queued
        (demand, min_gpus) shape admits at that cluster's node size."""
        if not queued_shapes:
            return 0
        total = 0
        for k in range(self.n_clusters):
            gpn = int(self.cluster_gpn[k])
            mp = min(min_piece(d, m, gpn) for d, m in queued_shapes)
            seg = self.node_free[int(self.cluster_lo[k]) : int(self.cluster_hi[k])]
            total += int(seg[(seg > 0) & (seg < mp)].sum())
        return total

    # ----------------------------------------------------------- invariant
    def check(self) -> None:
        dead = np.minimum(self.node_cap, self.node_out)
        assert (self.node_free >= 0).all(), "negative node free count"
        assert (self.node_used >= 0).all(), "negative node used count"
        assert (self.node_free + self.node_used + dead == self.node_cap).all(), (
            "per-node conservation violated (free + used + dead != cap)"
        )
        pn = self._pool_n
        live = self.span_gpus[:pn] > 0
        used = np.zeros(self.node_cap.size, np.int64)
        np.add.at(used, self.span_node[:pn][live], self.span_gpus[:pn][live])
        assert (used == self.node_used).all(), "span pool != node_used"

    def overlay(self) -> "PlacementOverlay":
        return PlacementOverlay(self)


# ------------------------------------------------------- placement overlay
class PlacementOverlay:
    """A decide-pass view of node free counts: the policy releases and
    fits spans against the overlay without touching the NodeMap, and the
    accumulated plan (``released`` rows + ``assigns`` pieces) is committed
    by the simulator's ``_apply``.  Per-cluster gang-feasibility stats
    (empty-node count, largest partial hole) are numpy segment reductions,
    cached and recomputed only for clusters the pass dirtied."""

    __slots__ = (
        "nm",
        "free",
        "cfree",
        "_empty",
        "_maxp",
        "_dirty",
        "released",
        "assigns",
    )

    def __init__(self, nm: NodeMap):
        self.nm = nm
        self.free = nm.node_free.copy()
        self.cfree = nm.cluster_free_vector().astype(np.int64)
        k = nm.n_clusters
        self._empty = np.zeros(k, np.int64)
        self._maxp = np.zeros(k, np.int64)
        self._dirty = np.ones(k, bool)
        self.released: List[int] = []
        self.assigns: List[Optional[Tuple[int, List[int], List[int]]]] = []

    def release_row(self, row: int) -> None:
        nm = self.nm
        nodes, gpus = nm.row_pieces(row)
        if nodes.size:
            self.free[nodes] += gpus
            ks = nm.node_cluster[nodes]
            np.add.at(self.cfree, ks, gpus)
            self._dirty[np.unique(ks)] = True
        self.released.append(row)

    def _stats(self, k: int) -> Tuple[int, int]:
        if self._dirty[k]:
            nm = self.nm
            seg = self.free[int(nm.cluster_lo[k]) : int(nm.cluster_hi[k])]
            gpn = int(nm.cluster_gpn[k])
            self._empty[k] = int(np.count_nonzero(seg == gpn))
            part = seg[seg < gpn]
            self._maxp[k] = int(part.max()) if part.size else 0
            self._dirty[k] = False
        return int(self._empty[k]), int(self._maxp[k])

    def feasible(self, k: int, g: int) -> bool:
        """Can cluster ``k`` host a gang of ``g`` as ``w`` full nodes plus
        one remainder piece?"""
        gpn = int(self.nm.cluster_gpn[k])
        w, r = divmod(int(g), gpn)
        empty, maxp = self._stats(k)
        if empty < w:
            return False
        return r == 0 or maxp >= r or empty >= w + 1

    def feasible_vec(self, g: int) -> np.ndarray:
        """``feasible`` for every cluster at once — one vector expression
        instead of a Python call per cluster (the decide path's per-job
        pool test)."""
        for k in np.flatnonzero(self._dirty):
            self._stats(int(k))
        gpn = self.nm.cluster_gpn
        w = g // gpn
        r = g - w * gpn
        return (self._empty >= w) & (
            (r == 0) | (self._maxp >= r) | (self._empty >= w + 1)
        )

    def best_value(self, k: int, demand: int, lo: int, hi: int) -> int:
        """Largest splice-compatible world size in ``[lo, hi]`` that
        cluster ``k`` can host (0 if none)."""
        for v in gang_values(int(demand), int(lo), int(hi)):
            if self.feasible(k, v):
                return v
        return 0

    def undo(self, idx: int) -> None:
        """Reverse a fit made earlier this pass (the entry is tombstoned;
        the caller filters ``assigns`` before committing)."""
        row, nodes, gpus = self.assigns[idx]
        ns = np.asarray(nodes, np.int64)
        gs = np.asarray(gpus, np.int64)
        self.free[ns] += gs
        ks = self.nm.node_cluster[ns]
        np.add.at(self.cfree, ks, gs)
        self._dirty[np.unique(ks)] = True
        self.assigns[idx] = None

    def fit_any(self, row: int, k: int, g: int) -> None:
        """Place a gang that fits the cluster's aggregate free capacity:
        the clean shape (``fit``) when feasible, else a scattered fill —
        largest holes first (lowest index on ties), which minimizes the
        piece count.  The device-proxy makes scattered placement legal;
        it is merely the low-locality fallback the defragmentation pass
        exists to avoid."""
        if self.feasible(k, g):
            self.fit(row, k, g)
            return
        nm = self.nm
        lo, hi = int(nm.cluster_lo[k]), int(nm.cluster_hi[k])
        seg = self.free[lo:hi]
        order = np.lexsort((np.arange(seg.size), -seg))
        nodes: List[int] = []
        gpus: List[int] = []
        rem = int(g)
        for j in order:
            take = min(rem, int(seg[j]))
            if take <= 0:
                break
            nodes.append(lo + int(j))
            gpus.append(take)
            seg[j] -= take
            rem -= take
            if rem == 0:
                break
        assert rem == 0, "fit_any() without aggregate capacity"
        self.cfree[k] -= int(g)
        self._dirty[k] = True
        self.assigns.append((row, nodes, gpus))

    def fit(self, row: int, k: int, g: int) -> None:
        """Place a feasible gang: full pieces on the lowest-index empty
        nodes, the remainder best-fit into the smallest sufficient
        partial hole (lowest index on ties; the next empty node when no
        partial hole fits)."""
        nm = self.nm
        lo, hi = int(nm.cluster_lo[k]), int(nm.cluster_hi[k])
        gpn = int(nm.cluster_gpn[k])
        w, r = divmod(int(g), gpn)
        seg = self.free[lo:hi]  # view: writes land in self.free
        nodes: List[int] = []
        gpus: List[int] = []
        if w:
            empt = np.flatnonzero(seg == gpn)[:w]
            assert empt.size == w, "fit() without feasibility"
            for j in empt:
                nodes.append(lo + int(j))
                gpus.append(gpn)
            seg[empt] -= gpn
        if r:
            cand = np.flatnonzero((seg < gpn) & (seg >= r))
            if cand.size:
                j = int(cand[np.lexsort((cand, seg[cand]))[0]])
            else:
                rest = np.flatnonzero(seg == gpn)
                assert rest.size, "fit() without feasibility"
                j = int(rest[0])
            nodes.append(lo + j)
            gpus.append(r)
            seg[j] -= r
        self.cfree[k] -= int(g)
        self._dirty[k] = True
        self.assigns.append((row, nodes, gpus))

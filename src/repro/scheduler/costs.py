"""Scheduling cost model: what preemption/migration/resize actually cost.

Singularity's claim (§1, Table 5) is that its mechanisms are *cheap but
not free* — tens of seconds of downtime each — and that the scheduler
stays efficient despite paying them.  A simulator that never charges
those costs silently overstates every elastic-vs-static comparison, so
this module makes them a first-class input to the scheduler layer.

The per-job downtime decomposition mirrors ``core/migration.py``'s
measured end-to-end flow (Table 5):

  barrier   — in-graph quiesce; bounded at two mini-batches (§4.3)
  dump      — device+host state to local host memory
  upload    — deduped checkpoint to the remote blob store
  download  — checkpoint from the blob store at the destination
  restore   — fresh rendezvous + state load + step recompile

``CheckpointStore`` dedups DP replicas, so checkpoint bytes are a
function of model-state size, not of the allocation (Table 4) — which is
why per-job bytes live on the job, not the cost model.  Both the
simulator and any analysis tooling consume the same model; a uniform
scalar configuration (``CostModel.uniform``) reproduces flat per-event
charges for controlled experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.utils import constants


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Derives per-job preempt/restore/migrate/resize downtime (seconds).

    Downtime is charged to the *job*: wall time during which its
    allocation makes no progress (dead GPU time for held allocations,
    delayed resume for preempted ones).
    """

    blob_bandwidth: float = constants.BLOB_STORE_BANDWIDTH
    host_device_bandwidth: float = constants.HOST_DEVICE_BANDWIDTH
    barrier_minibatches: int = 2          # §4.3: quiesce within two steps
    minibatch_seconds: float = 0.5
    rendezvous_seconds: float = 5.0       # destination compile + rendezvous
    scale: float = 1.0                    # global knob (0 = free mechanisms)

    # ---------------------------------------------------------- components
    def barrier_seconds(self) -> float:
        return self.barrier_minibatches * self.minibatch_seconds

    def dump_seconds(self, checkpoint_bytes: int) -> float:
        return checkpoint_bytes / self.host_device_bandwidth

    def upload_seconds(self, checkpoint_bytes: int) -> float:
        return checkpoint_bytes / self.blob_bandwidth

    def download_seconds(self, checkpoint_bytes: int) -> float:
        return checkpoint_bytes / self.blob_bandwidth

    # ------------------------------------------------------------- events
    def preempt_seconds(self, checkpoint_bytes: int) -> float:
        """Quiesce + dump + upload: paid before the GPUs are released."""
        return self.scale * (self.barrier_seconds()
                             + self.dump_seconds(checkpoint_bytes)
                             + self.upload_seconds(checkpoint_bytes))

    def restore_seconds(self, checkpoint_bytes: int) -> float:
        """Download + rendezvous: paid before the first useful step."""
        return self.scale * (self.download_seconds(checkpoint_bytes)
                             + self.rendezvous_seconds)

    def migrate_seconds(self, checkpoint_bytes: int) -> float:
        """Full Table-5 path: the job is down for the whole round trip."""
        return self.preempt_seconds(checkpoint_bytes) \
            + self.restore_seconds(checkpoint_bytes)

    def resize_seconds(self, checkpoint_bytes: int) -> float:
        """In-place splice swap: quiesce + re-rendezvous, state stays
        resident (no blob round trip)."""
        return self.scale * (self.barrier_seconds()
                             + self.rendezvous_seconds)

    # --------------------------------------------------------- constructors
    @classmethod
    def free(cls) -> "CostModel":
        """All mechanisms free — the (dishonest) seed behaviour, kept for
        ablations."""
        return cls(scale=0.0)

    @classmethod
    def uniform(cls, migration_cost_seconds: float,
                preemption_cost_seconds: Optional[float] = None,
                restore_cost_seconds: Optional[float] = None,
                resize_cost_seconds: Optional[float] = None) -> "UniformCostModel":
        """Flat per-event charges, independent of checkpoint size."""
        return UniformCostModel(
            migration=migration_cost_seconds,
            preemption=preemption_cost_seconds,
            restore=restore_cost_seconds,
            resize=resize_cost_seconds)


@dataclasses.dataclass(frozen=True)
class UniformCostModel(CostModel):
    """Flat per-event costs (seconds), ignoring checkpoint size.

    ``CostModel.uniform(60.0)`` reproduces the paper's "tens of seconds"
    headline number as a single knob; ``CostModel.uniform(0.0)`` is the
    cost-free ablation.  Unset per-event costs derive from ``migration``
    (preempt + restore == migrate, resize = migration / 6), and the
    inherited ``scale`` knob applies here too.
    """

    migration: float = 60.0
    preemption: Optional[float] = None    # default: migration / 2
    restore: Optional[float] = None       # default: migration / 2
    resize: Optional[float] = None        # default: migration / 6

    def __post_init__(self):
        if self.preemption is None:
            object.__setattr__(self, "preemption", self.migration / 2)
        if self.restore is None:
            object.__setattr__(self, "restore", self.migration / 2)
        if self.resize is None:
            object.__setattr__(self, "resize", self.migration / 6)

    def preempt_seconds(self, checkpoint_bytes: int) -> float:
        return self.scale * self.preemption

    def restore_seconds(self, checkpoint_bytes: int) -> float:
        return self.scale * self.restore

    def migrate_seconds(self, checkpoint_bytes: int) -> float:
        return self.scale * self.migration

    def resize_seconds(self, checkpoint_bytes: int) -> float:
        return self.scale * self.resize


def default_checkpoint_bytes(demand_gpus: int,
                             state_bytes_per_gpu: int = 2 << 30,
                             host_bytes_per_worker: int = 8 << 20) -> int:
    """Estimate a job's deduped checkpoint size.

    Device state S_G is independent of the DP degree (content dedup,
    Table 4) but larger models ship more shards, so we anchor it to the
    job's model-parallel footprint; per-worker host state S_Cr scales
    with the worker count (§7.2).
    """
    model_shards = max(1, demand_gpus // 8)    # DP degree ~8 in the fleet mix
    return model_shards * state_bytes_per_gpu \
        + demand_gpus * host_bytes_per_worker

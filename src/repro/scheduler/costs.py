"""Scheduling cost model: what preemption/migration/resize actually cost.

Singularity's claim (§1, Table 5) is that its mechanisms are *cheap but
not free* — tens of seconds of downtime each — and that the scheduler
stays efficient despite paying them.  A simulator that never charges
those costs silently overstates every elastic-vs-static comparison, so
this module makes them a first-class input to the scheduler layer.

The per-job downtime decomposition mirrors ``core/migration.py``'s
measured end-to-end flow (Table 5):

  barrier   — in-graph quiesce; bounded at two mini-batches (§4.3)
  dump      — device+host state to local host memory
  transfer  — deduped checkpoint through the blob store (upload at the
              source, download at the destination); for a cross-region
              move the blob path is the slower inter-region link, so the
              transfer is weighted by the ``RegionTopology`` entry for
              the (source, destination) pair
  restore   — fresh rendezvous + state load + step recompile

``CheckpointStore`` dedups DP replicas, so checkpoint bytes are a
function of model-state size, not of the allocation (Table 4) — which is
why per-job bytes live on the job, not the cost model.  Both the
simulator and any analysis tooling consume the same model; a uniform
scalar configuration (``CostModel.uniform``) reproduces flat per-event
charges for controlled experiments, and ``CostModel.from_reports``
calibrates the derived model from measured ``MigrationReport`` runs so
the scheduler charges what the mechanisms actually cost on this host.

All per-event methods accept either a scalar ``checkpoint_bytes`` or a
numpy array (they are pure broadcastable arithmetic): the vectorized
``ElasticPolicy`` ranks whole job arrays through the same code path the
scalar oracle uses per job.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Tuple

from repro.utils import constants


@dataclasses.dataclass(frozen=True)
class RegionLink:
    """One source<->destination blob path between a pair of regions."""

    bandwidth: float
    latency_seconds: float = 0.0


class RegionTopology:
    """Source->destination transfer tiers between regions.

    Intra-region blob traffic runs at the full blob-store bandwidth with
    no extra latency.  Cross-region traffic pays the inter-region link:
    lower bandwidth (geo-replication shares the WAN) plus a flat
    latency charge (control-plane + first-byte).  Pairs without an
    explicit ``links`` entry fall back to the uniform cross-region tier,
    so a two-line topology is enough for controlled experiments while
    ``tiered`` builds a realistic near/far mesh.
    """

    def __init__(
        self,
        intra_bandwidth: float = constants.BLOB_STORE_BANDWIDTH,
        cross_bandwidth: Optional[float] = None,
        cross_latency_seconds: float = 2.0,
        links: Optional[Dict[Tuple[str, str], RegionLink]] = None,
    ):
        self.intra_bandwidth = float(intra_bandwidth)
        self.cross_bandwidth = (
            float(cross_bandwidth)
            if cross_bandwidth is not None
            else self.intra_bandwidth / 6.0
        )
        self.cross_latency_seconds = float(cross_latency_seconds)
        self.links: Dict[Tuple[str, str], RegionLink] = dict(links or {})

    def link(self, src: Optional[str], dst: Optional[str]) -> RegionLink:
        if src is None or dst is None or src == dst:
            return RegionLink(self.intra_bandwidth, 0.0)
        if (src, dst) in self.links:
            return self.links[(src, dst)]
        if (dst, src) in self.links:
            return self.links[(dst, src)]
        return RegionLink(self.cross_bandwidth, self.cross_latency_seconds)

    def bandwidth(self, src: Optional[str], dst: Optional[str]) -> float:
        return self.link(src, dst).bandwidth

    def latency_seconds(self, src: Optional[str], dst: Optional[str]) -> float:
        return self.link(src, dst).latency_seconds

    def transfer_factor(self, src: Optional[str], dst: Optional[str]) -> float:
        """How much slower the src->dst blob path is than intra-region
        (1.0 for an intra-region or unspecified pair)."""
        return self.intra_bandwidth / max(self.bandwidth(src, dst), 1e-9)

    @classmethod
    def tiered(
        cls,
        region_ids: Iterable[str],
        intra_bandwidth: float = constants.BLOB_STORE_BANDWIDTH,
        near_factor: float = 4.0,
        far_factor: float = 8.0,
        near_latency_seconds: float = 1.0,
        far_latency_seconds: float = 5.0,
    ) -> "RegionTopology":
        """Realistic two-tier mesh over an ordered region ring.

        Adjacent regions (ring distance 1: paired DCs on the same
        backbone) get the fast "near" tier; everything farther is the
        slow "far" tier — the intra/near/far split Singularity's global
        scheduler prices when it moves work across AzureML regions.
        """
        ids = list(region_ids)
        n = len(ids)
        links: Dict[Tuple[str, str], RegionLink] = {}
        for i in range(n):
            for k in range(i + 1, n):
                ring = min(k - i, n - (k - i))
                if ring <= 1:
                    links[(ids[i], ids[k])] = RegionLink(
                        intra_bandwidth / near_factor, near_latency_seconds
                    )
                else:
                    links[(ids[i], ids[k])] = RegionLink(
                        intra_bandwidth / far_factor, far_latency_seconds
                    )
        return cls(
            intra_bandwidth=intra_bandwidth,
            cross_bandwidth=intra_bandwidth / far_factor,
            cross_latency_seconds=far_latency_seconds,
            links=links,
        )


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Derives per-job preempt/restore/migrate/resize downtime (seconds).

    Downtime is charged to the *job*: wall time during which its
    allocation makes no progress (dead GPU time for held allocations,
    delayed resume for preempted ones).
    """

    blob_bandwidth: float = constants.BLOB_STORE_BANDWIDTH
    host_device_bandwidth: float = constants.HOST_DEVICE_BANDWIDTH
    barrier_minibatches: int = 2          # §4.3: quiesce within two steps
    minibatch_seconds: float = 0.5
    rendezvous_seconds: float = 5.0       # destination compile + rendezvous
    scale: float = 1.0                    # global knob (0 = free mechanisms)
    topology: Optional[RegionTopology] = None   # region-pair transfer tiers

    # ---------------------------------------------------------- components
    def barrier_seconds(self) -> float:
        return self.barrier_minibatches * self.minibatch_seconds

    def dump_seconds(self, checkpoint_bytes):
        return checkpoint_bytes / self.host_device_bandwidth

    def upload_seconds(self, checkpoint_bytes):
        return checkpoint_bytes / self.blob_bandwidth

    def download_seconds(self, checkpoint_bytes):
        return checkpoint_bytes / self.blob_bandwidth

    def transfer_seconds(self, checkpoint_bytes, src_region: Optional[str] = None,
                         dst_region: Optional[str] = None):
        """Blob round trip, weighted by the (source, destination) region
        pair when a topology is attached."""
        base = self.upload_seconds(checkpoint_bytes) \
            + self.download_seconds(checkpoint_bytes)
        if self.topology is None:
            return base
        return base * self.topology.transfer_factor(src_region, dst_region) \
            + self.topology.latency_seconds(src_region, dst_region)

    # ------------------------------------------------------------- events
    def preempt_seconds(self, checkpoint_bytes):
        """Quiesce + dump + upload: paid before the GPUs are released."""
        return self.scale * (self.barrier_seconds()
                             + self.dump_seconds(checkpoint_bytes)
                             + self.upload_seconds(checkpoint_bytes))

    def restore_seconds(self, checkpoint_bytes, src_region: Optional[str] = None,
                        dst_region: Optional[str] = None):
        """Download + rendezvous: paid before the first useful step.  A
        restore landing in a different region than the one that uploaded
        the checkpoint pays the pair's download tier, same as the
        download leg of a migration."""
        download = self.download_seconds(checkpoint_bytes)
        if self.topology is not None:
            download = download * self.topology.transfer_factor(
                src_region, dst_region) \
                + self.topology.latency_seconds(src_region, dst_region)
        return self.scale * (download + self.rendezvous_seconds)

    def migrate_seconds(self, checkpoint_bytes, src_region: Optional[str] = None,
                        dst_region: Optional[str] = None):
        """Full Table-5 path: the job is down for the whole round trip.
        A cross-region move pays the inter-region blob tier for the
        transfer leg (slower link + first-byte latency)."""
        return self.scale * (self.barrier_seconds()
                             + self.dump_seconds(checkpoint_bytes)
                             + self.transfer_seconds(checkpoint_bytes,
                                                     src_region, dst_region)
                             + self.rendezvous_seconds)

    def resize_seconds(self, checkpoint_bytes):
        """In-place splice swap: quiesce + re-rendezvous, state stays
        resident (no blob round trip)."""
        return self.scale * (self.barrier_seconds()
                             + self.rendezvous_seconds)

    def snapshot_seconds(self, checkpoint_bytes):
        """Online checkpoint while the job keeps its allocation: quiesce
        + dump + upload — the save side of a preemption, charged as
        downtime.  This is the Young–Daly ``delta`` the checkpoint
        cadence weighs against the domain failure rate."""
        return self.preempt_seconds(checkpoint_bytes)

    # --------------------------------------------------------- constructors
    @classmethod
    def free(cls) -> "CostModel":
        """All mechanisms free — the (dishonest) seed behaviour, kept for
        ablations."""
        return cls(scale=0.0)

    @classmethod
    def uniform(cls, migration_cost_seconds: float,
                preemption_cost_seconds: Optional[float] = None,
                restore_cost_seconds: Optional[float] = None,
                resize_cost_seconds: Optional[float] = None) -> "UniformCostModel":
        """Flat per-event charges, independent of checkpoint size."""
        return UniformCostModel(
            migration=migration_cost_seconds,
            preemption=preemption_cost_seconds,
            restore=restore_cost_seconds,
            resize=resize_cost_seconds)

    @classmethod
    def from_reports(cls, reports: Iterable, topology: Optional[RegionTopology] = None,
                     scale: float = 1.0) -> "CostModel":
        """Calibrate the derived model from measured ``MigrationReport``s.

        Closes the loop between ``core/migration.py`` (which measures the
        real barrier/dump/transfer/restore flow on this host) and the
        scheduler (which charges those costs fleet-wide): bandwidths are
        fitted as total-bytes / total-seconds over all reports, the
        barrier as mean per-minibatch wall time, the rendezvous as the
        mean measured restore.  Reports are duck-typed so analysis
        tooling can calibrate from serialized rows as well.

        When reports carry ``src_region``/``dst_region``, the fit is
        region-aware: the base blob bandwidth comes from intra-region
        (or region-blind) reports, and each measured cross-region pair
        gets its own fitted ``RegionLink`` in a synthesized
        ``RegionTopology`` — so the scheduler charges the slower WAN
        tiers it actually observed.  A ``topology`` passed explicitly is
        never overwritten by the fit.
        """
        reports = list(reports)
        if not reports:
            raise ValueError("from_reports needs at least one MigrationReport")

        def _pair(r) -> Optional[Tuple[str, str]]:
            src = getattr(r, "src_region", None)
            dst = getattr(r, "dst_region", None)
            if src is None or dst is None or src == dst:
                return None
            return (src, dst)

        def _blob_bw(rs) -> float:
            nbytes = float(sum(r.device_stored_bytes + r.host_stored_bytes
                               for r in rs))
            secs = float(sum(r.upload_seconds + r.download_seconds
                             for r in rs))
            return 2.0 * nbytes / max(secs, 1e-9)

        intra = [r for r in reports if _pair(r) is None]
        cross: Dict[Tuple[str, str], list] = {}
        for r in reports:
            pair = _pair(r)
            if pair is not None:
                cross.setdefault(pair, []).append(r)
        # base (intra-region) bandwidth from intra reports when any exist;
        # a purely cross-region report set falls back to the full pool
        base = intra if intra else reports
        base_bw = _blob_bw(base)
        if topology is None and cross:
            links = {
                pair: RegionLink(_blob_bw(rs)) for pair, rs in cross.items()
            }
            topology = RegionTopology(
                intra_bandwidth=base_bw,
                cross_bandwidth=min(lk.bandwidth for lk in links.values()),
                cross_latency_seconds=0.0,
                links=links)

        total_bytes = float(sum(r.device_stored_bytes + r.host_stored_bytes
                                for r in reports))
        dump_s = float(sum(r.dump_seconds for r in reports))
        n = len(reports)
        mb = max(1, round(sum(r.barrier_minibatches for r in reports) / n))
        mb_seconds = sum(r.barrier_seconds / max(r.barrier_minibatches, 1)
                         for r in reports) / n
        rendezvous = sum(r.restore_seconds for r in reports) / n
        return cls(
            blob_bandwidth=base_bw,
            host_device_bandwidth=total_bytes / max(dump_s, 1e-9),
            barrier_minibatches=mb,
            minibatch_seconds=mb_seconds,
            rendezvous_seconds=rendezvous,
            scale=scale,
            topology=topology)


@dataclasses.dataclass(frozen=True)
class UniformCostModel(CostModel):
    """Flat per-event costs (seconds), ignoring checkpoint size.

    ``CostModel.uniform(60.0)`` reproduces the paper's "tens of seconds"
    headline number as a single knob; ``CostModel.uniform(0.0)`` is the
    cost-free ablation.  Unset per-event costs derive from ``migration``
    (preempt + restore == migrate, resize = migration / 6), and the
    inherited ``scale`` knob applies here too.  When a topology is
    attached the flat migration charge is weighted by the region pair's
    transfer factor plus its latency (intra = 1.0 + 0s), so cross-region
    moves stay more expensive even in controlled uniform-cost
    experiments; a zero-cost model stays exactly zero.
    """

    migration: float = 60.0
    preemption: Optional[float] = None    # default: migration / 2
    restore: Optional[float] = None       # default: migration / 2
    resize: Optional[float] = None        # default: migration / 6

    def __post_init__(self):
        if self.preemption is None:
            object.__setattr__(self, "preemption", self.migration / 2)
        if self.restore is None:
            object.__setattr__(self, "restore", self.migration / 2)
        if self.resize is None:
            object.__setattr__(self, "resize", self.migration / 6)

    def preempt_seconds(self, checkpoint_bytes):
        return self.scale * self.preemption

    def restore_seconds(self, checkpoint_bytes, src_region: Optional[str] = None,
                        dst_region: Optional[str] = None):
        base = self.scale * self.restore
        if self.topology is None or base == 0:
            return base      # a free/flat-zero model stays exactly zero
        return base * self.topology.transfer_factor(src_region, dst_region) \
            + self.scale * self.topology.latency_seconds(src_region, dst_region)

    def migrate_seconds(self, checkpoint_bytes, src_region: Optional[str] = None,
                        dst_region: Optional[str] = None):
        base = self.scale * self.migration
        if self.topology is None or base == 0:
            return base      # a free/flat-zero model stays exactly zero
        return base * self.topology.transfer_factor(src_region, dst_region) \
            + self.scale * self.topology.latency_seconds(src_region, dst_region)

    def resize_seconds(self, checkpoint_bytes):
        return self.scale * self.resize


def default_checkpoint_bytes(demand_gpus: int,
                             state_bytes_per_gpu: int = 2 << 30,
                             host_bytes_per_worker: int = 8 << 20) -> int:
    """Estimate a job's deduped checkpoint size.

    Device state S_G is independent of the DP degree (content dedup,
    Table 4) but larger models ship more shards, so we anchor it to the
    job's model-parallel footprint; per-worker host state S_Cr scales
    with the worker count (§7.2).
    """
    model_shards = max(1, demand_gpus // 8)    # DP degree ~8 in the fleet mix
    return model_shards * state_bytes_per_gpu \
        + demand_gpus * host_bytes_per_worker


def defrag_worthwhile(cost_model: CostModel,
                      checkpoint_bytes: Iterable[int],
                      freed_gpus: int,
                      interval_seconds: float) -> bool:
    """Gate for a defragmentation move: consolidating a node's stranded
    fragments is worth it only when one scheduling interval of the freed
    capacity (GPU-seconds a queued gang could now use) outweighs the
    intra-cluster migrate downtime charged to every moved job."""
    cost = sum(cost_model.migrate_seconds(cb) for cb in checkpoint_bytes)
    return cost < float(freed_gpus) * float(interval_seconds)

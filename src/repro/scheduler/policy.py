"""Scheduling policies.

``ElasticPolicy`` is Singularity's: every job is preemptible, migratable and
elastic, so the scheduler (a) never leaves capacity idle while work is
queued (opportunistic scale-up of running jobs / admission of basic jobs
anywhere in the fleet), (b) shrinks before it preempts, preempts strictly
by tier, (c) defragments by migrating small jobs to open contiguous
capacity for large arrivals, all while respecting GPU-fraction SLAs.

Three properties distinguish it from the seed policy:

**Cost-aware.**  When a ``CostModel`` is attached (the simulator and the
executor thread theirs in automatically), decisions weigh the mechanisms'
real downtime instead of treating them as free:

- *Victim ranking* — within a tier, running jobs are admitted ahead of
  queued ones and ranked by the downtime a preemption+restore of them
  would burn per GPU freed (``preempt_seconds + restore_seconds``); so
  when capacity forces evictions, the victims are the jobs with small
  ``checkpoint_bytes`` — the cheap ones to stop (Aryl's weighting).
- *Shrink-before-queue gate* — comfort-shrinking a job into leftover
  capacity is only worth a restore/resize whose downtime is shorter than
  the scheduling interval; otherwise the mechanism would eat the whole
  tick it was meant to exploit.
- *Expansion gate* — opportunistic scale-up of an already-running job
  triggers a splice resize; a chunk of extra GPUs is only granted when
  the productive GPU-seconds it delivers in one interval — priced on
  the job's concave scaling curve (``scheduler/curves.py``), not a
  linear fiction — exceed the dead GPU-seconds the resize charges.
  Spare capacity is *water-filled* in descending marginal-slope order:
  pre-knee chunks (marginal gain of one interval per GPU, the seed's
  linear pricing, and the whole chunk for flat-curve jobs) fill first
  in scale-up-priority order, then post-knee chunks by descending
  ``sat_slope``; a job's post-knee chunk is reachable only once its
  pre-knee chunk filled (concavity).  ``curve_aware=False`` restores
  linear pricing — the A/B arm ``benchmarks/sched_scale.py --curves``
  measures against.
- *Region-aware placement* — a running job that must move is placed in
  its current region when any same-region cluster fits, because the cost
  model prices cross-region migrations at the slower inter-region blob
  tier.
- *Reliability-aware placement* — only HEALTHY capacity is allocatable
  (failed-out domains await repair), draining domains are avoided when a
  healthy cluster fits, and a running job evacuates a draining cluster
  proactively when one migration costs less than the work a failure
  would destroy (unsnapshotted progress plus the forced restore).

**Fair under permanent overload.**  Victim ranking alone lets a queued
guaranteed job starve forever behind running peers that are expensive to
stop.  Admission-order *fairness aging* fixes that: a guaranteed job
queued longer than ``aging_threshold_intervals`` scheduling intervals
accrues a bonus of ``aging_rate`` cost-seconds per excess second queued
(a float, or a per-tier mapping so premium ages faster than standard),
and competes in the running-job class with that bonus as its score — once
the bonus exceeds a running peer's preempt+restore downtime, the aged job
is admitted ahead of it.  When the queue drains (or within the
threshold), the ordering is exactly the unaged one, so aging is a no-op
on healthy fleets.

**Vectorized.**  ``decide`` runs as numpy array passes — lexsort for the
admission/expansion/placement orders, cumsum-based greedy capacity fits,
and one batched ``FleetSLAAccounts.headroom_all`` call for the SLA state
of every guaranteed job (no per-job account queries remain on the decide
path when jobs carry ledger-backed accounts) — so million-job traces
clear in minutes (``benchmarks/sched_scale.py``).  When the driver's
jobs live in a fleet ``JobTable`` (the production setup: the simulator
and the executor adopt theirs at construction), even the per-job
*attribute gather* disappears: the decide pass slices the table's
columns directly, the ledger slots come from the ``sla_slot`` column,
and the ``Decision`` carries its array form (``table_update``) so the
simulator applies it with masked column writes.  Hand-built scalar
``Job`` lists keep the per-job build path; mixed or foreign-table lists
are detected (``job_table.shared_table``) and fall back the same way
``_shared_ledger`` does.
``ElasticPolicy(vectorized=False)`` keeps a pure-Python reference oracle
with identical semantics; ``tests/test_policy_equivalence.py`` proves the
two paths emit byte-identical decisions on random fleets, and
``tests/test_job_table.py`` proves the table path is indistinguishable
from plain jobs.

``StaticGangPolicy`` is the status-quo baseline: jobs are gang-scheduled at
full demand in FIFO order, never preempted, never resized — the comparison
that motivates the paper (§1: utilization/idling).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Mapping as MappingABC
from typing import Dict, List, Mapping, Optional, Tuple, Union

import numpy as np

from repro.core.sla import TIERS, FleetSlotAccount
from repro.scheduler.costs import CostModel
from repro.scheduler.job_table import TIER_CODE, JobView, shared_table
from repro.scheduler.node_map import (
    floor_gang,
    gang_down,
    gang_down_vec,
    gang_values,
    splice_divisors,
)
from repro.scheduler.telemetry import Profiler
from repro.scheduler.types import Fleet, Job

DEFAULT_INTERVAL_SECONDS = 300.0

# tier attributes as numpy lookup tables: one dict hit per job instead of
# three TIERS consultations on the decide hot path (codes shared with the
# JobTable's tier_code column)
_TIER_CODE = TIER_CODE
_TIER_PRIO = np.array([TIERS[t].preempt_priority for t in TIERS], np.int64)
_TIER_SUP = np.array([TIERS[t].scaleup_priority for t in TIERS], np.int64)
_TIER_GFRAC = np.array([TIERS[t].gpu_fraction for t in TIERS], np.float64)


class _TableAlloc(MappingABC):
    """``Decision.alloc`` backed by the decide pass's arrays.

    The simulator's table-aware ``_apply`` consumes the array form
    directly, so for table-backed fleets the per-job ``{id: (gpus,
    cluster)}`` dict never needs to exist; it materializes lazily (and
    identically) for anyone who reads the mapping — digest wrappers,
    the executor, hand-written consumers."""

    __slots__ = ("_ids", "_gpus", "_placed", "_cluster_ids", "_dict")

    def __init__(self, ids, gpus, placed, cluster_ids):
        self._ids = ids
        self._gpus = gpus
        self._placed = placed
        self._cluster_ids = cluster_ids
        self._dict: Optional[Dict[str, Tuple[int, Optional[str]]]] = None

    def _materialize(self) -> Dict[str, Tuple[int, Optional[str]]]:
        if self._dict is None:
            cids = self._cluster_ids
            placed = self._placed
            gpus = self._gpus
            self._dict = {
                jid: (
                    int(gpus[i]),
                    cids[placed[i]] if placed[i] >= 0 else None,
                )
                for i, jid in enumerate(self._ids)
            }
        return self._dict

    def __getitem__(self, key):
        return self._materialize()[key]

    def __iter__(self):
        return iter(self._materialize())

    def __len__(self) -> int:
        return len(self._ids)

    def items(self):
        return self._materialize().items()


@dataclasses.dataclass
class Decision:
    """Target allocation for the next interval: job -> (gpus, cluster)."""

    alloc: Mapping[str, Tuple[int, Optional[str]]]
    preemptions: List[str]
    migrations: List[str]
    # array form of ``alloc`` when the decide pass ran over a JobTable
    # whose cluster codes index ``fleet.clusters()``: ``(table, slots,
    # gpus, placed)`` with ``placed`` a cluster index (-1 = unplaced).
    # The simulator applies it with masked column writes instead of a
    # per-job Python loop; consumers that only know the mapping ignore it.
    table_update: Optional[tuple] = None
    # node placement plan when the fleet carries a NodeMap: ``(node_map,
    # released_rows, assigns)`` where ``assigns`` is [(row, nodes, gpus)].
    # The simulator commits it in ``_apply``; decisions without one (the
    # static baseline, hand-written policies) get an auto-fit span.
    node_plan: Optional[tuple] = None
    # ids of jobs whose grant includes a curve-priced (slope-gated)
    # expansion chunk this interval — the simulator tags their resize
    # events with the ``slope`` cause.  None when no such grant was made
    # (all-flat fleets, curve_aware=False).  Sorted for path equality.
    slope_expanded: Optional[Tuple[str, ...]] = None


class StaticGangPolicy:
    """FIFO gang scheduling without preemption/elasticity."""

    name = "static"

    def decide(self, now: float, jobs: List[Job], fleet: Fleet) -> Decision:
        # healthy capacity only: failed-out GPUs are not allocatable
        free = {c.id: c.capacity() for c in fleet.clusters()}
        for j in jobs:
            if j.done_at is None and j.allocated > 0:
                free[j.cluster] -= j.allocated
        alloc: Dict[str, Tuple[int, Optional[str]]] = {}
        for j in sorted(jobs, key=lambda j: j.arrival):
            if j.done_at is not None:
                continue
            if j.allocated > 0:
                alloc[j.id] = (j.allocated, j.cluster)  # never touched again
                continue
            # admit only if some cluster fits the FULL demand
            for cid, f in free.items():
                if f >= j.demand_gpus:
                    alloc[j.id] = (j.demand_gpus, cid)
                    free[cid] -= j.demand_gpus
                    break
            else:
                alloc[j.id] = (0, None)
        return Decision(alloc=alloc, preemptions=[], migrations=[])


def _greedy_take(
    wants: np.ndarray, floors: np.ndarray, cap: int, partial: bool
) -> Tuple[np.ndarray, int]:
    """Greedy capacity fit along an already-ordered candidate axis.

    Each candidate takes its full ``want`` when that fits in the remaining
    capacity; with ``partial=True`` a candidate whose full want no longer
    fits may instead take everything left, provided that is still at or
    above its ``floor``.  Equivalent to the per-job reference loop, but
    runs as cumsum rounds: every round admits a whole prefix at once, so
    the number of rounds is bounded by the number of skipped boundary
    candidates, not by the job count.

    Returns the granted array (aligned with ``wants``) and the capacity
    left over.
    """
    gives = np.zeros(wants.size, dtype=np.int64)
    remaining = int(cap)
    # a candidate whose full want is below its own floor can never be
    # granted anything (partial grants are capacity splits, not floor
    # relaxations), matching the reference loop's give >= floor check
    active = np.flatnonzero((wants > 0) & (wants >= floors))
    while active.size and remaining > 0:
        active = active[floors[active] <= remaining]
        if not active.size:
            break
        prefix = np.cumsum(wants[active])
        fit = prefix <= remaining
        k = int(np.argmin(fit)) if not fit.all() else int(active.size)
        if k > 0:
            taken = active[:k]
            gives[taken] = wants[taken]
            remaining -= int(prefix[k - 1])
        if k >= active.size:
            break
        boundary = active[k]
        if partial and remaining >= floors[boundary]:
            gives[boundary] = remaining  # full want no longer fits
            remaining = 0
        tail = k + 1
        active = active[tail:]
    return gives, remaining


def _gang_topup(
    galloc: np.ndarray, demand: np.ndarray, prio: np.ndarray, rem: int
) -> None:
    """Hand gang-rounding's shavings back: climb shrunk jobs up the
    splice-divisor ladder toward full demand while spare capacity lasts
    (highest tier, largest grant, lowest index first).  Without this a
    grant like 51-of-64 rounds to 32 and the 19 freed GPUs idle; with it
    they finance the next divisor step.  In-place; candidates are only
    jobs holding GPUs below demand, so the trip count is bounded by the
    running-job count, not queue depth.  Both decide paths call this
    exact routine, so grants cannot drift between them."""
    if rem <= 0:
        return
    cand = np.flatnonzero((galloc > 0) & (galloc < demand))
    if not cand.size:
        return
    order = cand[np.lexsort((cand, -galloc[cand], -prio[cand]))]
    for i in order:
        g = int(galloc[i])
        divs = splice_divisors(int(demand[i]))
        p = int(np.searchsorted(np.asarray(divs, np.int64), g, side="right"))
        while p < len(divs) and divs[p] - g <= rem:
            rem -= divs[p] - g
            g = divs[p]
            p += 1
        galloc[i] = g
        if rem <= 0:
            break


def _shared_ledger(accs: list):
    """(ledger, slots) when every account is a view on one
    ``FleetSLAAccounts``; (None, None) otherwise (mixed or scalar
    accounts fall back to the per-job oracle loop)."""
    ledger = None
    slots = np.empty(len(accs), np.int64)
    for k, acc in enumerate(accs):
        if not isinstance(acc, FleetSlotAccount):
            return None, None
        if ledger is None:
            ledger = acc.ledger
        elif acc.ledger is not ledger:
            return None, None
        slots[k] = acc.slot
    return ledger, slots


class ElasticPolicy:
    """Singularity's policy: SLA-tiered, shrink-before-preempt, elastic
    expansion into spare capacity, migration-based defragmentation —
    cost-aware, aging-fair and vectorized (see module docstring)."""

    name = "elastic"

    def __init__(
        self,
        expand_factor: float = 2.0,
        cost_model: Optional[CostModel] = None,
        interval_hint: Optional[float] = None,
        vectorized: bool = True,
        aging_rate: Union[float, Mapping[str, float]] = 1.0,
        aging_threshold_intervals: float = 12.0,
        node_batch: bool = True,
        curve_aware: bool = True,
    ):
        self.expand_factor = expand_factor
        # price expansion/shrink on each job's concave scaling curve
        # (curves.py).  False treats every curve as flat — the seed's
        # linear pricing — while the simulator still *progresses* jobs on
        # their true curves; the bench's --curves A/B arm flips this
        self.curve_aware = curve_aware
        # threaded in by FleetSimulator/FleetExecutor when left unset, so
        # the policy always prices decisions with the charged model
        self.cost_model = cost_model
        self.interval_hint = interval_hint
        self.vectorized = vectorized
        # node placement core: batched array passes (production) or the
        # per-job loop oracle the batched core is digest-checked against
        self.node_batch = node_batch
        # fairness aging: a guaranteed job queued longer than
        # aging_threshold_intervals ticks accrues aging_rate cost-seconds
        # of admission credit per excess second; 0 disables aging.  A
        # mapping gives per-tier rates (premium can age faster than
        # standard); tiers absent from the mapping do not age.
        self.aging_rate = aging_rate
        if isinstance(aging_rate, Mapping):
            self._aging_by_tier = {t: float(aging_rate.get(t, 0.0)) for t in TIERS}
        else:
            self._aging_by_tier = {t: float(aging_rate) for t in TIERS}
        self._aging_vec = np.array(
            [self._aging_by_tier[t] for t in TIERS], np.float64
        )
        self.aging_threshold_intervals = aging_threshold_intervals
        self._bound_cost = False
        self._bound_interval = False
        # unified decide-pass profiler (telemetry.Profiler).  Totals
        # always accumulate at the exact cost of the old ad-hoc
        # ``gather_seconds``/``node_seconds`` fields (two perf_counter
        # calls per span); per-span records for trace export are kept
        # only once a FleetTelemetry is bound via ``bind_telemetry``.
        self.prof = Profiler()

    @property
    def decide_seconds(self) -> float:
        """Wall seconds spent inside ``decide`` since construction."""
        return self.prof.total("decide")

    @property
    def gather_seconds(self) -> float:
        """Share of decide time spent gathering per-job state into
        arrays inside ``_decide_vectorized`` (the base-array build, or
        the JobTable column slicing that replaces it); benchmarks
        report the split."""
        return self.prof.total("gather")

    @property
    def node_seconds(self) -> float:
        """Share of decide time spent inside the node-granular
        placement pass; benchmarks gate it separately."""
        return self.prof.total("place")

    def bind_telemetry(self, telemetry) -> None:
        """Adopt a ``FleetTelemetry``'s profiler so this policy's spans
        land in the shared trace (called by the simulator when
        ``SimConfig.telemetry`` is set)."""
        self.prof = telemetry.prof

    def bind_costs(self, cost_model: CostModel, interval_hint: float) -> None:
        """Thread the driver's charged cost model and tick length into
        this policy.  Values the caller configured explicitly are never
        overwritten; values a previous bind installed are — so one policy
        object can be reused across simulators/executors with different
        cost configurations without silently pricing decisions with a
        stale model."""
        if self.cost_model is None or self._bound_cost:
            self.cost_model = cost_model
            self._bound_cost = True
        if self.interval_hint is None or self._bound_interval:
            self.interval_hint = interval_hint
            self._bound_interval = True

    # -- shared scalar helpers (both paths must agree bit-for-bit) --------
    def _interval(self) -> float:
        if self.interval_hint is not None:
            return self.interval_hint
        return DEFAULT_INTERVAL_SECONDS

    def _required(self, now: float, j: Job) -> int:
        """GPUs needed this interval to keep the job's hourly SLA safe."""
        tier = TIERS[j.tier]
        if tier.gpu_fraction <= 0:
            return 0  # basic: best effort
        # fraction delivered so far this window; demand enough to stay above
        if j.account.headroom(now) > 0.1:
            # comfortably above guarantee -> can run shrunk this interval
            # (with a margin so the hourly window stays safe)
            frac = min(1.0, tier.gpu_fraction + 0.1)
            return max(j.min_gpus, int(j.demand_gpus * frac))
        return j.demand_gpus

    def _victim_cost(self, j: Job) -> float:
        """Downtime burned per GPU freed by preempting-then-restoring this
        job (checkpoint-size-driven under the derived model); expensive
        jobs are kept running, cheap ones are victimized.  Deliberately
        NOT weighted by the job's size: per GPU freed the downtime is the
        same, and preferring small victims only multiplies event count."""
        if self.cost_model is None or j.allocated <= 0:
            return 0.0
        cb = j.checkpoint_bytes
        return self.cost_model.preempt_seconds(cb) + self.cost_model.restore_seconds(
            cb
        )

    def _restart_cost(self, j: Job) -> float:
        """Downtime a restart/resize of this job would charge right now.

        The restore term is the region-blind (intra) price — a lower
        bound, since the destination cluster is only chosen later in
        placement; the simulator charges the true pair-priced cost."""
        if self.cost_model is None:
            return 0.0
        if j.allocated > 0:
            return self.cost_model.resize_seconds(j.checkpoint_bytes)
        if j.ever_ran:
            return self.cost_model.restore_seconds(j.checkpoint_bytes) + j.restore_debt
        return 0.0

    def decide(self, now: float, jobs: List[Job], fleet: Fleet) -> Decision:
        with self.prof.span("decide"):
            return self._decide(now, jobs, fleet)

    def _decide(self, now: float, jobs: List[Job], fleet: Fleet) -> Decision:
        if isinstance(jobs, JobView):
            # table-backed fast path: the active filter is a masked
            # column read, no per-job Python at all
            t, s = jobs.table, jobs.slots
            keep = np.isnan(t.done_at[s]) & (t.arrival[s] <= now)
            if not keep.all():
                s = s[keep]
            if s.size == 0:
                return Decision(alloc={}, preemptions=[], migrations=[])
            if self.vectorized:
                return self._decide_vectorized(now, JobView(t, s), fleet)
            return self._decide_reference(now, list(JobView(t, s)), fleet)
        active = [j for j in jobs if j.done_at is None and j.arrival <= now]
        if not active:
            return Decision(alloc={}, preemptions=[], migrations=[])
        if self.vectorized:
            return self._decide_vectorized(now, active, fleet)
        return self._decide_reference(now, active, fleet)

    # ================= vectorized path (the production path) =============
    def _decide_vectorized(
        self, now: float, active: List[Job], fleet: Fleet
    ) -> Decision:
        n = len(active)
        interval = self._interval()
        cm = self.cost_model
        # gather every job's numeric state into arrays.  Table-backed
        # jobs (the production setup): column slices straight out of the
        # shared JobTable, zero per-job Python.  Hand-built scalar jobs:
        # one pass over the objects into a single (n, 8) float64 array
        # (exact — GPU counts and byte sizes are far below 2**53), tier
        # attributes via code lookup tables.  Mixed or foreign-table
        # lists fall back to the object path, like _shared_ledger.
        with self.prof.span("gather"):
            table, slots = shared_table(active)
            if table is not None:
                demand = table.demand_gpus[slots]
                min_g = table.min_gpus[slots]
                alloc0 = table.allocated[slots]
                arrival = table.arrival[slots]
                tcode = table.tier_code[slots]
                qsince = table.queued_since[slots]
                cb = table.checkpoint_bytes[slots].astype(np.float64)
                debt = table.restore_debt[slots]
                ran = table.ever_ran[slots]
                svc = table.service[slots]
                knee = table.knee_gpus[slots]
                sat = table.sat_slope[slots]
            else:
                base = np.array(
                    [
                        (
                            j.demand_gpus,
                            j.min_gpus,
                            j.allocated,
                            j.arrival,
                            j.checkpoint_bytes,
                            j.restore_debt,
                            _TIER_CODE[j.tier],
                            j.queued_since,
                            j.service,
                            j.knee_gpus,
                            j.sat_slope,
                        )
                        for j in active
                    ],
                    dtype=np.float64,
                ).reshape(n, 11)
                demand = base[:, 0].astype(np.int64)
                min_g = base[:, 1].astype(np.int64)
                alloc0 = base[:, 2].astype(np.int64)
                arrival = base[:, 3]
                tcode = base[:, 6].astype(np.int64)
                qsince = base[:, 7]
                cb = base[:, 4]
                debt = base[:, 5]
                svc = base[:, 8] > 0.5
                knee = base[:, 9].astype(np.int64)
                sat = base[:, 10]
                ran = None  # gathered lazily, when a cost model needs it
        prio = _TIER_PRIO[tcode]
        sup = _TIER_SUP[tcode]
        gfrac = _TIER_GFRAC[tcode]
        running = alloc0 > 0
        guar = gfrac > 0.0
        # jobs whose scaling curve the policy prices (knee_gpus == 0 is
        # the flat/linear sentinel; curve_aware=False flattens them all)
        curved = (knee > 0) & self.curve_aware

        # SLA headroom: ONE batched ledger query when the guaranteed jobs
        # carry FleetSLAAccounts-backed accounts (the production setup —
        # table-adopted accounts mirror their ledger slots into the
        # sla_slot column, so not even the account objects are touched);
        # hand-built jobs with scalar accounts fall back to the oracle loop
        with self.prof.span("sla"):
            head = np.full(n, np.inf)
            gidx = np.flatnonzero(guar)
            if gidx.size:
                if (
                    table is not None
                    and table.sla is not None
                    and bool(table.sla_view[slots[gidx]].all())
                ):
                    head[gidx] = table.sla.headroom_all(
                        now, table.sla_slot[slots[gidx]], gfrac[gidx]
                    )
                else:
                    gaccs = [active[i].account for i in gidx]
                    ledger, lslots = _shared_ledger(gaccs)
                    if ledger is not None:
                        head[gidx] = ledger.headroom_all(
                            now, lslots, gfrac[gidx]
                        )
                    else:
                        for k, i in enumerate(gidx):
                            head[i] = gaccs[k].headroom(now)
            shrunk = np.maximum(
                min_g, (demand * np.minimum(1.0, gfrac + 0.1)).astype(np.int64)
            )
            need = np.where(guar, np.where(head > 0.1, shrunk, demand), 0)

        if cm is None:
            vcost = np.zeros(n)
            restart = np.zeros(n)
            resize_s = np.zeros(n)
        else:
            if ran is None:
                ran = np.fromiter((j.ever_ran for j in active), bool, n)
            pre_s = np.broadcast_to(
                np.asarray(cm.preempt_seconds(cb), np.float64), (n,)
            )
            rest_s = np.broadcast_to(
                np.asarray(cm.restore_seconds(cb), np.float64), (n,)
            )
            resize_s = np.broadcast_to(
                np.asarray(cm.resize_seconds(cb), np.float64), (n,)
            )
            vcost = np.where(running, pre_s + rest_s, 0.0)
            restart = np.where(
                running,
                resize_s,
                np.where(ran, rest_s + debt, 0.0),
            )

        idx = np.arange(n)
        with self.prof.span("sort"):
            # fairness aging: a guaranteed job queued past the threshold
            # joins the running-job class, scored by its accrued bonus
            # against the running peers' preempt+restore downtime; rates
            # are per tier
            wait = now - qsince
            threshold = self.aging_threshold_intervals * interval
            rate = self._aging_vec[tcode]
            aged = (~running) & guar & (wait > threshold) & (rate > 0.0)
            score = np.where(
                running,
                vcost,
                np.where(aged, rate * (wait - threshold), 0.0),
            )
            waiting = (~(running | aged)).astype(np.int64)
            # admission order: tier first, serving replica groups ahead
            # of training within their tier (a reclaim retarget must
            # never wait on training admission); then the running jobs
            # and aged long-queued jobs come ahead of the plain queue,
            # ranked by how expensive they are to stop (or how starved
            # they are), then FIFO (lexsort: last key is primary)
            order_a = np.lexsort(
                (idx, arrival, -score, waiting, -svc.astype(np.int64), -prio)
            )
        # failed-out domains await repair: only healthy capacity is real
        total = fleet.capacity()
        galloc = np.zeros(n, dtype=np.int64)

        # 1. guaranteed tier demands, all-or-nothing per job: under
        #    overload it is better to run fewer jobs at guaranteed speed
        #    than all jobs too slow to meet any SLA
        w1 = need[order_a]
        g1, rem = _greedy_take(w1, w1, total, partial=False)
        galloc[order_a] = g1

        # 1b. shrink-before-queue: a guaranteed job whose full slice did
        #     not fit but which is comfortably above its hourly guarantee
        #     runs shrunk (>= min_gpus) instead of queueing — if the
        #     restart it takes costs less downtime than the interval buys.
        #     Curved jobs price the buy at the shrunk operating point
        #     (shrunk/demand of a nominal interval — the curve is linear
        #     below the knee), so a restart a full-size slice would
        #     justify no longer passes on a small one
        worth = np.where(curved, interval * (shrunk / demand), interval)
        cand = (galloc == 0) & (need > 0) & (head > 0.1) & (restart < worth)
        g1b, rem = _greedy_take(
            np.where(cand, demand, 0)[order_a], min_g[order_a], rem, True
        )
        galloc[order_a] += g1b

        # 2. top up to full demand, same order (the guarantee slice is
        #    already safe); a job skipped by the all-or-nothing pass must
        #    not be partially admitted here, and a best-effort job only
        #    at or above its splice floor
        skipped = (galloc == 0) & (need > 0)
        want2 = np.where(skipped, 0, demand - galloc)
        floor2 = np.where(galloc == 0, min_g, 1)
        g2, rem = _greedy_take(want2[order_a], floor2[order_a], rem, True)
        galloc[order_a] += g2

        # 3. opportunistic expansion into spare capacity — only with real
        #    fleet slack, only for jobs admitted this interval.  Greedy
        #    marginal-utility water-filling over the scaling curves
        #    (scheduler/curves.py): a job's headroom up to ``expand_factor
        #    x demand`` splits at its saturation knee into a pre-knee
        #    chunk whose marginal GPU earns one full interval (the seed's
        #    linear pricing — and the WHOLE chunk for flat-curve jobs)
        #    and a post-knee chunk whose marginal GPU earns only
        #    ``sat_slope`` of one.  Filling in global descending-slope
        #    order therefore collapses to two blocks: every pre-knee
        #    chunk first, in scale-up order, then post-knee chunks by
        #    descending ``sat_slope`` (ties to scale-up order); a job's
        #    post-knee chunk is reachable only once its pre-knee chunk
        #    filled (concavity).  Each chunk is gated on the
        #    CostModel-charged resize burn.  Serving replica groups never
        #    expand past their autoscaler target: replicas beyond it buy
        #    no SLO, only churn
        nm = fleet.node_map
        slope_rows = None
        if rem > 0.1 * total:
            extra = (demand * (self.expand_factor - 1.0)).astype(np.int64)
            target = galloc + extra
            end_a = np.where(curved, np.clip(knee, galloc, target), target)
            if nm is not None:
                # splice ladder: a curved chunk boundary must be a world
                # size gang rounding keeps — a multiple of demand (the
                # boundary sits at/above demand whenever it exceeds
                # galloc) — or pass 3b would round a knee-capped grant
                # back down.  Post-boundary capacity is then priced at
                # sat_slope: conservative when the snap moved the
                # boundary below the true knee
                end_a = np.where(
                    curved,
                    np.maximum(end_a - end_a % demand, galloc),
                    end_a,
                )
            d_a = end_a - galloc
            d_b = target - end_a
            slope_b = sat * interval
            if cm is None:
                gate_a = np.ones(n, dtype=bool)
                gate_b = gate_a
            else:
                free_event = ~running | (galloc != alloc0)
                gain_a = d_a.astype(np.float64) * interval
                burn_a = resize_s * (galloc + d_a).astype(np.float64)
                gate_a = free_event | (burn_a < gain_a)
                # past the knee, a job whose pre-knee chunk already paid
                # for the resize only needs the marginal GPU to out-earn
                # its own burn; a job sitting AT its knee pays the fixed
                # burn against the flat-slope gain instead
                burn_b = resize_s * (galloc + d_b).astype(np.float64)
                gate_b = np.where(
                    d_a > 0,
                    gate_a & (free_event | (slope_b > resize_s)),
                    free_event | (burn_b < slope_b * d_b.astype(np.float64)),
                )
            cand_a = (galloc > 0) & (d_a > 0) & gate_a & ~svc
            cand_b = (galloc > 0) & (d_b > 0) & gate_b & ~svc
            order_s = np.lexsort((idx, sup))
            ones = np.ones(n, dtype=np.int64)
            g3, rem = _greedy_take(
                np.where(cand_a, d_a, 0)[order_s], ones[order_s], rem, True
            )
            grant_a = np.zeros(n, dtype=np.int64)
            grant_a[order_s] = g3
            galloc += grant_a
            grant_b = np.zeros(n, dtype=np.int64)
            if rem > 0 and cand_b.any():
                # concavity: the cheap chunk must fill before the dear one
                cand_b &= (d_a == 0) | (grant_a == d_a)
                order_b = np.lexsort((idx, sup, -slope_b))
                g3b, rem = _greedy_take(
                    np.where(cand_b, d_b, 0)[order_b], ones[order_b], rem, True
                )
                grant_b[order_b] = g3b
                galloc += grant_b
            if curved.any():
                slope_rows = np.flatnonzero(curved & (grant_a + grant_b > 0))

        # 3b. gang/splice rounding (node-granular fleets): a grant must be
        #     a world size the splice mechanism supports — a divisor or
        #     multiple of demand — before placement shapes it onto nodes
        if nm is not None:
            galloc = gang_down_vec(galloc, demand)
            _gang_topup(galloc, demand, prio, int(total - galloc.sum()))

        # 4. enforce min_gpus (ZeRO partial-sharding floor): below it the
        #    job is preempted instead (checkpointed, zero lost work); only
        #    a job that was actually running is a preemption event
        below = (galloc > 0) & (galloc < min_g)
        preempt = below & running
        galloc[below] = 0

        # 5. placement
        galloc, placed, preempt, migrate, node_plan = self._place_vectorized(
            active, table, slots, fleet, galloc, min_g, demand, prio, running, preempt
        )

        clusters = fleet.clusters()
        if table is not None:
            ids = table.ids[slots]
        else:
            ids = [j.id for j in active]
        slope_expanded = (
            tuple(sorted(ids[i] for i in slope_rows))
            if slope_rows is not None and slope_rows.size
            else None
        )
        if table is not None:
            cluster_ids = [c.id for c in clusters]
            return Decision(
                alloc=_TableAlloc(ids, galloc, placed, cluster_ids),
                preemptions=sorted(ids[i] for i in np.flatnonzero(preempt)),
                migrations=sorted(ids[i] for i in np.flatnonzero(migrate)),
                table_update=(
                    (table, slots, galloc, placed)
                    if table.matches_clusters(cluster_ids)
                    else None
                ),
                node_plan=node_plan,
                slope_expanded=slope_expanded,
            )
        final: Dict[str, Tuple[int, Optional[str]]] = {}
        for i in range(n):
            cid = clusters[placed[i]].id if placed[i] >= 0 else None
            final[ids[i]] = (int(galloc[i]), cid)
        return Decision(
            alloc=final,
            preemptions=sorted(ids[i] for i in np.flatnonzero(preempt)),
            migrations=sorted(ids[i] for i in np.flatnonzero(migrate)),
            node_plan=node_plan,
            slope_expanded=slope_expanded,
        )

    def _place_vectorized(
        self,
        active: List[Job],
        table,
        slots: Optional[np.ndarray],
        fleet: Fleet,
        galloc: np.ndarray,
        min_g: np.ndarray,
        demand: np.ndarray,
        prio: np.ndarray,
        running: np.ndarray,
        preempt: np.ndarray,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, Optional[tuple]]:
        """Bin-pack allocations into clusters: keep placements that still
        fit, then region-aware defragmentation for the rest.

        The stay-put pass is a per-cluster cumsum greedy; the residual
        loop only visits jobs that actually hold GPUs, so its trip count
        is bounded by fleet capacity, not by queue depth.  On a fleet
        carrying a NodeMap, placement descends to node granularity
        (``_place_nodes``) and the decision carries the span plan.
        """
        n = len(active)
        clusters = fleet.clusters()
        cid_index = {c.id: k for k, c in enumerate(clusters)}
        regions = {r.id: k for k, r in enumerate(fleet.regions)}
        creg = np.fromiter(
            (regions[fleet.region_of(c.id)] for c in clusters),
            np.int64,
            len(clusters),
        )
        if table is not None and table.matches_clusters(cid_index):
            # table cluster codes below len(clusters) index fleet.clusters()
            # directly; codes past it are clusters this fleet doesn't know
            # (same as the object path's cid_index miss -> -1)
            raw = table.cluster_idx[slots]
            has_cluster = raw >= 0
            jcl = np.where(raw < len(clusters), raw, -1)
        else:
            jcl = np.fromiter(
                (cid_index.get(j.cluster, -1) for j in active), np.int64, n
            )
            has_cluster = np.fromiter((j.cluster is not None for j in active), bool, n)
        jreg = np.where(jcl >= 0, creg[np.maximum(jcl, 0)], -1)
        drain = np.fromiter((c.draining for c in clusters), bool, len(clusters))
        nm = fleet.node_map
        if nm is not None:
            if table is not None:
                rows = slots  # drivers register node rows at table slots
            else:
                rows = np.fromiter((j.node_slot for j in active), np.int64, n)
            return self._place_nodes(
                nm,
                active,
                rows,
                galloc,
                min_g,
                demand,
                prio,
                running,
                preempt,
                jcl,
                has_cluster,
                jreg,
                creg,
                drain,
            )
        free = np.fromiter((c.capacity() for c in clusters), np.int64, len(clusters))
        idx = np.arange(n)
        # guaranteed tiers and large allocations place first so basic
        # absorbs fragmentation
        order_p = np.lexsort((idx, -galloc, -prio))
        placed = np.full(n, -1, dtype=np.int64)

        # proactive migration off draining domains: a running job on a
        # cluster in its drain-warning window loses its stay-put right
        # when moving now costs less downtime than the work a failure
        # would destroy (unsnapshotted progress + the restore it forces)
        no_stay = np.zeros(n, dtype=bool)
        any_drain = bool(drain.any())
        if any_drain:
            on_draining = (
                (jcl >= 0) & running & (galloc > 0) & drain[np.maximum(jcl, 0)]
            )
            for i in np.flatnonzero(on_draining):
                no_stay[i] = self._proactive_move(active[i])

        # keep existing placement when it still fits (no gratuitous moves)
        stay = order_p[
            (galloc[order_p] > 0) & (jcl[order_p] >= 0) & ~no_stay[order_p]
        ]
        for k in range(len(clusters)):
            sel = stay[jcl[stay] == k]
            if sel.size:
                g, left = _greedy_take(
                    galloc[sel], galloc[sel], int(free[k]), partial=False
                )
                placed[sel[g > 0]] = k
                free[k] = left

        migrate = np.zeros(n, dtype=bool)
        # only jobs that actually hold GPUs enter the Python loop: its
        # trip count is bounded by fleet capacity, not queue depth
        for i in order_p[galloc[order_p] > 0]:
            g = int(galloc[i])
            if g == 0 or placed[i] >= 0:
                continue
            fits = free >= g
            if fits.any():
                # defrag: most-free cluster, avoiding draining domains
                # when a healthy one fits; a running job prefers to stay
                # in-region (cross-region moves pay the slower blob tier)
                pool = fits
                if any_drain:
                    nd = fits & ~drain
                    if nd.any():
                        pool = nd
                if running[i] and jreg[i] >= 0:
                    same = pool & (creg == jreg[i])
                    if same.any():
                        pool = same
                k = int(np.argmax(np.where(pool, free, -1)))
                placed[i] = k
                free[k] -= g
            else:
                # cannot fit contiguously anywhere -> shrink to the
                # biggest hole (preferring healthy clusters), but never
                # below the ZeRO splice floor (§5.4): below that the job
                # is preempted
                if any_drain:
                    k = int(np.argmax(np.where(~drain, free, -1)))
                    if drain.all() or free[k] < min_g[i]:
                        k = int(np.argmax(free))
                else:
                    k = int(np.argmax(free))
                hole = int(free[k])
                if hole < min_g[i]:
                    galloc[i] = 0
                    if running[i]:
                        preempt[i] = True
                    continue
                galloc[i] = hole
                placed[i] = k
                free[k] = 0
            if running[i] and has_cluster[i] and placed[i] != jcl[i]:
                migrate[i] = True
        return galloc, placed, preempt, migrate, None

    def _place_nodes(
        self,
        nm,
        active: List[Job],
        rows: np.ndarray,
        galloc: np.ndarray,
        min_g: np.ndarray,
        demand: np.ndarray,
        prio: np.ndarray,
        running: np.ndarray,
        preempt: np.ndarray,
        jcl: np.ndarray,
        has_cluster: np.ndarray,
        jreg: np.ndarray,
        creg: np.ndarray,
        drain: np.ndarray,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, tuple]:
        """Node placement entry for both decide paths: dispatch to the
        batched core (production) or the per-job loop it is
        digest-checked against (``node_batch=False``), accumulating the
        node-pass share of decide time in the profiler's ``place`` span
        (surfaced as ``node_seconds``)."""
        with self.prof.span("place"):
            core = (
                self._place_nodes_batched
                if self.node_batch
                else self._place_nodes_loop
            )
            return core(
                nm,
                active,
                rows,
                galloc,
                min_g,
                demand,
                prio,
                running,
                preempt,
                jcl,
                has_cluster,
                jreg,
                creg,
                drain,
            )

    def _place_nodes_loop(
        self,
        nm,
        active: List[Job],
        rows: np.ndarray,
        galloc: np.ndarray,
        min_g: np.ndarray,
        demand: np.ndarray,
        prio: np.ndarray,
        running: np.ndarray,
        preempt: np.ndarray,
        jcl: np.ndarray,
        has_cluster: np.ndarray,
        jreg: np.ndarray,
        creg: np.ndarray,
        drain: np.ndarray,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, tuple]:
        """Node-granular placement over a ``PlacementOverlay``.

        Grants arrive gang-rounded.  An unchanged running job whose span
        already matches keeps it untouched (zero work — the common case
        that bounds decide time); every other span is released into the
        overlay and re-fit: first onto the job's own cluster when a gang
        fit exists there, then pool selection with the cluster-granular
        preferences (healthy over draining, same-region for running
        jobs, most aggregate free capacity, lowest index).  The fit test
        prefers a clean gang shape — ``w`` empty nodes plus a best-fit
        remainder hole, computed as cached segment reductions over the
        overlay's node columns — and falls back to a scattered
        multi-piece fill wherever the aggregate free capacity suffices
        (legal under the device-proxy; the locality loss is what the
        fragmentation metric and defrag pass track).  Only when no
        cluster fits the gang even scattered does the job shrink down
        the splice-compatible ladder into the best healthy cluster
        (preempted below its floor).

        This per-job loop is the placement ORACLE: the batched core
        (``_place_nodes_batched``, the production path) must reproduce
        its plans byte-for-byte — the digest equivalence gates pin the
        two against each other on every bench trace.  Both decide paths
        dispatch here on identically-derived inputs, so span plans — and
        therefore failure blast radii — cannot drift between the scalar
        oracle and the vectorized path."""
        n = galloc.size
        idx = np.arange(n)
        order_p = np.lexsort((idx, -galloc, -prio))
        any_drain = bool(drain.any())
        no_stay = np.zeros(n, dtype=bool)
        if any_drain:
            on_draining = (
                (jcl >= 0) & running & (galloc > 0) & drain[np.maximum(jcl, 0)]
            )
            for i in np.flatnonzero(on_draining):
                no_stay[i] = self._proactive_move(active[i])

        ov = nm.overlay()
        has_span, span_k, span_tot = nm.row_state(rows)
        placed = np.full(n, -1, dtype=np.int64)
        migrate = np.zeros(n, dtype=bool)
        # trivially kept: same cluster, same world size, allowed to stay
        # -> the physical span is already correct, nothing to do
        kept = (
            (galloc > 0)
            & has_span
            & (span_k == jcl)
            & (span_tot == galloc)
            & ~no_stay
        )
        placed[kept] = jcl[kept]
        for i in np.flatnonzero(has_span & ~kept):
            ov.release_row(int(rows[i]))

        changed = order_p[(galloc[order_p] > 0) & ~kept[order_p]]
        fresh: dict = {}  # job index -> its entry in ov.assigns
        # phase A (mirrors the stay-put pass): resized/restored jobs stay
        # on their cluster when a gang fit exists there
        staying = np.zeros(n, dtype=bool)
        for i in changed:
            k = int(jcl[i])
            if (
                k >= 0
                and not no_stay[i]
                and (ov.feasible(k, int(galloc[i])) or ov.cfree[k] >= galloc[i])
            ):
                ov.fit_any(int(rows[i]), k, int(galloc[i]))
                placed[i] = k
                staying[i] = True
                fresh[int(i)] = len(ov.assigns) - 1
        # phase B: residual pool, cluster preferences unchanged from the
        # cluster-granular path but with gang feasibility as the fit test
        for i in changed:
            if staying[i]:
                continue
            g = int(galloc[i])
            feas = ov.feasible_vec(g)
            if not feas.any():
                # no clean gang shape anywhere: scattered placement is
                # still legal wherever the aggregate free capacity fits
                feas = ov.cfree >= g
            if feas.any():
                pool = feas
                if any_drain:
                    nd = feas & ~drain
                    if nd.any():
                        pool = nd
                if running[i] and jreg[i] >= 0:
                    same = pool & (creg == jreg[i])
                    if same.any():
                        pool = same
                k = int(np.argmax(np.where(pool, ov.cfree, -1)))
            else:
                # no cluster hosts the full gang even scattered: shrink
                # down the splice ladder into the best healthy cluster
                if any_drain and not drain.all():
                    k = int(np.argmax(np.where(~drain, ov.cfree, -1)))
                    v = gang_down(int(min(g, ov.cfree[k])), int(demand[i]))
                    if v < int(min_g[i]):
                        k = int(np.argmax(ov.cfree))
                        v = gang_down(int(min(g, ov.cfree[k])), int(demand[i]))
                else:
                    k = int(np.argmax(ov.cfree))
                    v = gang_down(int(min(g, ov.cfree[k])), int(demand[i]))
                if v < int(min_g[i]):
                    v = 0
                if v == 0:
                    galloc[i] = 0
                    if running[i]:
                        preempt[i] = True
                    continue
                galloc[i] = v
                g = v
            ov.fit_any(int(rows[i]), k, g)
            placed[i] = k
            fresh[int(i)] = len(ov.assigns) - 1
            if running[i] and has_cluster[i] and placed[i] != jcl[i]:
                migrate[i] = True
        # phase C: work conservation — grow placed jobs back up their
        # splice ladder into capacity left idle by gang rounding and
        # shrink-to-fit, highest priority first.  Growth stays on the
        # job's cluster (no migration; the allocation change is charged
        # as a resize like any other).
        left = int(ov.cfree.sum())
        if left > 0:
            for i in order_p:
                if left <= 0:
                    break
                k = int(placed[i])
                if k >= 0:
                    # grow a placed job toward its demand
                    if galloc[i] >= demand[i]:
                        continue
                    rem = int(ov.cfree[k])
                    if rem <= 0:
                        continue
                    g = int(galloc[i])
                    hi_v = min(int(demand[i]), g + rem)
                    lad = gang_values(int(demand[i]), g + 1, hi_v)
                    if not lad:
                        continue
                    v = int(lad[0])
                    ii = int(i)
                    if ii in fresh:
                        ov.undo(fresh[ii])
                    else:
                        ov.release_row(int(rows[i]))
                    ov.fit_any(int(rows[i]), k, v)
                    fresh[ii] = len(ov.assigns) - 1
                    galloc[i] = v
                    left -= v - g
                    continue
                # admit a waiting job at the largest compatible gang the
                # best cluster still holds (rescues grants the ledger's
                # gang rounding zeroed below the job's floor)
                d_i, m_i = int(demand[i]), int(min_g[i])
                if any_drain and not drain.all():
                    k = int(np.argmax(np.where(~drain, ov.cfree, -1)))
                    v = gang_down(int(min(d_i, ov.cfree[k])), d_i)
                    if v < m_i:
                        k = int(np.argmax(ov.cfree))
                        v = gang_down(int(min(d_i, ov.cfree[k])), d_i)
                else:
                    k = int(np.argmax(ov.cfree))
                    v = gang_down(int(min(d_i, ov.cfree[k])), d_i)
                if v <= 0 or v < m_i:
                    continue
                ov.fit_any(int(rows[i]), k, v)
                fresh[int(i)] = len(ov.assigns) - 1
                placed[i] = k
                galloc[i] = v
                left -= v
                preempt[i] = False
                if running[i] and has_cluster[i] and k != int(jcl[i]):
                    migrate[i] = True
        assigns = [a for a in ov.assigns if a is not None]
        return galloc, placed, preempt, migrate, (nm, ov.released, assigns)

    def _place_nodes_batched(
        self,
        nm,
        active: List[Job],
        rows: np.ndarray,
        galloc: np.ndarray,
        min_g: np.ndarray,
        demand: np.ndarray,
        prio: np.ndarray,
        running: np.ndarray,
        preempt: np.ndarray,
        jcl: np.ndarray,
        has_cluster: np.ndarray,
        jreg: np.ndarray,
        creg: np.ndarray,
        drain: np.ndarray,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, tuple]:
        """Batched node placement: byte-identical plans to the per-job
        loop oracle (``_place_nodes_loop``), derived as array passes.

        Three exact reductions carry the phases:

        * Phase A — the oracle keeps a changed job on its own cluster
          when ``feasible(k, g) or cfree[k] >= g``; a feasible gang
          always fits the aggregate, so the test is just
          ``cfree[k] >= g`` and the per-cluster admissions are the same
          cumsum greedy (``_greedy_take``) the cluster-granular stay-put
          pass uses.  The winning fits replay in changed order through
          ``fit_batch``, which collapses runs of identical whole-node
          shapes into slices.
        * Phase B keeps the oracle's loop shape (its trip count is
          bounded by jobs holding GPUs, not queue depth), but the pool
          pick is ``PlacementOverlay.pick_cluster``, which answers the
          oracle's ``argmax(where(pool, cfree, -1))`` (argmax ties
          break low) by walking a lazily-validated max-heap of
          ``(-cfree, k)`` entries — the heap order *is* the argmax
          order, so the first gang-feasible valid head is the answer —
          with a K-cluster scan only for drain/region-filtered picks.
        * Phase C — a candidate acts only when a watched capacity
          counter reaches its precomputed threshold: growth of a placed
          job fires iff its cluster's free count covers the next rung of
          its divisor ladder, admission of a queued job fires iff the
          fleet-wide max cluster free covers its smallest admissible
          gang (``floor_gang``).  Phase C only consumes capacity, so the
          counters are non-increasing between visits: a chunked scan
          against chunk-start counters passes a superset of the oracle's
          actors, and each hit re-runs the oracle's own body, which
          rejects exactly the stale ones.  The 1M-job scan thus touches
          Python only for jobs that actually grow or admit."""
        n = galloc.size
        idx = np.arange(n)
        order_p = np.lexsort((idx, -galloc, -prio))
        any_drain = bool(drain.any())
        no_stay = np.zeros(n, dtype=bool)
        if any_drain:
            on_draining = (
                (jcl >= 0) & running & (galloc > 0) & drain[np.maximum(jcl, 0)]
            )
            for i in np.flatnonzero(on_draining):
                no_stay[i] = self._proactive_move(active[i])

        ov = nm.overlay()
        has_span, span_k, span_tot = nm.row_state(rows)
        placed = np.full(n, -1, dtype=np.int64)
        migrate = np.zeros(n, dtype=bool)
        kept = (
            (galloc > 0)
            & has_span
            & (span_k == jcl)
            & (span_tot == galloc)
            & ~no_stay
        )
        placed[kept] = jcl[kept]
        ov.release_rows(rows[has_span & ~kept])

        changed = order_p[(galloc[order_p] > 0) & ~kept[order_p]]
        fresh: dict = {}  # job index -> its entry in ov.assigns
        # phase A: per-cluster cumsum greedy over the changed jobs that
        # may stay put, then one fit_batch replay in changed order
        with self.prof.span("phase_a"):
            staying = np.zeros(n, dtype=bool)
            elig = changed[(jcl[changed] >= 0) & ~no_stay[changed]]
            if elig.size:
                for k in np.unique(jcl[elig]):
                    sel = elig[jcl[elig] == k]
                    g, _ = _greedy_take(
                        galloc[sel], galloc[sel], int(ov.cfree[k]), partial=False
                    )
                    staying[sel[g > 0]] = True
                st = changed[staying[changed]]
                if st.size:
                    placed[st] = jcl[st]
                    base = len(ov.assigns)
                    ov.fit_batch(rows[st], jcl[st], galloc[st])
                    for t, i in enumerate(st):
                        fresh[int(i)] = base + t
        # phase B: residual pool picks — the oracle loop's pool filters,
        # but each pick is the overlay's heap-walk pick_cluster instead
        # of K-wide vector math, and the per-job columns are
        # pre-gathered to python lists so the loop never touches numpy
        # scalars
        with self.prof.span("phase_b"):
            drain_l = drain.tolist() if any_drain else None
            all_drain = bool(drain.all()) if any_drain else False
            creg_l = creg.tolist()
            ch_l = changed.tolist()
            stay_l = staying[changed].tolist()
            g_l = galloc[changed].tolist()
            run_l = running[changed].tolist()
            jreg_l = jreg[changed].tolist()
            rows_l = rows[changed].tolist()
            jcl_l = jcl[changed].tolist()
            hasc_l = has_cluster[changed].tolist()
            for t, i in enumerate(ch_l):
                if stay_l[t]:
                    continue
                g = g_l[t]
                want = jreg_l[t] if run_l[t] and jreg_l[t] >= 0 else -1
                k = ov.pick_cluster(g, drain_l, want, creg_l)
                if k < 0:
                    if any_drain and not all_drain:
                        k = ov.best_healthy(drain_l)
                        v = gang_down(min(g, ov._cfree[k]), int(demand[i]))
                        if v < int(min_g[i]):
                            k = ov.best_cluster()
                            v = gang_down(min(g, ov._cfree[k]), int(demand[i]))
                    else:
                        k = ov.best_cluster()
                        v = gang_down(min(g, ov._cfree[k]), int(demand[i]))
                    if v < int(min_g[i]):
                        v = 0
                    if v == 0:
                        galloc[i] = 0
                        if run_l[t]:
                            preempt[i] = True
                        continue
                    galloc[i] = v
                    g = v
                ov.fit_any(rows_l[t], k, g)
                placed[i] = k
                fresh[i] = len(ov.assigns) - 1
                if run_l[t] and hasc_l[t] and k != jcl_l[t]:
                    migrate[i] = True
        # phase C: work conservation as a threshold scan (see docstring)
        with self.prof.span("phase_c"):
            left = int(ov.cfree.sum())
            if left > 0:
                cand = order_p[
                    (placed[order_p] < 0) | (galloc[order_p] < demand[order_p])
                ]
                never = np.int64(2**62)
                thr = np.full(cand.size, never)
                wk = np.full(cand.size, -1, np.int64)
                grow = placed[cand] >= 0
                gi = cand[grow]
                if gi.size:
                    wk[grow] = placed[gi]
                    gg = galloc[gi]
                    dd = demand[gi]
                    delta = np.empty(gi.size, np.int64)
                    for d in np.unique(dd):
                        m = dd == d
                        divs = np.asarray(splice_divisors(int(d)), np.int64)
                        # next compatible world size above the current grant
                        delta[m] = (
                            divs[np.searchsorted(divs, gg[m], side="right")]
                            - gg[m]
                        )
                    thr[grow] = delta
                ai = cand[~grow]
                if ai.size:
                    dd = demand[ai]
                    mm = np.maximum(1, min_g[ai])
                    base_m = int(mm.max()) + 1
                    uk, inv = np.unique(dd * base_m + mm, return_inverse=True)
                    ut = np.fromiter(
                        (
                            floor_gang(int(u) // base_m, int(u) % base_m)
                            for u in uk
                        ),
                        np.int64,
                        uk.size,
                    )
                    tau = ut[inv]
                    thr[~grow] = np.where(tau > 0, tau, never)
                ch = 4096
                pos = 0
                while pos < cand.size and left > 0:
                    lim = min(pos + ch, cand.size)
                    cw = wk[pos:lim]
                    m_free = int(ov.cfree.max())
                    cur = np.where(cw >= 0, ov.cfree[np.maximum(cw, 0)], m_free)
                    for i in cand[pos:lim][cur >= thr[pos:lim]]:
                        if left <= 0:
                            break
                        k = int(placed[i])
                        if k >= 0:
                            if galloc[i] >= demand[i]:
                                continue
                            rem = int(ov.cfree[k])
                            if rem <= 0:
                                continue
                            g = int(galloc[i])
                            hi_v = min(int(demand[i]), g + rem)
                            lad = gang_values(int(demand[i]), g + 1, hi_v)
                            if not lad:
                                continue
                            v = int(lad[0])
                            ii = int(i)
                            if ii in fresh:
                                ov.undo(fresh[ii])
                            else:
                                ov.release_row(int(rows[i]))
                            ov.fit_any(int(rows[i]), k, v)
                            fresh[ii] = len(ov.assigns) - 1
                            galloc[i] = v
                            left -= v - g
                            continue
                        d_i, m_i = int(demand[i]), int(min_g[i])
                        if any_drain and not drain.all():
                            k = int(np.argmax(np.where(~drain, ov.cfree, -1)))
                            v = gang_down(int(min(d_i, ov.cfree[k])), d_i)
                            if v < m_i:
                                k = int(np.argmax(ov.cfree))
                                v = gang_down(int(min(d_i, ov.cfree[k])), d_i)
                        else:
                            k = int(np.argmax(ov.cfree))
                            v = gang_down(int(min(d_i, ov.cfree[k])), d_i)
                        if v <= 0 or v < m_i:
                            continue
                        ov.fit_any(int(rows[i]), k, v)
                        fresh[int(i)] = len(ov.assigns) - 1
                        placed[i] = k
                        galloc[i] = v
                        left -= v
                        preempt[i] = False
                        if running[i] and has_cluster[i] and k != int(jcl[i]):
                            migrate[i] = True
                    pos = lim
        assigns = [a for a in ov.assigns if a is not None]
        return galloc, placed, preempt, migrate, (nm, ov.released, assigns)

    def _proactive_move(self, j: Job) -> bool:
        """Should a running job evacuate its draining cluster now?

        Moving costs one migration's downtime (intra price as the lower
        bound — the destination is only chosen afterwards).  Staying
        risks the domain's deadline: the unsnapshotted progress is lost
        and the job pays a restore anyway.  Evacuate when the move is
        cheaper than the work it saves."""
        lost = max(0.0, j.progress - j.snap_progress) * j.ideal_seconds
        if self.cost_model is None:
            return lost > 0.0
        cb = j.checkpoint_bytes
        at_risk = lost + self.cost_model.restore_seconds(cb)
        return self.cost_model.migrate_seconds(cb) < at_risk

    # ================= scalar reference oracle ===========================
    def _decide_reference(
        self, now: float, active: List[Job], fleet: Fleet
    ) -> Decision:
        """Pure-Python oracle with semantics identical to the vectorized
        path (property-tested equivalence); kept for auditability and as
        the ground truth the numpy passes are checked against."""
        n = len(active)
        interval = self._interval()
        total = fleet.capacity()
        need = [self._required(now, j) for j in active]
        head = [
            active[i].account.headroom(now)
            if TIERS[active[i].tier].gpu_fraction > 0
            else float("inf")
            for i in range(n)
        ]
        vcost = [self._victim_cost(j) for j in active]
        restart = [self._restart_cost(j) for j in active]
        running = [j.allocated > 0 for j in active]

        # fairness aging, same per-tier formula as the vectorized path
        threshold = self.aging_threshold_intervals * interval
        wait = [now - j.queued_since for j in active]
        rate = [self._aging_by_tier[j.tier] for j in active]
        aged = [
            rate[i] > 0.0
            and not running[i]
            and TIERS[active[i].tier].gpu_fraction > 0
            and wait[i] > threshold
            for i in range(n)
        ]
        score = [
            vcost[i]
            if running[i]
            else (rate[i] * (wait[i] - threshold) if aged[i] else 0.0)
            for i in range(n)
        ]

        order_a = sorted(
            range(n),
            key=lambda i: (
                -TIERS[active[i].tier].preempt_priority,
                0 if active[i].service else 1,
                0 if (running[i] or aged[i]) else 1,
                -score[i],
                active[i].arrival,
                i,
            ),
        )
        galloc = [0] * n
        used = 0

        # 1. guaranteed demands, all-or-nothing
        for i in order_a:
            if need[i] > 0 and total - used >= need[i]:
                galloc[i] = need[i]
                used += need[i]

        # 1b. shrink-before-queue (restart-cost gated; curved jobs price
        #     the interval's buy at the shrunk operating point, like the
        #     vectorized pass)
        for i in order_a:
            if galloc[i] > 0 or need[i] == 0:
                continue
            j = active[i]
            if self.curve_aware and j.knee_gpus > 0:
                worth = interval * (need[i] / j.demand_gpus)
            else:
                worth = interval
            if head[i] <= 0.1 or restart[i] >= worth:
                continue
            give = min(j.demand_gpus, total - used)
            if give >= j.min_gpus:
                galloc[i] = give
                used += give

        # 2. top up to full demand
        for i in order_a:
            if galloc[i] == 0 and need[i] > 0:
                continue  # not admitted this interval
            give = min(active[i].demand_gpus - galloc[i], total - used)
            if galloc[i] == 0 and give < active[i].min_gpus:
                continue  # below the ZeRO floor: keep it queued
            if give > 0:
                galloc[i] += give
                used += give

        # 3. slope-gated opportunistic expansion: the scalar mirror of the
        #    vectorized water-filling pass (see _decide_vectorized pass 3
        #    for the chunking/pricing rationale)
        nm = fleet.node_map
        slope_ids: set = set()
        if total - used > 0.1 * total:
            cm = self.cost_model
            chunks = []  # (d_a, d_b, slope_b, gate_a, gate_b, is_curved)
            for i in range(n):
                j = active[i]
                extra = int(j.demand_gpus * (self.expand_factor - 1))
                target = galloc[i] + extra
                is_curved = self.curve_aware and j.knee_gpus > 0
                if is_curved:
                    end_a = min(max(j.knee_gpus, galloc[i]), target)
                    if nm is not None:
                        end_a = max(end_a - end_a % j.demand_gpus, galloc[i])
                else:
                    end_a = target
                d_a = end_a - galloc[i]
                d_b = target - end_a
                slope_b = j.sat_slope * interval
                if cm is None:
                    gate_a = gate_b = True
                else:
                    free = not running[i] or galloc[i] != j.allocated
                    rs = cm.resize_seconds(j.checkpoint_bytes)
                    gate_a = (
                        free or rs * float(galloc[i] + d_a) < float(d_a) * interval
                    )
                    if d_a > 0:
                        gate_b = gate_a and (free or slope_b > rs)
                    else:
                        gate_b = (
                            free
                            or rs * float(galloc[i] + d_b) < slope_b * float(d_b)
                        )
                chunks.append((d_a, d_b, slope_b, gate_a, gate_b, is_curved))
            order_s = sorted(
                range(n),
                key=lambda i: (TIERS[active[i].tier].scaleup_priority, i),
            )
            grant_a = [0] * n
            grant_b = [0] * n
            for i in order_s:
                d_a, _, _, gate_a, _, _ = chunks[i]
                if galloc[i] == 0 or active[i].service:
                    continue  # serving never expands past its target
                if d_a <= 0 or not gate_a:
                    continue
                give = min(d_a, total - used)
                if give > 0:
                    grant_a[i] = give
                    galloc[i] += give
                    used += give
            order_b = sorted(
                range(n),
                key=lambda i: (
                    -chunks[i][2],
                    TIERS[active[i].tier].scaleup_priority,
                    i,
                ),
            )
            for i in order_b:
                d_a, d_b, _, _, gate_b, _ = chunks[i]
                if galloc[i] - grant_a[i] == 0 or active[i].service:
                    continue
                if d_b <= 0 or not gate_b:
                    continue
                if d_a > 0 and grant_a[i] != d_a:
                    continue  # concavity: cheap chunk fills first
                give = min(d_b, total - used)
                if give > 0:
                    grant_b[i] = give
                    galloc[i] += give
                    used += give
            for i in range(n):
                if chunks[i][5] and grant_a[i] + grant_b[i] > 0:
                    slope_ids.add(active[i].id)

        # 3b. gang/splice rounding + ladder top-up, same point and same
        #     routine as the vectorized path
        if nm is not None:
            for i in range(n):
                galloc[i] = gang_down(galloc[i], active[i].demand_gpus)
            arr = np.asarray(galloc, np.int64)
            _gang_topup(
                arr,
                np.fromiter((j.demand_gpus for j in active), np.int64, n),
                np.fromiter(
                    (TIERS[j.tier].preempt_priority for j in active), np.int64, n
                ),
                int(total - arr.sum()),
            )
            galloc = [int(v) for v in arr]

        # 4. splice floor -> preempt
        preempted = set()
        for i in range(n):
            if 0 < galloc[i] < active[i].min_gpus:
                if running[i]:
                    preempted.add(i)
                galloc[i] = 0

        # 5. placement (node-granular when the fleet carries a NodeMap:
        # the reference path derives the same inputs per job in Python
        # and runs the same placement core, so span plans cannot drift)
        slope_expanded = tuple(sorted(slope_ids)) if slope_ids else None
        if nm is not None:
            return self._place_reference_nodes(
                active, fleet, nm, galloc, preempted, slope_expanded
            )
        clusters = fleet.clusters()
        free = {c.id: c.capacity() for c in clusters}
        cdrain = {c.id: c.draining for c in clusters}
        cluster_region = {c.id: fleet.region_of(c.id) for c in clusters}
        order_ids = {c.id: k for k, c in enumerate(clusters)}
        order_p = sorted(
            range(n),
            key=lambda i: (
                -TIERS[active[i].tier].preempt_priority,
                -galloc[i],
                i,
            ),
        )
        placements: Dict[int, str] = {}
        for i in order_p:
            j = active[i]
            if galloc[i] > 0 and j.cluster in free and free[j.cluster] >= galloc[i]:
                # a running job on a draining cluster evacuates instead of
                # staying put when the move saves more work than it costs
                if running[i] and cdrain[j.cluster] and self._proactive_move(j):
                    continue
                placements[i] = j.cluster
                free[j.cluster] -= galloc[i]
        migrations = set()
        for i in order_p:
            j = active[i]
            g = galloc[i]
            if g == 0 or i in placements:
                continue
            fitting = [c for c in free if free[c] >= g]
            if fitting:
                healthy = [c for c in fitting if not cdrain[c]]
                if healthy:
                    fitting = healthy
                region = cluster_region.get(j.cluster)
                if running[i] and region is not None:
                    same = [c for c in fitting if cluster_region[c] == region]
                    if same:
                        fitting = same
                cid = min(fitting, key=lambda c: (-free[c], order_ids[c]))
            else:
                healthy = [c for c in free if not cdrain[c]]
                cid = (
                    min(healthy, key=lambda c: (-free[c], order_ids[c]))
                    if healthy
                    else None
                )
                if cid is None or free[cid] < j.min_gpus:
                    cid = min(free, key=lambda c: (-free[c], order_ids[c]))
                hole = free[cid]
                if hole < j.min_gpus:
                    galloc[i] = 0
                    if running[i]:
                        preempted.add(i)
                    continue
                g = hole
                galloc[i] = g
            placements[i] = cid
            free[cid] -= g
            if running[i] and j.cluster is not None and cid != j.cluster:
                migrations.add(i)

        final = {active[i].id: (galloc[i], placements.get(i)) for i in range(n)}
        return Decision(
            alloc=final,
            preemptions=sorted(active[i].id for i in preempted),
            migrations=sorted(active[i].id for i in migrations),
            slope_expanded=slope_expanded,
        )

    def _place_reference_nodes(
        self,
        active: List[Job],
        fleet: Fleet,
        nm,
        galloc: List[int],
        preempted: set,
        slope_expanded: Optional[Tuple[str, ...]] = None,
    ) -> Decision:
        """Reference-path entry to node placement: gather the per-job
        state as the scalar loops see it, then run the shared placement
        core on it."""
        n = len(active)
        clusters = fleet.clusters()
        cid_index = {c.id: k for k, c in enumerate(clusters)}
        regions = {r.id: k for k, r in enumerate(fleet.regions)}
        creg = np.fromiter(
            (regions[fleet.region_of(c.id)] for c in clusters),
            np.int64,
            len(clusters),
        )
        jcl = np.fromiter((cid_index.get(j.cluster, -1) for j in active), np.int64, n)
        has_cluster = np.fromiter((j.cluster is not None for j in active), bool, n)
        jreg = np.where(jcl >= 0, creg[np.maximum(jcl, 0)], -1)
        drain = np.fromiter((c.draining for c in clusters), bool, len(clusters))
        rows = np.fromiter((j.node_slot for j in active), np.int64, n)
        g = np.asarray(galloc, np.int64)
        min_g = np.fromiter((j.min_gpus for j in active), np.int64, n)
        demand = np.fromiter((j.demand_gpus for j in active), np.int64, n)
        running = np.fromiter((j.allocated > 0 for j in active), bool, n)
        prio = np.fromiter(
            (TIERS[j.tier].preempt_priority for j in active), np.int64, n
        )
        preempt = np.zeros(n, dtype=bool)
        for i in preempted:
            preempt[i] = True
        g, placed, preempt, migrate, node_plan = self._place_nodes(
            nm,
            active,
            rows,
            g,
            min_g,
            demand,
            prio,
            running,
            preempt,
            jcl,
            has_cluster,
            jreg,
            creg,
            drain,
        )
        final: Dict[str, Tuple[int, Optional[str]]] = {}
        for i in range(n):
            cid = clusters[placed[i]].id if placed[i] >= 0 else None
            final[active[i].id] = (int(g[i]), cid)
        return Decision(
            alloc=final,
            preemptions=sorted(active[i].id for i in np.flatnonzero(preempt)),
            migrations=sorted(active[i].id for i in np.flatnonzero(migrate)),
            node_plan=node_plan,
            slope_expanded=slope_expanded,
        )

"""Scheduling policies.

``ElasticPolicy`` is Singularity's: every job is preemptible, migratable and
elastic, so the scheduler (a) never leaves capacity idle while work is
queued (opportunistic scale-up of running jobs / admission of basic jobs
anywhere in the fleet), (b) shrinks before it preempts, preempts strictly
by tier, (c) defragments by migrating small jobs to open contiguous
capacity for large arrivals, all while respecting GPU-fraction SLAs.

``StaticGangPolicy`` is the status-quo baseline: jobs are gang-scheduled at
full demand in FIFO order, never preempted, never resized — the comparison
that motivates the paper (§1: utilization/idling).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.sla import TIERS
from repro.scheduler.types import Cluster, Fleet, Job


def _tier_key(j: Job) -> Tuple[int, float]:
    # preemption order: basic first, then standard, then premium; later
    # arrivals preempted before earlier ones
    return (TIERS[j.tier].preempt_priority, -j.arrival)


@dataclasses.dataclass
class Decision:
    """Target allocation for the next interval: job -> (gpus, cluster)."""
    alloc: Dict[str, Tuple[int, Optional[str]]]
    preemptions: List[str]
    migrations: List[str]


class StaticGangPolicy:
    """FIFO gang scheduling without preemption/elasticity."""

    name = "static"

    def decide(self, now: float, jobs: List[Job], fleet: Fleet) -> Decision:
        free = {c.id: c.total_gpus for c in fleet.clusters()}
        for j in jobs:
            if j.done_at is None and j.allocated > 0:
                free[j.cluster] -= j.allocated
        alloc: Dict[str, Tuple[int, Optional[str]]] = {}
        for j in sorted(jobs, key=lambda j: j.arrival):
            if j.done_at is not None:
                continue
            if j.allocated > 0:
                alloc[j.id] = (j.allocated, j.cluster)   # never touched again
                continue
            # admit only if some cluster fits the FULL demand
            for cid, f in free.items():
                if f >= j.demand_gpus:
                    alloc[j.id] = (j.demand_gpus, cid)
                    free[cid] -= j.demand_gpus
                    break
            else:
                alloc[j.id] = (0, None)
        return Decision(alloc=alloc, preemptions=[], migrations=[])


class ElasticPolicy:
    """Singularity's policy: SLA-tiered, shrink-before-preempt, elastic
    expansion into spare capacity, migration-based defragmentation."""

    name = "elastic"

    def __init__(self, expand_factor: float = 2.0):
        self.expand_factor = expand_factor

    # -- helpers ---------------------------------------------------------
    def _required(self, now: float, j: Job) -> int:
        """GPUs needed this interval to keep the job's hourly SLA safe."""
        tier = TIERS[j.tier]
        if tier.gpu_fraction <= 0:
            return 0                       # basic: best effort
        # fraction delivered so far this window; demand enough to stay above
        headroom = j.account.headroom(now)
        if headroom > 0.1:
            # comfortably above guarantee -> can run shrunk this interval
            # (with a margin so the hourly window stays safe)
            frac = min(1.0, tier.gpu_fraction + 0.1)
            return max(j.min_gpus, int(j.demand_gpus * frac))
        return j.demand_gpus

    def decide(self, now: float, jobs: List[Job], fleet: Fleet) -> Decision:
        active = [j for j in jobs if j.done_at is None and j.arrival <= now]
        total = fleet.total()
        alloc: Dict[str, int] = {j.id: 0 for j in active}
        preempted: List[str] = []

        # 1. guaranteed tier demands, premium first, FIFO within tier.
        #    All-or-nothing per job: under overload it is better to run
        #    fewer jobs at guaranteed speed than all jobs too slow to meet
        #    any SLA (jobs skipped here queue with zero lost work).
        by_guarantee = sorted(
            active, key=lambda j: (-TIERS[j.tier].preempt_priority, j.arrival))
        used = 0
        for j in by_guarantee:
            need = self._required(now, j)
            if total - used >= need:
                alloc[j.id] = need
                used += need

        # 1b. shrink-before-queue: a guaranteed job whose full slice did not
        #     fit but which is comfortably above its hourly guarantee can run
        #     shrunk (>= min_gpus) this interval instead of queueing — the
        #     paper's shrink-before-preempt, applied at admission time
        for j in by_guarantee:
            if alloc[j.id] > 0 or self._required(now, j) == 0:
                continue
            if j.account.headroom(now) <= 0.1:
                continue        # guarantee at risk: all-or-nothing stands
            give = min(j.demand_gpus, total - used)
            if give >= j.min_gpus:
                alloc[j.id] = give
                used += give

        # 2. top up to full demand, same order (partial top-ups are fine —
        #    the guarantee slice is already safe); a job skipped by the
        #    all-or-nothing pass must not be partially admitted here, and a
        #    best-effort job is only admitted at or above its splice floor
        for j in by_guarantee:
            if alloc[j.id] == 0 and self._required(now, j) > 0:
                continue        # not admitted this interval
            want = j.demand_gpus - alloc[j.id]
            give = min(want, total - used)
            if alloc[j.id] == 0 and give < j.min_gpus:
                continue        # below the ZeRO floor: keep it queued
            if give > 0:
                alloc[j.id] += give
                used += give

        # 3. opportunistic expansion of elastic jobs into spare capacity —
        #    only when the fleet has real slack (avoid fragmenting under
        #    load), and only for jobs admitted this interval: handing spare
        #    GPUs to a job the guarantee pass skipped would partially admit
        #    it below its guarantee (or even below min_gpus)
        if total - used > 0.1 * total:
            for j in sorted(active,
                            key=lambda j: TIERS[j.tier].scaleup_priority):
                if total - used <= 0:
                    break
                if alloc[j.id] == 0:
                    continue
                extra = min(int(j.demand_gpus * (self.expand_factor - 1)),
                            total - used)
                if extra > 0:
                    alloc[j.id] += extra
                    used += extra

        # 4. enforce min_gpus (ZeRO partial-sharding floor): a job below its
        #    floor is preempted instead (checkpointed, zero lost work).  Only
        #    a job that was actually running is a preemption; zeroing a
        #    queued job's tentative allocation is not an event.
        for j in sorted(active, key=_tier_key):
            if 0 < alloc[j.id] < j.min_gpus:
                if j.allocated > 0:
                    preempted.append(j.id)
                alloc[j.id] = 0

        # 5. placement: bin-pack descending into clusters; count migrations
        placements, migrations = self._place(active, alloc, fleet)
        final = {jid: (alloc[jid], placements.get(jid)) for jid in alloc}
        return Decision(alloc=final, preemptions=preempted,
                        migrations=migrations)

    def _place(self, jobs: List[Job], alloc: Dict[str, int], fleet: Fleet
               ) -> Tuple[Dict[str, str], List[str]]:
        free = {c.id: c.total_gpus for c in fleet.clusters()}
        placements: Dict[str, str] = {}
        migrations: List[str] = []
        # guaranteed tiers place first so basic absorbs fragmentation
        order = sorted(jobs, key=lambda j: (
            -TIERS[j.tier].preempt_priority, -alloc[j.id]))
        # keep existing placement when it still fits (avoid gratuitous moves)
        for j in order:
            g = alloc[j.id]
            if g == 0:
                continue
            if j.cluster and free.get(j.cluster, 0) >= g:
                placements[j.id] = j.cluster
                free[j.cluster] -= g
        for j in order:
            g = alloc[j.id]
            if g == 0 or j.id in placements:
                continue
            # defrag: pick the cluster with the most free capacity
            cid = max(free, key=free.get)
            if free[cid] < g:
                # cannot fit contiguously anywhere -> shrink to the biggest
                # hole, but never below the ZeRO splice floor (§5.4): below
                # that the job is preempted (checkpointed, zero lost work)
                g = free[cid]
                if g < j.min_gpus:
                    g = 0
                alloc[j.id] = g
                if g == 0:
                    continue
            placements[j.id] = cid
            free[cid] -= g
            # transparent live migration — only a RUNNING job moving
            # cluster; a restore onto a new cluster is a restore, matching
            # the simulator's one-event classification
            if j.allocated > 0 and j.cluster is not None and j.cluster != cid:
                migrations.append(j.id)
        return placements, migrations

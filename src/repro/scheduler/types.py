"""Fleet and job model for the planet-scale scheduler simulation."""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, List, Optional

from repro.core.sla import TIERS, FleetSLAAccounts, GpuFractionAccount, SLAAccount
from repro.scheduler.costs import RegionTopology, default_checkpoint_bytes
from repro.scheduler.curves import scaling_eff, validate_curve

if TYPE_CHECKING:  # avoid the import cycle: job_table/node_map view Job
    from repro.scheduler.job_table import JobTable
    from repro.scheduler.node_map import NodeMap


@dataclasses.dataclass
class Cluster:
    """One cluster: ``total_gpus`` devices grouped into nodes of
    ``gpus_per_node`` (the failure-domain granularity between a single
    device flake and a whole-cluster outage).  ``dead_gpus`` is capacity
    currently taken out by an unrepaired failure; ``draining`` marks a
    planned drain in its advance-warning window (the policy avoids
    placing onto draining clusters and proactively migrates off them)
    with ``drain_deadline`` the wall time capacity actually dies."""

    id: str
    region: str
    total_gpus: int
    free_gpus: int = -1
    gpus_per_node: int = 8
    dead_gpus: int = 0
    draining: bool = False
    drain_deadline: float = 0.0

    def __post_init__(self):
        if self.free_gpus < 0:
            self.free_gpus = self.total_gpus

    def nodes(self) -> int:
        return max(1, -(-self.total_gpus // max(self.gpus_per_node, 1)))

    def node_capacities(self) -> List[int]:
        """Per-node GPU counts.  Ceil division used to pad a trailing
        partial node up to ``gpus_per_node``; the node vector keeps its
        TRUE smaller capacity so placement and failure blast radius see
        the hardware that exists."""
        gpn = max(self.gpus_per_node, 1)
        full, rem = divmod(self.total_gpus, gpn)
        caps = [gpn] * full
        if rem or not caps:
            caps.append(rem)
        return caps

    def capacity(self) -> int:
        """GPUs currently healthy (total minus failed-out capacity)."""
        return max(0, self.total_gpus - self.dead_gpus)


@dataclasses.dataclass
class Region:
    id: str
    clusters: List[Cluster]

    def total(self) -> int:
        return sum(c.total_gpus for c in self.clusters)

    def free(self) -> int:
        return sum(c.free_gpus for c in self.clusters)

    def capacity(self) -> int:
        return sum(c.capacity() for c in self.clusters)


@dataclasses.dataclass
class Fleet:
    """The global scheduler's world model: regions of clusters plus the
    inter-region transfer topology the cost model prices migrations
    against (``None`` = region-blind, every pair at blob bandwidth), the
    shared SLA ledger all active jobs' accounts live in (``None`` =
    per-job scalar accounts), and the shared ``JobTable`` the driver's
    jobs are adopted into (``None`` = plain scalar ``Job`` objects)."""

    regions: List[Region]
    topology: Optional[RegionTopology] = None
    sla: Optional[FleetSLAAccounts] = None
    jobs: Optional["JobTable"] = None
    # node-granular placement state owned by the current driver (None =
    # cluster-granular placement only, the pre-NodeMap behaviour); the
    # policy plans node spans exactly when this is attached
    node_map: Optional["NodeMap"] = None

    def total(self) -> int:
        return sum(r.total() for r in self.regions)

    def capacity(self) -> int:
        """Healthy GPUs fleet-wide — what the scheduler may allocate
        while failed-out domains await repair."""
        return sum(r.capacity() for r in self.regions)

    def free(self) -> int:
        return sum(r.free() for r in self.regions)

    def clusters(self) -> List[Cluster]:
        return [c for r in self.regions for c in r.clusters]

    def cluster_index(self) -> dict:
        """Cluster id -> flat fleet index, in ``clusters()`` order
        (cached; clusters are static for a fleet's lifetime).  The
        simulator's apply path and the telemetry event log both key
        clusters by this index."""
        idx = self.__dict__.get("_cluster_index")
        if idx is None:
            idx = {c.id: k for k, c in enumerate(self.clusters())}
            self.__dict__["_cluster_index"] = idx
        return idx

    def region_of(self, cluster_id: Optional[str]) -> Optional[str]:
        """Region id owning ``cluster_id`` (cached; clusters are static
        for a fleet's lifetime)."""
        if cluster_id is None:
            return None
        by_cluster = self.__dict__.get("_region_by_cluster")
        if by_cluster is None:
            by_cluster = {c.id: r.id for r in self.regions for c in r.clusters}
            self.__dict__["_region_by_cluster"] = by_cluster
        return by_cluster.get(cluster_id)


@dataclasses.dataclass
class Job:
    """A training job: demands N GPUs of work ``gpu_hours`` total.

    ``min_gpus`` encodes the ZeRO partial-sharding limit (§5.4): the job
    cannot be spliced below demand/max_splice devices.  ``elastic`` and
    ``preemptible`` are ALWAYS true in Singularity (the paper's point);
    the static baseline policy ignores them.
    """

    id: str
    tier: str  # premium | standard | basic
    demand_gpus: int
    gpu_hours: float  # total work in (demand_gpus x hours)
    arrival: float  # seconds
    min_gpus: int = 1
    splice_overhead: float = 0.03  # Fig-4 measured time-slicing overhead
    checkpoint_bytes: int = 0  # deduped snapshot size (Table 4); 0 = estimate
    # concave scaling curve (scheduler/curves.py): efficiency rises at
    # slope 1/demand up to the saturation knee, then at sat_slope/demand
    # to the 2x cap.  knee_gpus == 0 is the flat sentinel — the seed's
    # linear model exactly, so pre-curve traces stay byte-identical.
    knee_gpus: int = 0
    sat_slope: float = 1.0
    # latency-SLO serving replica group (scheduler/serving.py): demand is
    # retargeted every tick by the autoscaler and the policy must never
    # expand it past demand (replicas beyond the target buy no SLO)
    service: bool = False

    # runtime state
    allocated: int = 0
    cluster: Optional[str] = None
    progress: float = 0.0  # in [0, 1]
    done_at: Optional[float] = None
    preemptions: int = 0
    migrations: int = 0
    resizes: int = 0
    # filled by __post_init__ with a scalar account when the caller does
    # not supply one; the simulator/executor swap in a ledger-backed
    # FleetSlotAccount view so fleet-wide queries batch
    account: Optional[SLAAccount] = None
    # wall time this job last entered the queue (arrival, or the moment
    # of its last preemption); the policy's fairness aging reads it
    queued_since: float = -1.0
    # NodeMap row holding this job's node span (-1 = no driver assigned
    # one); set once by the simulator/executor, stable across the job's
    # lifetime — deliberately NOT a JobTable column, so it survives
    # adopt/detach untouched
    node_slot: int = -1

    # cost accounting (set by the simulator's cost model)
    downtime_until: float = 0.0  # no progress before this wall time
    downtime_seconds: float = 0.0  # total dead time charged so far
    restore_debt: float = 0.0  # preempt cost carried into the next restore
    ever_ran: bool = False  # has a checkpoint to restore from

    # reliability state (maintained by the simulator's failure machinery):
    # a durable snapshot exists at progress ``snap_progress`` taken at wall
    # time ``snap_time``; an unplanned failure rolls progress back to it.
    snap_progress: float = 0.0
    # None = "no snapshot recorded yet": __post_init__ fills the arrival
    # (initial state is restartable).  A sentinel, not a <= 0 clamp, so a
    # replayed/restored job with a legitimate snapshot AT t=0 keeps it.
    snap_time: Optional[float] = None
    failures: int = 0  # unplanned failures that killed this job's domain
    failed_at: Optional[float] = None  # pending failure awaiting restart

    def __post_init__(self):
        assert self.tier in TIERS
        if self.demand_gpus < 1:
            raise ValueError(
                f"job {self.id}: demand_gpus must be >= 1, got "
                f"{self.demand_gpus} (ideal_seconds divides by it)"
            )
        if not 1 <= self.min_gpus <= self.demand_gpus:
            raise ValueError(
                f"job {self.id}: min_gpus must satisfy 1 <= min_gpus <= "
                f"demand_gpus, got min_gpus={self.min_gpus} with "
                f"demand_gpus={self.demand_gpus}"
            )
        try:
            validate_curve(self.demand_gpus, self.knee_gpus, self.sat_slope)
        except ValueError as e:
            raise ValueError(f"job {self.id}: {e}") from None
        if self.account is None:
            self.account = GpuFractionAccount(self.tier, self.demand_gpus)
        if self.queued_since < 0.0:
            self.queued_since = self.arrival
        if self.checkpoint_bytes <= 0:
            self.checkpoint_bytes = default_checkpoint_bytes(self.demand_gpus)
        if self.snap_time is None:
            self.snap_time = self.arrival  # initial state = restartable

    @property
    def ideal_seconds(self) -> float:
        return self.gpu_hours * 3600.0 / self.demand_gpus

    def rate(self) -> float:
        """Progress per second given current allocation (work-conserving
        elasticity; scaled-down jobs pay the splicing overhead).  Above
        the saturation knee the marginal GPU buys only ``sat_slope`` of
        a linear GPU (scheduler/curves.py); the flat sentinel
        ``knee_gpus == 0`` keeps the seed's linear model."""
        if self.allocated <= 0 or self.done_at is not None:
            return 0.0
        eff = scaling_eff(
            self.allocated, self.demand_gpus, self.knee_gpus, self.sat_slope
        )
        if self.allocated < self.demand_gpus:
            eff *= 1.0 - self.splice_overhead
        return eff / self.ideal_seconds

    def remaining_seconds(self) -> float:
        r = self.rate()
        return float("inf") if r <= 0 else (1.0 - self.progress) / r

"""Elastic inference serving tier: SLO replica groups on the shared fleet.

Singularity's §1.1b claim is that inference and training share one
preemptible elastic fleet — the scheduler "elastically shrinks training to
absorb inference load".  This module makes latency-SLO services first-class
scheduler jobs:

* Each service is one guaranteed-tier ``Job`` (``service=True``) whose
  ``demand_gpus`` the simulator retargets every tick from a qps -> replicas
  curve (``ReplicaProfile`` from ``repro.serving.engine``) driven by a
  seeded diurnal+spike ``TrafficTrace``.
* **Capacity loaning** (Aryl, arXiv:2202.07896): the service's *reserved*
  quota covers the trace peak, but off-peak the autoscaler shrinks demand
  below it, and the freed GPUs flow to best-effort training through the
  ordinary allocation passes.  On a spike the retarget raises demand again
  and the policy's guaranteed-first admission preempts the borrowers in the
  same tick — reclaim latency is measured against a deadline charged from
  the ``CostModel``.
* **Predictive pre-warm** (arXiv:2010.05049): a Holt double-exponential
  forecaster (EWMA level + trend, the trend member of the Holt-Winters
  family — our traces are shorter than one seasonal period) raises replicas
  ahead of a ramp so the resize downtime lands *before* the traffic does; a
  reactive baseline scales on the observed qps and eats that warm-up inside
  the SLO window.

Everything here is pure numpy and deliberately policy-agnostic: demand
columns are mutated *before* ``ElasticPolicy.decide`` runs, so the
vectorized and scalar paths (table-backed or plain) see identical inputs
and the decision-digest equivalence gate extends over serving unchanged.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.scheduler.costs import CostModel, default_checkpoint_bytes
from repro.scheduler.telemetry import C_SPIKE, E_LOAN, E_RECLAIM
from repro.scheduler.types import Job
from repro.serving.engine import ReplicaProfile


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Seeded diurnal+spike qps generator parameters.

    The diurnal curve is a raised cosine between ``trough_fraction *
    peak_qps`` and ``peak_qps`` with a per-service random phase.  Spikes
    arrive as a Poisson process, multiply the diurnal value by a random
    amplitude, and rise over ``spike_ramp_seconds`` — a *ramp*, not a step,
    so a trend forecaster has something to extrapolate.
    """

    seed: int = 0
    sample_seconds: float = 60.0
    diurnal_period_seconds: float = 86400.0
    trough_fraction: float = 0.35
    spikes_per_day: float = 2.0
    spike_amplitude: tuple = (1.4, 1.6)
    spike_ramp_seconds: float = 600.0
    spike_hold_seconds: float = 900.0
    spike_decay_seconds: float = 900.0


@dataclasses.dataclass(frozen=True)
class ServiceSpec:
    """One latency-SLO service: a replica operating point plus its traffic
    scale.  ``peak_qps`` is the diurnal peak; spikes go above it and the
    reserved quota is sized from the realized trace maximum."""

    name: str
    profile: ReplicaProfile
    peak_qps: float
    min_replicas: int = 1


@dataclasses.dataclass
class ServingConfig:
    """Simulator-side serving tier configuration (``SimConfig.serving``)."""

    services: List[ServiceSpec]
    traffic: TrafficConfig = dataclasses.field(default_factory=TrafficConfig)
    autoscaler: str = "predictive"  # "predictive" | "reactive"
    # loan idle reserved capacity to best-effort training (False pins every
    # service at its reserved quota — the no-loaning baseline)
    loaning: bool = True
    # autoscaler sizes replicas for target_qps / (qps_per_replica * rho):
    # the 1/rho headroom is what absorbs within-window growth
    target_utilization: float = 0.75
    # consecutive ticks below target before scaling down (hysteresis)
    scale_down_ticks: int = 3
    # Holt double-exponential smoothing parameters and pre-warm lead
    holt_alpha: float = 0.6
    holt_beta: float = 0.5
    prewarm_lead_ticks: int = 2
    # fraction of a window the replicas may be warming before the window
    # is charged as an SLO violation
    warm_grace_fraction: float = 0.01
    # override the CostModel-derived reclaim deadline (seconds)
    reclaim_deadline_seconds: Optional[float] = None
    tier: str = "premium"
    # work per service job; large enough that a service never completes
    gpu_hours: float = 1e9
    # replicas are independent: a service schedules as up to this many
    # replica-group *shard* jobs so placement never needs one huge
    # contiguous gang and a spike's growth spreads across clusters
    shards_per_service: int = 4


class TrafficTrace:
    """Precomputed per-service qps series at ``sample_seconds`` resolution.

    Fully determined by (specs, config, horizon): both event loops and all
    policy paths read the same arrays, so serving stays digest-stable.
    """

    def __init__(
        self,
        specs: List[ServiceSpec],
        cfg: TrafficConfig,
        horizon_seconds: float,
    ):
        self.cfg = cfg
        self.sample_seconds = float(cfg.sample_seconds)
        n = int(math.ceil(horizon_seconds / self.sample_seconds)) + 2
        t = np.arange(n) * self.sample_seconds
        rng = np.random.Generator(np.random.Philox(cfg.seed))
        qps = np.zeros((len(specs), n))
        period = cfg.diurnal_period_seconds
        for s, spec in enumerate(specs):
            phase = float(rng.uniform(0.0, period))
            x = 0.5 * (1.0 - np.cos(2.0 * np.pi * (t - phase) / period))
            curve = spec.peak_qps * (
                cfg.trough_fraction + (1.0 - cfg.trough_fraction) * x
            )
            mult = np.ones(n)
            n_spikes = int(rng.poisson(cfg.spikes_per_day * horizon_seconds / 86400.0))
            for _ in range(n_spikes):
                t0 = float(rng.uniform(0.0, horizon_seconds))
                amp = float(rng.uniform(*cfg.spike_amplitude))
                rel = t - t0
                rise = np.clip(rel / cfg.spike_ramp_seconds, 0.0, 1.0)
                fall = np.clip(
                    1.0
                    - (rel - cfg.spike_ramp_seconds - cfg.spike_hold_seconds)
                    / cfg.spike_decay_seconds,
                    0.0,
                    1.0,
                )
                shape = np.where(rel >= 0.0, rise * fall, 0.0)
                mult = np.maximum(mult, 1.0 + (amp - 1.0) * shape)
            qps[s] = curve * mult
        self.qps = qps
        # the horizon the caller asked to cover; windows must START at or
        # before it (the +2-sample padding past it exists only so the
        # final in-simulation window has samples to read, not to serve
        # queries of its own)
        self.horizon_seconds = float(horizon_seconds)
        # last instant the trace covers; queries beyond it are errors,
        # not a silent flat replay of the final sample
        self.end_seconds = float((n - 1) * self.sample_seconds)

    def _check_start(self, t: float, what: str) -> None:
        if t > self.end_seconds:
            raise ValueError(
                f"traffic trace ends at t={self.end_seconds:.0f}s but "
                f"{what} t={t:.0f}s — build the trace with a horizon "
                "covering the simulation"
            )

    def at(self, now: float) -> np.ndarray:
        """Per-service qps observed at wall time ``now``.  Raises
        ``ValueError`` past the trace end instead of replaying the final
        sample forever."""
        self._check_start(now, "queried at")
        i = min(int(now / self.sample_seconds), self.qps.shape[1] - 1)
        return self.qps[:, i]

    def window_peak(self, t0: float, t1: float) -> np.ndarray:
        """Per-service max qps over samples in ``[t0, t1]``.  The window
        START must lie inside the simulated horizon — a start in the
        trailing sample padding (or beyond) raises like ``at`` does,
        instead of silently reading padding samples.  ``t1`` may overhang
        the trace end by part of one scheduler tick (the final
        in-simulation window: with ``t0 <= horizon`` the overhang is
        bounded by ``tick - sample``), in which case the peak covers the
        samples that exist."""
        if t0 > self.horizon_seconds:
            raise ValueError(
                f"traffic trace covers {self.horizon_seconds:.0f}s but "
                f"window starts at t={t0:.0f}s — build the trace with a "
                "horizon covering the simulation"
            )
        i0 = max(0, int(t0 / self.sample_seconds))
        i1 = min(int(math.ceil(t1 / self.sample_seconds)), self.qps.shape[1] - 1)
        return self.qps[:, i0 : i1 + 1].max(axis=1)

    def peak(self) -> np.ndarray:
        """Per-service trace maximum (what the reserved quota must cover)."""
        return self.qps.max(axis=1)


class ServiceTable:
    """SoA of per-service autoscaler + SLO-accounting state (the JobTable
    recipe: fixed columns, vectorized retarget, no per-service objects on
    the hot path)."""

    def __init__(self, specs: List[ServiceSpec], reserved_replicas: np.ndarray):
        n = len(specs)
        self.n = n
        self.names = [s.name for s in specs]
        self.gpus_per_replica = np.array(
            [s.profile.gpus_per_replica for s in specs], dtype=np.int64
        )
        self.qps_per_replica = np.array(
            [s.profile.qps_per_replica for s in specs], dtype=np.float64
        )
        self.min_replicas = np.array(
            [max(1, s.min_replicas) for s in specs], dtype=np.int64
        )
        self.reserved_replicas = np.maximum(
            reserved_replicas.astype(np.int64), self.min_replicas
        )
        # autoscaler state
        self.target_replicas = self.reserved_replicas.copy()
        self.below_ticks = np.zeros(n, dtype=np.int64)
        self.level = np.zeros(n, dtype=np.float64)
        self.trend = np.zeros(n, dtype=np.float64)
        self.seen = np.zeros(n, dtype=bool)
        # SLO window accounting
        self.prev_replicas = self.reserved_replicas.copy()
        self.ok_windows = np.zeros(n, dtype=np.int64)
        self.windows = np.zeros(n, dtype=np.int64)
        # open reclaim deficits (window start, NaN = none open)
        self.deficit_open = np.full(n, np.nan)

    def retarget(self, cfg: ServingConfig, qps_obs: np.ndarray) -> np.ndarray:
        """Advance forecaster state one tick and return replica targets."""
        y = qps_obs
        if cfg.autoscaler == "predictive":
            first = ~self.seen
            self.level[first] = y[first]
            self.trend[first] = 0.0
            self.seen[first] = True
            rest = ~first
            prev_level = self.level[rest]
            self.level[rest] = cfg.holt_alpha * y[rest] + (1.0 - cfg.holt_alpha) * (
                prev_level + self.trend[rest]
            )
            self.trend[rest] = (
                cfg.holt_beta * (self.level[rest] - prev_level)
                + (1.0 - cfg.holt_beta) * self.trend[rest]
            )
            forecast = self.level + cfg.prewarm_lead_ticks * self.trend
            target_qps = np.maximum(y, forecast)
        elif cfg.autoscaler == "reactive":
            target_qps = y
        else:
            raise ValueError(f"unknown autoscaler {cfg.autoscaler!r}")
        raw = np.ceil(
            target_qps / (self.qps_per_replica * cfg.target_utilization)
        ).astype(np.int64)
        raw = np.clip(raw, self.min_replicas, self.reserved_replicas)
        up = raw >= self.target_replicas
        self.target_replicas[up] = raw[up]
        self.below_ticks[up] = 0
        self.below_ticks[~up] += 1
        fire = ~up & (self.below_ticks >= cfg.scale_down_ticks)
        self.target_replicas[fire] = raw[fire]
        self.below_ticks[fire] = 0
        return self.target_replicas


class ServingTier:
    """Simulator-side driver: owns the trace, the ``ServiceTable``, the
    serving ``Job`` rows, and the SLO / reclaim / loan accounting.

    Replicas are independent, so each service schedules as up to
    ``shards_per_service`` replica-group shard jobs (replica targets
    round-robined across them): placement never needs one huge contiguous
    gang, and a spike's growth lands wherever borrowers freed capacity.

    Protocol (both event loops):

    * ``begin_tick(now)`` — once per scheduler tick, *before* ``decide``:
      advances traffic + autoscaler and returns per-*shard* target GPUs
      (``None`` if this wall time is still inside the previous tick).  The
      simulator writes the targets into the demand columns.
    * ``end_tick(now, alloc, downtime_until, best_effort_allocated)`` —
      after the decision is applied: scores the SLO window, closes/opens
      reclaim deficits, accrues loaned GPU time.
    """

    def __init__(
        self,
        cfg: ServingConfig,
        tick_seconds: float,
        horizon_seconds: float,
        costs: CostModel,
    ):
        self.cfg = cfg
        self.tick = float(tick_seconds)
        self.trace = TrafficTrace(cfg.services, cfg.traffic, horizon_seconds)
        rho = cfg.target_utilization
        qpr = np.array([s.profile.qps_per_replica for s in cfg.services])
        reserved = np.ceil(self.trace.peak() / (qpr * rho)).astype(np.int64)
        self.table = ServiceTable(cfg.services, reserved)
        t = self.table
        # shard layout: service i owns shards[i] consecutive shard jobs,
        # each at least one replica (so no shard's demand ever hits zero)
        self.shards = np.minimum(
            max(1, cfg.shards_per_service), t.reserved_replicas
        ).astype(np.int64)
        t.min_replicas = np.maximum(t.min_replicas, self.shards)
        t.target_replicas = t.reserved_replicas.copy()
        self.shard_service = np.repeat(np.arange(t.n), self.shards)
        self.n_shards = int(self.shards.sum())
        self.reserved_gpus = t.reserved_replicas * t.gpus_per_replica
        shard_reserved = self._distribute(t.reserved_replicas)
        gpr_shard = t.gpus_per_replica[self.shard_service]
        self.jobs: List[Job] = []
        for k in range(self.n_shards):
            i = int(self.shard_service[k])
            spec = cfg.services[i]
            self.jobs.append(
                Job(
                    id=f"svc/{spec.name}/{k - int(self.shards[:i].sum())}",
                    tier=cfg.tier,
                    demand_gpus=int(shard_reserved[k] * gpr_shard[k]),
                    gpu_hours=cfg.gpu_hours,
                    arrival=0.0,
                    min_gpus=int(gpr_shard[k]),
                    checkpoint_bytes=max(1, int(spec.profile.weight_bytes)),
                    service=True,
                )
            )
        self.costs = costs
        self.target_gpus = self.reserved_gpus.copy()  # per service
        self._last_target_gpus = self.reserved_gpus.copy()
        self._rose = np.zeros(t.n, dtype=bool)
        self._last_k = -1
        self.reclaim_latencies: List[float] = []
        self.loaned_gpu_seconds = 0.0
        self.serving_gpu_seconds = 0.0
        # observability (scheduler/telemetry.py): when the simulator runs
        # with telemetry, this is its EventLog and end_tick emits LOAN /
        # RECLAIM rows (job = service index).  last_loan_out feeds the
        # per-tick metrics series.
        self.telemetry = None
        self.last_loan_out = 0.0

    def _distribute(self, replicas: np.ndarray) -> np.ndarray:
        """Round-robin per-service replica counts over their shards."""
        out = np.empty(self.n_shards, dtype=np.int64)
        pos = 0
        for i in range(self.table.n):
            s = int(self.shards[i])
            base, rem = divmod(int(replicas[i]), s)
            for k in range(s):
                out[pos + k] = base + (1 if k < rem else 0)
            pos += s
        return out

    # -- deadline -------------------------------------------------------
    def reclaim_deadline(self) -> float:
        """Worst acceptable reclaim latency, charged from the CostModel:
        one scheduler tick to notice the spike, plus preempting a typical
        64-GPU borrower, plus re-warming the largest replica payload."""
        if self.cfg.reclaim_deadline_seconds is not None:
            return float(self.cfg.reclaim_deadline_seconds)
        borrower = self.costs.preempt_seconds(default_checkpoint_bytes(64))
        warm = max(
            self.costs.restore_seconds(j.checkpoint_bytes) for j in self.jobs
        )
        return self.tick + float(borrower) + float(warm)

    # -- per-tick protocol ----------------------------------------------
    def begin_tick(self, now: float) -> Optional[np.ndarray]:
        k = int(math.floor(now / self.tick + 1e-9))
        if k <= self._last_k:
            return None
        self._last_k = k
        t0 = k * self.tick
        t = self.table
        if self.cfg.loaning:
            targets = t.retarget(self.cfg, self.trace.at(t0))
        else:
            targets = t.reserved_replicas
        gpus = targets * t.gpus_per_replica
        self._rose = gpus > self._last_target_gpus
        self._last_target_gpus = gpus.copy()
        self.target_gpus = gpus
        shard_gpus = self._distribute(targets) * t.gpus_per_replica[
            self.shard_service
        ]
        return shard_gpus

    def end_tick(
        self,
        now: float,
        shard_alloc: np.ndarray,
        shard_downtime_until: np.ndarray,
        best_effort_allocated: float,
    ) -> None:
        t = self.table
        t0 = self._last_k * self.tick
        # aggregate shards to services: warm replicas are whole replicas
        # per shard (a partial shard grant serves nothing), residual
        # warm-up is the worst shard's
        gpr = t.gpus_per_replica[self.shard_service]
        replicas = np.bincount(
            self.shard_service, weights=shard_alloc // gpr, minlength=t.n
        ).astype(np.int64)
        alloc = np.bincount(
            self.shard_service, weights=shard_alloc, minlength=t.n
        ).astype(np.int64)
        warm = np.zeros(t.n)
        np.maximum.at(
            warm,
            self.shard_service,
            np.maximum(0.0, shard_downtime_until - now),
        )
        needed = np.ceil(
            self.trace.window_peak(t0, t0 + self.tick) / t.qps_per_replica
        ).astype(np.int64)
        grace = self.cfg.warm_grace_fraction * self.tick
        ok = (replicas >= needed) & ((t.prev_replicas >= needed) | (warm <= grace))
        t.ok_windows += ok
        t.windows += 1
        t.prev_replicas = replicas.copy()
        if self.cfg.loaning:
            ev = self.telemetry
            deficit = self.target_gpus > alloc
            had_open = ~np.isnan(t.deficit_open)
            t.deficit_open[deficit & ~had_open] = t0
            closed = ~deficit & had_open
            for i in np.nonzero(closed)[0]:
                latency = now - float(t.deficit_open[i]) + float(warm[i])
                self.reclaim_latencies.append(latency)
                if ev is not None:
                    ev.append(
                        now,
                        E_RECLAIM,
                        job=int(i),
                        cause=C_SPIKE,
                        gpus=int(alloc[i]),
                        seconds=latency,
                    )
            t.deficit_open[closed] = np.nan
            # a rise satisfied in the same tick: reclaim cost = residual warm
            instant = self._rose & ~deficit & ~had_open
            for i in np.nonzero(instant)[0]:
                latency = float(warm[i])
                self.reclaim_latencies.append(latency)
                if ev is not None:
                    ev.append(
                        now,
                        E_RECLAIM,
                        job=int(i),
                        cause=C_SPIKE,
                        gpus=int(alloc[i]),
                        seconds=latency,
                    )
            loan_out = float(np.maximum(0, self.reserved_gpus - alloc).sum())
            loaned = min(loan_out, best_effort_allocated)
            self.last_loan_out = loaned
            self.loaned_gpu_seconds += loaned * self.tick
            if ev is not None and loaned > 0:
                # one aggregate row per tick: reserved serving capacity
                # currently flowing to best-effort training
                ev.append(now, E_LOAN, gpus=int(loaned), seconds=self.tick)
        self.serving_gpu_seconds += float(alloc.sum()) * self.tick

    # -- results --------------------------------------------------------
    def attainment(self) -> float:
        """Cumulative fleet SLO attainment so far (cheap; the per-tick
        metrics series samples it every tick)."""
        windows = int(self.table.windows.sum())
        return (int(self.table.ok_windows.sum()) / windows) if windows else 1.0

    def summary(self) -> Dict[str, object]:
        t = self.table
        windows = int(t.windows.sum())
        ok = int(t.ok_windows.sum())
        lats = self.reclaim_latencies
        deadline = self.reclaim_deadline()
        return {
            "serving_windows": windows,
            "serving_violations": windows - ok,
            "serving_slo_attainment": (ok / windows) if windows else 1.0,
            "serving_attainment_by_service": {
                name: (
                    float(t.ok_windows[i] / t.windows[i]) if t.windows[i] else 1.0
                )
                for i, name in enumerate(t.names)
            },
            "serving_reclaims": len(lats),
            "serving_reclaim_mean_seconds": (
                float(np.mean(lats)) if lats else 0.0
            ),
            "serving_reclaim_max_seconds": float(np.max(lats)) if lats else 0.0,
            "serving_reclaim_deadline_seconds": deadline,
            "serving_reclaims_over_deadline": int(
                sum(1 for v in lats if v > deadline)
            ),
            "serving_loaned_gpu_hours": self.loaned_gpu_seconds / 3600.0,
            "serving_gpu_hours": self.serving_gpu_seconds / 3600.0,
            "serving_reserved_gpus": int(self.reserved_gpus.sum()),
        }

from repro.scheduler.types import Cluster, Fleet, Job, Region  # noqa: F401
from repro.scheduler.costs import CostModel, UniformCostModel  # noqa: F401
from repro.scheduler.simulator import FleetSimulator, SimConfig  # noqa: F401
from repro.scheduler.policy import ElasticPolicy, StaticGangPolicy  # noqa: F401
from repro.scheduler.executor import FleetExecutor, ManagedJob  # noqa: F401

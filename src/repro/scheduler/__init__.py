from repro.scheduler.costs import (
    CostModel,
    RegionLink,
    RegionTopology,
    UniformCostModel,
)
from repro.scheduler.executor import FleetExecutor, ManagedJob
from repro.scheduler.job_table import JobTable, JobView, TableJob
from repro.scheduler.policy import ElasticPolicy, StaticGangPolicy
from repro.scheduler.reliability import (
    CheckpointCadence,
    FailureEvent,
    FailureModel,
    FailureTrace,
)
from repro.scheduler.simulator import FleetSimulator, SimConfig
from repro.scheduler.types import Cluster, Fleet, Job, Region

__all__ = [
    "CostModel",
    "RegionLink",
    "RegionTopology",
    "UniformCostModel",
    "FleetExecutor",
    "ManagedJob",
    "JobTable",
    "JobView",
    "TableJob",
    "ElasticPolicy",
    "StaticGangPolicy",
    "CheckpointCadence",
    "FailureEvent",
    "FailureModel",
    "FailureTrace",
    "FleetSimulator",
    "SimConfig",
    "Cluster",
    "Fleet",
    "Job",
    "Region",
]

from repro.scheduler.costs import (
    CostModel,
    RegionLink,
    RegionTopology,
    UniformCostModel,
)
from repro.scheduler.job_table import JobTable, JobView, TableJob
from repro.scheduler.policy import ElasticPolicy, StaticGangPolicy
from repro.scheduler.reliability import (
    CheckpointCadence,
    FailureEvent,
    FailureModel,
    FailureTrace,
)
from repro.scheduler.serving import (
    ServiceSpec,
    ServingConfig,
    ServingTier,
    TrafficConfig,
    TrafficTrace,
)
from repro.scheduler.simulator import FleetSimulator, SimConfig
from repro.scheduler.types import Cluster, Fleet, Job, Region

# The executor drives real jax processes; load it lazily (PEP 562) so the
# pure-numpy scheduler/simulator/serving path imports without jax.
_LAZY = ("FleetExecutor", "ManagedJob")


def __getattr__(name):
    if name in _LAZY:
        from repro.scheduler import executor

        val = getattr(executor, name)
        globals()[name] = val
        return val
    raise AttributeError(f"module 'repro.scheduler' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))


__all__ = [
    "CostModel",
    "RegionLink",
    "RegionTopology",
    "UniformCostModel",
    "FleetExecutor",
    "ManagedJob",
    "JobTable",
    "JobView",
    "TableJob",
    "ElasticPolicy",
    "StaticGangPolicy",
    "CheckpointCadence",
    "FailureEvent",
    "FailureModel",
    "FailureTrace",
    "ServiceSpec",
    "ServingConfig",
    "ServingTier",
    "TrafficConfig",
    "TrafficTrace",
    "FleetSimulator",
    "SimConfig",
    "Cluster",
    "Fleet",
    "Job",
    "Region",
]

"""Reliability subsystem: failure-domain fault injection, checkpoint
cadence, and goodput accounting at fleet scale.

Singularity's reliability claim (§1, §6) is that because every job is
preemptible and resumable from a transparent checkpoint, an unplanned
hardware failure is just another preemption: the job loses only the work
since its last snapshot and restarts wherever capacity exists.  The
scheduler layers reproduce the *planned* mechanisms (preempt / migrate /
resize, charged by ``CostModel``); this module supplies the *unplanned*
half:

- ``FailureModel`` samples correlated failure events over the fleet's
  device -> node -> cluster -> region domain hierarchy.  Each level has
  its own per-unit MTBF and repair time; inter-arrival times are Weibull
  (shape 1.0 = exponential; shape < 1 models infant-mortality bursts)
  drawn from deterministic per-level Philox streams, so a seed fully
  determines the storm.
- ``FailureTrace`` is the replayable artifact: an ordered event list
  with JSON (de)serialization plus scenario constructors — single-device
  flakes, rack power loss, whole-cluster outage, region drain with
  advance warning — so benchmarks and tests can replay named storms.
- ``CheckpointCadence`` picks each job's snapshot interval from its
  checkpoint cost versus its domain failure rate (Young–Daly:
  ``tau = sqrt(2 * delta * MTTI)``), trading snapshot downtime against
  expected lost work.

``FleetSimulator`` consumes a trace (``SimConfig(failures=...)``): a
failure force-preempts every job intersecting the domain, rolls progress
back to the last snapshot (the lost work is accounted as
``lost_work_gpu_seconds``), marks the domain's capacity dead until a
sampled repair completes, and attributes the eventual restart downtime
by cause.  When the fleet carries a ``NodeMap`` the blast radius is
exact: a partial-domain event kills only the jobs whose assigned node
spans intersect the failed nodes (idle capacity absorbs the hit first),
instead of sampling victims proportionally from the cluster's residents.  ``ElasticPolicy`` avoids placing onto draining domains and
proactively migrates off them when the move costs less than the work it
saves.  ``SimResult`` reports ``goodput_fraction``, ``restarts_by_cause``
and per-tier ETTR so reliability wins are measurable.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.scheduler.costs import CostModel

FAILURE_LEVELS = ("device", "node", "cluster", "region")

# Event-kind vocabulary: "failure" is the generic unplanned event, the
# named scenarios refine it (ECC flake, rack power, cluster outage,
# planned drain).  telemetry.py folds these into its cause-code table so
# a FAILURE row in the event log says *what kind* of failure killed the
# job — keep this tuple the single source of that vocabulary.
FAILURE_KINDS = ("failure", "flake", "power", "outage", "drain")

# stable per-level stream offsets: adding a level or resampling one never
# perturbs the others' streams
_LEVEL_STREAM = {level: i for i, level in enumerate(FAILURE_LEVELS)}


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One failure-domain event.

    ``domain`` is a cluster id for device/node/cluster levels and a
    region id for region level.  ``gpus`` is the capacity taken out
    (0 = the whole domain).  ``warning_seconds > 0`` marks a *planned*
    drain: the scheduler sees the domain as draining from
    ``time - warning_seconds`` and can migrate work off proactively.
    """

    time: float
    level: str
    domain: str
    gpus: int
    repair_seconds: float
    warning_seconds: float = 0.0
    kind: str = "failure"

    def __post_init__(self):
        assert self.level in FAILURE_LEVELS, self.level
        assert self.kind in FAILURE_KINDS, self.kind

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "FailureEvent":
        return cls(**d)


class FailureTrace:
    """A replayable, time-ordered failure scenario.

    Traces are the unit of scenario diversity: sample one from a
    ``FailureModel``, build one from the named constructors below, merge
    several, save to JSON and replay byte-identically later.
    """

    def __init__(self, events: Iterable[FailureEvent] = ()):
        self.events: List[FailureEvent] = sorted(
            events, key=lambda e: (e.time, e.domain, e.level)
        )

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def __eq__(self, other) -> bool:
        return isinstance(other, FailureTrace) and self.events == other.events

    # ------------------------------------------------------- persistence
    def to_json(self) -> str:
        return json.dumps([e.to_dict() for e in self.events], indent=2)

    @classmethod
    def from_json(cls, text: str) -> "FailureTrace":
        return cls(FailureEvent.from_dict(d) for d in json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "FailureTrace":
        with open(path) as f:
            return cls.from_json(f.read())

    @classmethod
    def merge(cls, *traces: "FailureTrace") -> "FailureTrace":
        return cls(e for t in traces for e in t.events)

    # ------------------------------------------------- named scenarios
    @classmethod
    def device_flake(
        cls, cluster_id: str, at: float, repair_seconds: float = 1800.0
    ) -> "FailureTrace":
        """One GPU in ``cluster_id`` drops out (ECC flake, XID error)."""
        return cls(
            [FailureEvent(at, "device", cluster_id, 1, repair_seconds, kind="flake")]
        )

    @classmethod
    def rack_power_loss(
        cls,
        cluster_id: str,
        at: float,
        nodes: int = 4,
        gpus_per_node: int = 8,
        repair_seconds: float = 4 * 3600.0,
    ) -> "FailureTrace":
        """A rack PDU trips: ``nodes`` nodes in one cluster die at once."""
        return cls(
            [
                FailureEvent(
                    at,
                    "node",
                    cluster_id,
                    nodes * gpus_per_node,
                    repair_seconds,
                    kind="power",
                )
            ]
        )

    @classmethod
    def cluster_outage(
        cls, cluster_id: str, at: float, repair_seconds: float = 8 * 3600.0
    ) -> "FailureTrace":
        """The whole cluster goes dark (network partition, cooling)."""
        return cls(
            [FailureEvent(at, "cluster", cluster_id, 0, repair_seconds, kind="outage")]
        )

    @classmethod
    def region_drain(
        cls,
        region_id: str,
        at: float,
        repair_seconds: float = 12 * 3600.0,
        warning_seconds: float = 2 * 3600.0,
    ) -> "FailureTrace":
        """Planned maintenance: the region drains with advance warning —
        the scheduler can move work off before capacity actually dies."""
        return cls(
            [
                FailureEvent(
                    at,
                    "region",
                    region_id,
                    0,
                    repair_seconds,
                    warning_seconds=warning_seconds,
                    kind="drain",
                )
            ]
        )


@dataclasses.dataclass(frozen=True)
class FailureModel:
    """Correlated failure sampling over the fleet's domain hierarchy.

    Per-level MTBF is *per unit* (per GPU, per node, per cluster, per
    region): the aggregate arrival rate at a level scales with how many
    units the fleet has, which is what makes big fleets fail somewhere
    all the time even when each part is reliable.  ``weibull_shape``
    shapes inter-arrival times (1.0 = memoryless exponential; < 1 gives
    the bursty infant-mortality clustering real fleets show).  Repair
    times are exponential around each level's mean.  All streams are
    per-level Philox generators keyed off ``seed`` — the same seed and
    fleet always produce the same trace.
    """

    device_mtbf_seconds: float = 5.0 * 365 * 24 * 3600.0
    node_mtbf_seconds: float = 2.0 * 365 * 24 * 3600.0
    cluster_mtbf_seconds: float = 0.5 * 365 * 24 * 3600.0
    region_drain_interval_seconds: float = 0.0  # 0 = no scheduled drains
    weibull_shape: float = 1.0
    device_repair_seconds: float = 1800.0
    node_repair_seconds: float = 4 * 3600.0
    cluster_repair_seconds: float = 8 * 3600.0
    region_drain_seconds: float = 12 * 3600.0
    drain_warning_seconds: float = 2 * 3600.0
    seed: int = 0
    max_events: int = 100_000  # per level, so one hot level cannot starve the rest

    # ------------------------------------------------------------ rates
    def level_rate(self, level: str, units: int) -> float:
        """Aggregate events/second at a level with ``units`` units."""
        mtbf = {
            "device": self.device_mtbf_seconds,
            "node": self.node_mtbf_seconds,
            "cluster": self.cluster_mtbf_seconds,
            "region": self.region_drain_interval_seconds,
        }[level]
        if mtbf <= 0:
            return 0.0
        return units / mtbf

    def job_failure_rate(self, demand_gpus, gpus_per_node: int = 8):
        """Unplanned-failure rate (events/second) seen by a job spanning
        ``demand_gpus`` GPUs: its devices, the nodes they sit on, and the
        one cluster it runs in.  Planned region drains are excluded — the
        scheduler migrates off those, it does not lose work to them.
        Broadcasts over numpy arrays for the vectorized cadence path.
        """
        demand = np.asarray(demand_gpus, np.float64)
        nodes = np.ceil(demand / max(gpus_per_node, 1))
        rate = (
            demand / self.device_mtbf_seconds
            + nodes / self.node_mtbf_seconds
            + 1.0 / self.cluster_mtbf_seconds
        )
        return rate if rate.ndim else float(rate)

    # ---------------------------------------------------------- sampling
    def _stream(self, level: str) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=np.array([self.seed, _LEVEL_STREAM[level]], np.uint64))
        )

    def _interarrival(self, rng: np.random.Generator, rate: float) -> float:
        mean = 1.0 / rate
        if self.weibull_shape == 1.0:
            return float(rng.exponential(mean))
        scale = mean / math.gamma(1.0 + 1.0 / self.weibull_shape)
        return float(scale * rng.weibull(self.weibull_shape))

    def sample(self, fleet, horizon_seconds: float) -> FailureTrace:
        """Sample a full trace for ``fleet`` over ``horizon_seconds``.

        Device/node events land in a cluster chosen proportionally to its
        unit count; cluster outages and region drains pick a domain
        uniformly.  Deterministic in (seed, fleet shape, horizon).
        """
        clusters = fleet.clusters()
        if not clusters:
            return FailureTrace()
        sizes = np.array([c.total_gpus for c in clusters], np.float64)
        node_counts = np.array([c.nodes() for c in clusters], np.float64)
        events: List[FailureEvent] = []

        def weighted(rng, weights) -> int:
            return int(rng.choice(len(clusters), p=weights / weights.sum()))

        plans: List[Tuple[str, float, Sequence]] = [
            ("device", self.level_rate("device", int(sizes.sum())), sizes),
            ("node", self.level_rate("node", int(node_counts.sum())), node_counts),
            ("cluster", self.level_rate("cluster", len(clusters)), None),
            ("region", self.level_rate("region", len(fleet.regions)), None),
        ]
        for level, rate, weights in plans:
            if rate <= 0:
                continue
            rng = self._stream(level)
            t = 0.0
            n_level = 0
            while n_level < self.max_events:
                n_level += 1
                t += self._interarrival(rng, rate)
                if t > horizon_seconds:
                    break
                if level == "device":
                    k = weighted(rng, weights)
                    events.append(
                        FailureEvent(
                            t,
                            "device",
                            clusters[k].id,
                            1,
                            float(rng.exponential(self.device_repair_seconds)),
                            kind="flake",
                        )
                    )
                elif level == "node":
                    k = weighted(rng, weights)
                    events.append(
                        FailureEvent(
                            t,
                            "node",
                            clusters[k].id,
                            clusters[k].gpus_per_node,
                            float(rng.exponential(self.node_repair_seconds)),
                            kind="power",
                        )
                    )
                elif level == "cluster":
                    k = int(rng.integers(0, len(clusters)))
                    events.append(
                        FailureEvent(
                            t,
                            "cluster",
                            clusters[k].id,
                            0,
                            float(rng.exponential(self.cluster_repair_seconds)),
                            kind="outage",
                        )
                    )
                else:
                    k = int(rng.integers(0, len(fleet.regions)))
                    events.append(
                        FailureEvent(
                            t,
                            "region",
                            fleet.regions[k].id,
                            0,
                            self.region_drain_seconds,
                            warning_seconds=self.drain_warning_seconds,
                            kind="drain",
                        )
                    )
        return FailureTrace(events)


@dataclasses.dataclass(frozen=True)
class CheckpointCadence:
    """Per-job snapshot interval from checkpoint cost vs failure rate.

    Young–Daly: with snapshot overhead ``delta`` (seconds of downtime per
    snapshot, ``CostModel.snapshot_seconds``) and mean time to interrupt
    ``M = 1/lambda`` from the job's domain failure rate, the optimal
    cadence is ``tau = sqrt(2 * delta * M)``.  Cheap checkpoints and
    flaky domains mean frequent snapshots; huge checkpoints on reliable
    hardware mean rare ones.  ``mtti_seconds`` overrides the model-derived
    rate for controlled experiments.  Intervals clamp to
    ``[min_interval_seconds, max_interval_seconds]``.
    """

    cost_model: CostModel = dataclasses.field(default_factory=CostModel)
    failure_model: Optional[FailureModel] = None
    mtti_seconds: Optional[float] = None
    min_interval_seconds: float = 300.0
    max_interval_seconds: float = 24 * 3600.0

    def interval_seconds(self, checkpoint_bytes, demand_gpus, gpus_per_node: int = 8):
        """Snapshot interval(s); broadcasts over numpy arrays."""
        delta = np.asarray(
            self.cost_model.snapshot_seconds(np.asarray(checkpoint_bytes, np.float64)),
            np.float64,
        )
        if self.mtti_seconds is not None:
            mtti = np.asarray(self.mtti_seconds, np.float64)
        else:
            model = self.failure_model or FailureModel()
            rate = np.asarray(
                model.job_failure_rate(demand_gpus, gpus_per_node), np.float64
            )
            mtti = 1.0 / np.maximum(rate, 1e-12)
        tau = np.sqrt(2.0 * delta * mtti)
        tau = np.clip(tau, self.min_interval_seconds, self.max_interval_seconds)
        return tau if tau.ndim else float(tau)

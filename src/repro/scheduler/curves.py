"""Concave throughput-vs-GPUs scaling curves.

Real DNN training does not scale linearly: past a per-job saturation
point the marginal GPU buys less and less step-time (gradient
synchronization, pipeline bubbles, shrinking per-device batch).  The
seed model priced elasticity as *linear* efficiency up to ``2 x
demand_gpus`` (``Job.rate``), so the policy's expansion gate
systematically over-valued extra GPUs — the exact failure mode the
marginal-utility allocators of "Effective Elastic Scaling of Deep
Learning Workloads" (arXiv:2006.13878) and "An Optimal Resource
Allocator of Elastic Training" (arXiv:2109.03389) are built to avoid.

This module is the single home of the curve family both the policy's
water-filling passes and the simulator's progress accounting consume, so
charged speedup always equals allocated speedup:

* **Two-segment piecewise-linear efficiency.**  A job's efficiency at
  ``g`` GPUs (in demand-equivalents: ``eff(demand_gpus) == 1``) rises at
  slope ``1/demand`` up to the saturation knee ``knee_gpus``, then at
  ``sat_slope/demand`` (``0 <= sat_slope <= 1``) up to the ``2x`` cap.
  ``knee_gpus == 0`` is the *flat* (linear) sentinel — the seed model
  exactly, which keeps every pre-curve trace, decision digest and bench
  budget byte-identical.
* **Splice overhead stays multiplicative below demand** (Fig. 4 of the
  paper measures time-slicing, not scaling), matching the seed's
  ``rate``; the *pricing* helpers used by the policy's gates are
  overhead-free, like the seed's ``extra * interval`` gate was.
* **Derivation for real model configs**: ``fit_knee`` least-squares
  fits the family to (world size, throughput) samples, and
  ``curve_from_step_seconds`` feeds it from the per-world step-time
  estimates that ``analysis/roofline.py`` reports and
  ``launch/hillclimb.py`` searches over (throughput ~ 1/step_seconds at
  fixed global batch).  Synthetic traces draw a parametric
  (``knee``, ``sat_slope``) pair per job from a *separate* seeded
  stream (``simulator.synth_workload(curves=True)``) so the base trace
  stays byte-identical with curves off.
"""

from __future__ import annotations

from typing import Dict, Mapping, Sequence, Tuple

import numpy as np

# efficiency is capped at this multiple of demand, like the seed model
MAX_SCALE = 2.0


def scaling_eff(
    g: int,
    demand: int,
    knee: int = 0,
    sat_slope: float = 1.0,
) -> float:
    """Demand-equivalent efficiency of running at ``g`` GPUs, before
    splice overhead.  ``knee == 0`` (the flat sentinel) or ``g`` at or
    below the knee reproduce the seed's linear ``min(g/demand, 2)``."""
    gf = min(float(g), MAX_SCALE * demand)
    if knee <= 0 or gf <= knee:
        return min(gf / demand, MAX_SCALE)
    return min((knee + sat_slope * (gf - knee)) / demand, MAX_SCALE)


def scaling_eff_vec(
    g: np.ndarray,
    demand: np.ndarray,
    knee: np.ndarray,
    sat_slope: np.ndarray,
) -> np.ndarray:
    """Vectorized ``scaling_eff`` (float64, overhead-free)."""
    d = demand.astype(np.float64)
    gf = np.minimum(g.astype(np.float64), MAX_SCALE * d)
    lin = np.minimum(gf / d, MAX_SCALE)
    over = (knee > 0) & (gf > knee)
    if not over.any():
        return lin
    kf = knee.astype(np.float64)
    curved = np.minimum((kf + sat_slope * (gf - kf)) / d, MAX_SCALE)
    return np.where(over, curved, lin)


def validate_curve(demand: int, knee: int, sat_slope: float) -> None:
    """Raise ``ValueError`` unless the (knee, slope) pair is a member of
    the concave family: the knee sits at or above demand (below it the
    job could never reach its nominal rate and every SLA computation
    keyed on ``ideal_seconds`` would silently lie) and the post-knee
    slope does not exceed the pre-knee slope (concavity)."""
    if knee < 0:
        raise ValueError(f"knee_gpus must be >= 0 (0 = linear), got {knee}")
    if knee and knee < demand:
        raise ValueError(
            f"knee_gpus {knee} below demand_gpus {demand}: the job could "
            "never reach its nominal rate; knee must be >= demand"
        )
    if not 0.0 <= sat_slope <= 1.0:
        raise ValueError(
            f"sat_slope must be in [0, 1] (concavity), got {sat_slope}"
        )


def fit_knee(
    worlds: Sequence[int],
    throughputs: Sequence[float],
    demand: int,
) -> Tuple[int, float]:
    """Fit ``(knee_gpus, sat_slope)`` to measured/estimated throughput
    samples.

    ``throughputs`` are in any consistent unit (tokens/s, steps/s);
    they are normalized so the sample nearest ``demand`` has efficiency
    1.  Every sampled world at or above demand is tried as the knee; the
    post-knee slope is the least-squares slope of the samples beyond it
    (clamped into [0, 1]), and the pair with the lowest squared error
    over the whole curve wins.  Fewer than two distinct samples above
    demand degenerate to the flat sentinel ``(0, 1.0)``."""
    w = np.asarray(worlds, np.float64)
    t = np.asarray(throughputs, np.float64)
    if w.size != t.size or w.size == 0:
        raise ValueError("worlds and throughputs must align and be non-empty")
    order = np.argsort(w)
    w, t = w[order], t[order]
    ref = int(np.argmin(np.abs(w - demand)))
    if t[ref] <= 0:
        return 0, 1.0
    eff = t / t[ref] * (w[ref] / demand)  # efficiency in demand units
    above = w >= demand
    if np.count_nonzero(above) < 2:
        return 0, 1.0
    best: Tuple[float, int, float] = (np.inf, 0, 1.0)
    for k in w[above]:
        tail = w > k
        if tail.any():
            dw = w[tail] - k
            de = eff[tail] - k / demand
            slope = float(np.dot(dw, de) / np.dot(dw, dw)) * demand
            slope = min(1.0, max(0.0, slope))
        else:
            slope = 1.0
        knee = np.full_like(w, k)
        sat = np.full_like(w, slope)
        model = scaling_eff_vec(w, np.full_like(w, demand), knee, sat)
        err = float(np.sum((model - eff) ** 2))
        if err < best[0] - 1e-12:
            best = (err, int(round(k)), slope)
    _, knee_g, sat = best
    if knee_g >= MAX_SCALE * demand or (sat >= 1.0 - 1e-9 and knee_g <= demand):
        return 0, 1.0  # indistinguishable from linear: flat sentinel
    return max(int(demand), knee_g), sat


def curve_from_step_seconds(
    step_seconds_by_world: Mapping[int, float],
    demand: int,
) -> Tuple[int, float]:
    """Derive a job curve from per-world step-time estimates — the form
    ``analysis/roofline.py`` reports (``RooflineReport.step_seconds``
    per mesh) and ``launch/hillclimb.py``'s analytic search produces.
    At fixed global batch, throughput ~ 1/step_seconds."""
    worlds = sorted(step_seconds_by_world)
    thr = []
    for wsize in worlds:
        s = float(step_seconds_by_world[wsize])
        if s <= 0:
            raise ValueError(f"non-positive step_seconds at world {wsize}")
        thr.append(1.0 / s)
    return fit_knee(worlds, thr, demand)


def synth_curve_params(
    rng: np.random.Generator,
    demand: np.ndarray,
    knee_range: Tuple[float, float] = (1.0, 1.6),
    sat_range: Tuple[float, float] = (0.05, 0.5),
) -> Tuple[np.ndarray, np.ndarray]:
    """Parametric concave family for synthetic traces: per-job knee at
    ``uniform(knee_range) x demand`` (clamped into [demand, 2 demand])
    and post-knee slope ``uniform(sat_range)``.  The caller owns the
    generator so the draw order is isolated from the trace's own
    stream."""
    d = np.asarray(demand, np.int64)
    frac = rng.uniform(knee_range[0], knee_range[1], d.size)
    knee = np.clip(np.rint(d * frac), d, MAX_SCALE * d).astype(np.int64)
    sat = rng.uniform(sat_range[0], sat_range[1], d.size)
    return knee, sat


def curves_for_reports(reports, demand: int) -> Dict[str, Tuple[int, float]]:
    """(knee, sat_slope) per model arch from ``RooflineReport`` rows —
    group by ``arch`` and fit over each group's (chips, 1/step_seconds)
    samples.  Accepts the dataclasses from ``analysis/roofline.py``
    without importing them (duck-typed: ``arch``/``chips``/
    ``step_seconds``), so this stays importable without jax."""
    by_arch: Dict[str, Dict[int, float]] = {}
    for r in reports:
        by_arch.setdefault(r.arch, {})[int(r.chips)] = float(r.step_seconds)
    return {
        arch: curve_from_step_seconds(samples, demand)
        for arch, samples in by_arch.items()
    }

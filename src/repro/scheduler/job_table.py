"""JobTable: a simulator-owned struct-of-arrays for per-job state.

``FleetSLAAccounts`` removed the per-job SLA queries from the decide
path; the remaining floor was the per-job *attribute gather* — at every
tick ``ElasticPolicy._decide_vectorized`` rebuilt an ``(n, 8)`` base
array by touching eight attributes of every active ``Job`` object (~60%
of decide time at 1M jobs), and the simulator re-materialized its own
``_arrival``/``_demand``/``_ideal`` arrays from the same objects.

``JobTable`` removes that floor the same way the SLA ledger did: every
numeric per-job field lives in a shared numpy column (one row per slot,
grown by doubling, freed rows reused), and the ``Job`` object becomes a
thin per-slot view — ``JobTable.adopt`` copies a plain ``Job``'s state
into a fresh row and flips the instance's class to ``TableJob``, whose
property accessors read and write the columns in place.  The decide path
then takes column *slices* (``table.demand_gpus[slots]``) with zero
per-job Python work, the simulator's event loop reads/writes the same
columns the policy and ``_apply`` see (no resync loops), and completed
jobs ``detach``: their final state is copied back onto the instance, the
class flips back to ``Job``, and the row returns to the free list.

Column fields (all shared with the policy's vectorized decide path):
``demand_gpus``, ``min_gpus``, ``allocated``, ``arrival``,
``checkpoint_bytes``, ``restore_debt``, ``tier_code``, ``queued_since``,
``ever_ran``, ``progress``, ``snap_progress``, ``snap_time``,
``done_at`` (NaN = not done), ``downtime_until``, ``downtime_seconds``,
``gpu_hours``, ``splice_overhead``, ``knee_gpus``/``sat_slope`` (the
concave scaling curve, ``scheduler/curves.py``), ``ideal`` and
``cluster_idx`` (an index into the owning fleet's cluster order, -1 =
unplaced).  Identity
(``id``, ``tier``), the SLA account object and the rare event counters
stay on the instance.

When the table carries an SLA ledger (``sla=``), ``adopt`` swaps a
job's ``FleetSlotAccount`` view for a ``_TableSlotAccount`` that mirrors
its lazily-registered ledger slot into the ``sla_slot`` column on every
``ensure_slot`` — so the policy reads the whole fleet's headroom with
one ``headroom_all(now, table.sla_slot[slots], ...)`` call and no
account-object gather.  Jobs with scalar or foreign-ledger accounts are
flagged ``sla_view=False`` and fall back per job, exactly like the
mixed-ledger fallback in ``policy._shared_ledger``.

``JobView`` is the zero-gather handle the simulator passes to
``ElasticPolicy.decide``: a sequence of the adopted ``Job`` objects plus
the array of their slots, so the policy never walks the objects at all.
Hand-built scalar ``Job`` lists keep the per-job build path, and
mixed/foreign-table lists are detected and fall back, mirroring
``_shared_ledger``.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.sla import TIERS, FleetSlotAccount
from repro.scheduler.types import Job

# tier name <-> small-int code, shared by the table, the simulator and
# the policy's lookup tables (all enumerate TIERS in dict order)
TIER_CODE = {name: i for i, name in enumerate(TIERS)}
TIER_NAMES = list(TIERS)

# (column name, dtype, fill value for freed rows).  ``arrival`` resets to
# +inf and ``done_at`` to NaN so a stale freed row can never look active.
_COLUMNS = (
    ("demand_gpus", np.int64, 0),
    ("min_gpus", np.int64, 0),
    ("allocated", np.int64, 0),
    ("arrival", np.float64, np.inf),
    ("checkpoint_bytes", np.int64, 0),
    ("restore_debt", np.float64, 0.0),
    ("tier_code", np.int64, 0),
    ("queued_since", np.float64, 0.0),
    ("ever_ran", np.bool_, False),
    ("service", np.bool_, False),
    ("progress", np.float64, 0.0),
    ("snap_progress", np.float64, 0.0),
    ("snap_time", np.float64, 0.0),
    ("done_at", np.float64, np.nan),
    ("downtime_until", np.float64, 0.0),
    ("downtime_seconds", np.float64, 0.0),
    ("gpu_hours", np.float64, 0.0),
    ("splice_overhead", np.float64, 0.0),
    ("knee_gpus", np.int64, 0),
    ("sat_slope", np.float64, 1.0),
    ("ideal", np.float64, 0.0),
    ("cluster_idx", np.int64, -1),
    ("sla_slot", np.int64, -1),
    ("sla_view", np.bool_, False),
)

# Job fields whose storage moves into the table on adopt (and back out on
# detach).  ``cluster`` maps through the table's cluster registry;
# ``done_at`` maps None <-> NaN.
_SCALAR_FIELDS = (
    "demand_gpus",
    "min_gpus",
    "allocated",
    "arrival",
    "checkpoint_bytes",
    "restore_debt",
    "queued_since",
    "ever_ran",
    "service",
    "progress",
    "snap_progress",
    "snap_time",
    "downtime_until",
    "downtime_seconds",
    "gpu_hours",
    "splice_overhead",
    "knee_gpus",
    "sat_slope",
)


class JobTable:
    """Struct-of-arrays job state owned by a simulator/executor fleet.

    Mirrors the ``FleetSLAAccounts`` design: slots registered on adopt,
    released (and the row reused) on detach, columns grown by doubling.
    ``objs``/``ids`` keep the adopted ``Job`` objects and their string
    ids per slot so the policy can emit ``Decision`` entries without
    walking the objects.
    """

    def __init__(
        self,
        clusters: Optional[Sequence[str]] = None,
        sla=None,
        capacity: int = 64,
    ):
        self._cap = max(1, int(capacity))
        self._n = 0  # high-water slot mark
        self._free: List[int] = []
        for name, dtype, fill in _COLUMNS:
            setattr(self, name, np.full(self._cap, fill, dtype=dtype))
        self.ids = np.full(self._cap, None, dtype=object)
        self.objs = np.full(self._cap, None, dtype=object)
        # cluster registry: id <-> small-int code.  Built from the owning
        # fleet's cluster order so ``cluster_idx`` doubles as an index
        # into ``fleet.clusters()``; unknown ids register lazily past it.
        self._cluster_ids: List[str] = []
        self._cluster_code = {}
        for cid in clusters or ():
            self.cluster_code(cid)
        self.sla = sla  # FleetSLAAccounts the adopted accounts live in
        # set by a driver that binds the column arrays into its event
        # loop (the vectorized simulator): growth would silently replace
        # the bound arrays, so it is forbidden while pinned
        self.pinned = False

    # ------------------------------------------------------------- slots
    @property
    def slots_in_use(self) -> int:
        return self._n - len(self._free)

    @property
    def capacity(self) -> int:
        return self._cap

    def cluster_code(self, cluster_id: Optional[str]) -> int:
        if cluster_id is None:
            return -1
        code = self._cluster_code.get(cluster_id)
        if code is None:
            code = len(self._cluster_ids)
            self._cluster_ids.append(cluster_id)
            self._cluster_code[cluster_id] = code
        return code

    def cluster_id(self, code: int) -> Optional[str]:
        return self._cluster_ids[code] if code >= 0 else None

    def matches_clusters(self, cluster_ids: Sequence[str]) -> bool:
        """True when this table's registry starts with ``cluster_ids`` in
        order — i.e. ``cluster_idx`` values below ``len(cluster_ids)``
        index that cluster list directly (the policy's placement fast
        path requires it)."""
        k = len(cluster_ids)
        ids = self._cluster_ids
        return len(ids) >= k and ids[:k] == list(cluster_ids)

    def _grow(self) -> None:
        assert not self.pinned, (
            "JobTable growth while its columns are bound into an event "
            "loop would decouple the bound views from the live arrays; "
            "size the table for the trace up front"
        )
        cap = self._cap * 2
        for name, dtype, fill in _COLUMNS:
            old = getattr(self, name)
            out = np.full(cap, fill, dtype=dtype)
            out[: self._cap] = old
            setattr(self, name, out)
        for name in ("ids", "objs"):
            old = getattr(self, name)
            out = np.full(cap, None, dtype=object)
            out[: self._cap] = old
            setattr(self, name, out)
        self._cap = cap

    def _register(self) -> int:
        if self._free:
            return self._free.pop()
        if self._n == self._cap:
            self._grow()
        slot = self._n
        self._n += 1
        return slot

    def _reset_slot(self, slot: int) -> None:
        for name, _, fill in _COLUMNS:
            getattr(self, name)[slot] = fill
        self.ids[slot] = None
        self.objs[slot] = None

    # ----------------------------------------------------- adopt / detach
    def adopt(self, job: Job) -> int:
        """Move ``job``'s numeric state into a table row and flip the
        instance to a ``TableJob`` view on it.  Returns the slot."""
        assert type(job) is Job, f"cannot adopt {type(job).__name__}"
        slot = self._register()
        for f in _SCALAR_FIELDS:
            getattr(self, f)[slot] = getattr(job, f)
        self.tier_code[slot] = TIER_CODE[job.tier]
        self.done_at[slot] = np.nan if job.done_at is None else job.done_at
        self.cluster_idx[slot] = self.cluster_code(job.cluster)
        self.ideal[slot] = job.gpu_hours * 3600.0 / job.demand_gpus
        self.ids[slot] = job.id
        self.objs[slot] = job
        acc = job.account
        if (
            self.sla is not None
            and isinstance(acc, FleetSlotAccount)
            and acc.ledger is self.sla
        ):
            job.account = _TableSlotAccount(acc, self, slot)
            self.sla_slot[slot] = acc.slot
            self.sla_view[slot] = True
        # drop the instance storage the properties now shadow, then flip
        d = job.__dict__
        for f in _SCALAR_FIELDS + ("done_at", "cluster"):
            d.pop(f, None)
        d["_table"] = self
        d["_slot"] = slot
        job.__class__ = TableJob
        return slot

    def detach(self, job: "TableJob") -> None:
        """Copy the row's final state back onto the instance, flip it
        back to a plain ``Job`` and free the slot for reuse."""
        assert isinstance(job, TableJob) and job._table is self
        slot = job._slot
        values = {f: getattr(job, f) for f in _SCALAR_FIELDS}
        values["done_at"] = job.done_at
        values["cluster"] = job.cluster
        acc = job.account
        if isinstance(acc, _TableSlotAccount):
            plain = FleetSlotAccount.__new__(FleetSlotAccount)
            plain.ledger = acc.ledger
            plain.tier = acc.tier
            plain.demand = acc.demand
            plain.slot = acc.slot
            values["account"] = plain
        d = job.__dict__
        d.pop("_table", None)
        d.pop("_slot", None)
        job.__class__ = Job
        d.update(values)
        self._reset_slot(slot)
        self._free.append(slot)

    def adopt_batch(self, jobs: Sequence[Job]) -> np.ndarray:
        """``adopt`` for a whole trace at once (the simulator's
        construction path): per-field column fills instead of per-job
        scalar writes.  Every job must be a plain ``Job`` (the caller
        checks); returns the slot array, in job order."""
        m = len(jobs)
        slots = np.fromiter((self._register() for _ in range(m)), np.int64, m)
        for f in _SCALAR_FIELDS:
            getattr(self, f)[slots] = [getattr(j, f) for j in jobs]
        self.tier_code[slots] = [TIER_CODE[j.tier] for j in jobs]
        self.done_at[slots] = [np.nan if j.done_at is None else j.done_at for j in jobs]
        self.cluster_idx[slots] = [self.cluster_code(j.cluster) for j in jobs]
        self.ideal[slots] = self.gpu_hours[slots] * 3600.0 / self.demand_gpus[slots]
        self.ids[slots] = [j.id for j in jobs]
        self.objs[slots] = list(jobs)
        sla = self.sla
        slot_list = slots.tolist()
        sview: List[bool] = []
        sslot: List[int] = []
        for k, j in enumerate(jobs):
            d = j.__dict__
            d["_table"] = self
            d["_slot"] = slot_list[k]
            j.__class__ = TableJob
            acc = d["account"]
            if (
                sla is not None
                and isinstance(acc, FleetSlotAccount)
                and acc.ledger is sla
            ):
                d["account"] = _TableSlotAccount(acc, self, slot_list[k])
                sview.append(True)
                sslot.append(acc.slot)
            else:
                sview.append(False)
                sslot.append(-1)
        self.sla_view[slots] = sview
        self.sla_slot[slots] = sslot
        return slots

    def detach_batch(self, slots: np.ndarray) -> None:
        """Detach every job at ``slots`` at once: column values are
        gathered vectorized and pushed back onto the instances with one
        dict update each, rows are reset with masked writes (the
        simulator detaches completions in batches of one tick's
        finishers)."""
        slots = np.asarray(slots, np.int64)
        if slots.size == 0:
            return
        rows = list(zip(*(getattr(self, f)[slots].tolist() for f in _SCALAR_FIELDS)))
        done_l = [None if np.isnan(v) else float(v) for v in self.done_at[slots]]
        clus = [self.cluster_id(c) for c in self.cluster_idx[slots].tolist()]
        objs = self.objs[slots]
        for k in range(slots.size):
            job = objs[k]
            acc = job.account
            d = job.__dict__
            d.pop("_table", None)
            d.pop("_slot", None)
            job.__class__ = Job
            d.update(zip(_SCALAR_FIELDS, rows[k]))
            d["done_at"] = done_l[k]
            d["cluster"] = clus[k]
            if isinstance(acc, _TableSlotAccount):
                plain = FleetSlotAccount.__new__(FleetSlotAccount)
                plain.ledger = acc.ledger
                plain.tier = acc.tier
                plain.demand = acc.demand
                plain.slot = acc.slot
                d["account"] = plain
        for name, _, fill in _COLUMNS:
            getattr(self, name)[slots] = fill
        self.ids[slots] = None
        self.objs[slots] = None
        self._free.extend(slots.tolist())

    def view(self, slots: np.ndarray) -> "JobView":
        return JobView(self, slots)


class JobView:
    """A set of table-backed jobs addressed by slot array.

    The simulator hands this to ``ElasticPolicy.decide`` so the
    vectorized path can slice the table's columns directly; iterating or
    indexing yields the adopted ``Job`` objects for the scalar
    fallbacks (reference oracle, rare placement escapes).
    """

    __slots__ = ("table", "slots")

    def __init__(self, table: JobTable, slots: np.ndarray):
        self.table = table
        self.slots = np.asarray(slots, np.int64)

    def __len__(self) -> int:
        return int(self.slots.size)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self.table.objs[s] for s in self.slots[i]]
        return self.table.objs[self.slots[i]]

    def __iter__(self):
        objs = self.table.objs
        for s in self.slots:
            yield objs[s]


def shared_table(jobs):
    """``(table, slots)`` when every job is a live view on ONE
    ``JobTable``; ``(None, None)`` otherwise — mixed plain/table or
    foreign-table job lists fall back to the per-job build path, the
    same contract as ``policy._shared_ledger``."""
    if isinstance(jobs, JobView):
        return jobs.table, jobs.slots
    table = None
    slots = np.empty(len(jobs), np.int64)
    for k, j in enumerate(jobs):
        if type(j) is not TableJob:
            return None, None
        if table is None:
            table = j._table
        elif j._table is not table:
            return None, None
        slots[k] = j._slot
    return table, slots


class _TableSlotAccount(FleetSlotAccount):
    """A ``FleetSlotAccount`` that mirrors its ledger slot into the
    owning ``JobTable``'s ``sla_slot`` column whenever it registers —
    every record path funnels through ``ensure_slot``, so the column can
    never go stale and the policy may trust it without re-reading the
    account objects."""

    __slots__ = ("table", "row")

    def __init__(self, acc: FleetSlotAccount, table: JobTable, row: int):
        self.ledger = acc.ledger
        self.tier = acc.tier
        self.demand = acc.demand
        self.slot = acc.slot
        self.table = table
        self.row = row

    def ensure_slot(self) -> int:
        slot = super().ensure_slot()
        self.table.sla_slot[self.row] = slot
        return slot


def _int_col(name):
    def fget(self):
        return int(getattr(self._table, name)[self._slot])

    def fset(self, v):
        getattr(self._table, name)[self._slot] = v

    return property(fget, fset)


def _float_col(name):
    def fget(self):
        return float(getattr(self._table, name)[self._slot])

    def fset(self, v):
        getattr(self._table, name)[self._slot] = v

    return property(fget, fset)


def _bool_col(name):
    def fget(self):
        return bool(getattr(self._table, name)[self._slot])

    def fset(self, v):
        getattr(self._table, name)[self._slot] = v

    return property(fget, fset)


class TableJob(Job):
    """A ``Job`` whose numeric state lives in a ``JobTable`` row.

    Instances are never constructed: ``JobTable.adopt`` flips a plain
    ``Job``'s class to this one (and ``detach`` flips it back), the same
    way ``Job.account`` becomes a ``FleetSlotAccount`` view.  Property
    accessors return plain Python scalars so reprs, digests and
    comparisons match a scalar ``Job`` exactly."""

    demand_gpus = _int_col("demand_gpus")
    min_gpus = _int_col("min_gpus")
    allocated = _int_col("allocated")
    checkpoint_bytes = _int_col("checkpoint_bytes")
    knee_gpus = _int_col("knee_gpus")
    sat_slope = _float_col("sat_slope")
    arrival = _float_col("arrival")
    restore_debt = _float_col("restore_debt")
    queued_since = _float_col("queued_since")
    progress = _float_col("progress")
    snap_progress = _float_col("snap_progress")
    snap_time = _float_col("snap_time")
    downtime_until = _float_col("downtime_until")
    downtime_seconds = _float_col("downtime_seconds")
    gpu_hours = _float_col("gpu_hours")
    splice_overhead = _float_col("splice_overhead")
    ever_ran = _bool_col("ever_ran")
    service = _bool_col("service")

    @property
    def done_at(self) -> Optional[float]:
        v = self._table.done_at[self._slot]
        return None if np.isnan(v) else float(v)

    @done_at.setter
    def done_at(self, v: Optional[float]) -> None:
        self._table.done_at[self._slot] = np.nan if v is None else v

    @property
    def cluster(self) -> Optional[str]:
        return self._table.cluster_id(int(self._table.cluster_idx[self._slot]))

    @cluster.setter
    def cluster(self, v: Optional[str]) -> None:
        self._table.cluster_idx[self._slot] = self._table.cluster_code(v)

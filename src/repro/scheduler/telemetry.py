"""Fleet observability: structured event log, per-tick metrics series,
nested-span profiler, Perfetto trace export, and event-log replay.

Singularity is operated as a service: the paper's evaluation (§5,
Tables 3-5) attributes every second of dead GPU time to a concrete
preempt / migrate / resize / failure cause.  The simulator computes that
attribution internally but, before this module, threw it away and kept
only end-of-run aggregates in ``SimResult``.  This module makes the
attribution first-class:

- ``EventLog`` — a columnar struct-of-arrays log (the JobTable /
  FleetSLAAccounts recipe: doubling numpy columns, batched appends from
  the vectorized paths) of every lifecycle transition: admit, preempt,
  restore, migrate (incl. drain evacuation), resize, failure kill,
  snapshot, defrag move, loan, reclaim, complete.  Each row carries the
  sim time, the job's stable trace index, the fleet cluster index, SLA
  tier, a cause code, GPUs involved, and the CostModel-charged downtime
  seconds (lost work gpu-seconds for failure kills, reclaim latency for
  reclaims).  JSONL-exportable and reloadable.
- ``MetricsSeries`` — one row per scheduler tick (utilization, queue
  depth by tier, stranded GPUs, goodput, SLO attainment, loaned GPUs,
  decide-latency breakdown) in doubling float columns, CSV/JSON dump.
- ``Profiler`` — nested named spans replacing the ad-hoc
  ``decide_seconds`` / ``gather_seconds`` / ``node_seconds`` fields in
  ``policy.py``.  Per-name totals are always accumulated (two
  ``perf_counter`` calls per span, the same cost as the old fields);
  span *records* for trace export are only kept when the profiler is
  enabled, so telemetry-off runs stay near-zero-cost.
- ``export_chrome_trace`` — Chrome/Perfetto trace-event JSON: job
  lifecycle spans on per-cluster tracks (pid = cluster, tid = job slot)
  plus decide-pass phase spans on a scheduler track, wired up as
  ``benchmarks/sched_scale.py --trace-out``.
- ``replay_events`` / ``check_replay`` — the differential check: a pure
  function folds an exported event log back into the run's ``SimResult``
  aggregates (mechanism counts, downtime by tier, restarts by cause,
  lost work) and asserts equality, catching silent accounting drift
  between ``_apply`` and ``SimResult``.

The log is strictly *read-only* with respect to scheduling: every gate
in CI pins that decision digests are byte-identical with telemetry on.
"""

from __future__ import annotations

import json
import time
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.sla import TIERS
from repro.scheduler.reliability import FAILURE_KINDS

TIER_NAMES = list(TIERS)

# ------------------------------------------------------------------ taxonomy
# Event kinds: one code per lifecycle transition.  Drain evacuation is a
# MIGRATE with cause "drain"; a failure kill is FAILURE with the
# FailureEvent kind (flake / power / outage / ...) as its cause.
EVENT_KINDS = (
    "admit",
    "preempt",
    "restore",
    "migrate",
    "resize",
    "failure",
    "snapshot",
    "defrag",
    "loan",
    "reclaim",
    "complete",
)
KIND_CODE = {name: i for i, name in enumerate(EVENT_KINDS)}

E_ADMIT = KIND_CODE["admit"]
E_PREEMPT = KIND_CODE["preempt"]
E_RESTORE = KIND_CODE["restore"]
E_MIGRATE = KIND_CODE["migrate"]
E_RESIZE = KIND_CODE["resize"]
E_FAILURE = KIND_CODE["failure"]
E_SNAPSHOT = KIND_CODE["snapshot"]
E_DEFRAG = KIND_CODE["defrag"]
E_LOAN = KIND_CODE["loan"]
E_RECLAIM = KIND_CODE["reclaim"]
E_COMPLETE = KIND_CODE["complete"]

# Cause vocabulary: scheduler-side causes first, then the reliability
# failure kinds (single source: reliability.FAILURE_KINDS), then serving,
# then curve pricing ("slope": a resize granted by the water-filling
# expansion pass on a curved job — appended last so existing codes in
# exported traces stay stable).
EVENT_CAUSES = ("", "policy", "preempt") + FAILURE_KINDS + ("spike", "slope")
CAUSE_CODE = {name: i for i, name in enumerate(EVENT_CAUSES)}

C_NONE = CAUSE_CODE[""]
C_POLICY = CAUSE_CODE["policy"]
C_PREEMPT = CAUSE_CODE["preempt"]
C_FAILURE = CAUSE_CODE["failure"]
C_DRAIN = CAUSE_CODE["drain"]
C_SPIKE = CAUSE_CODE["spike"]
C_SLOPE = CAUSE_CODE["slope"]

# flags bits
F_CROSS_REGION = 1

# Kinds whose ``seconds`` column is CostModel-charged downtime — exactly
# the ``_charge`` call sites in the simulator.  FAILURE rows carry lost
# work (gpu-seconds) instead; RECLAIM rows carry reclaim latency.
CHARGE_KINDS = frozenset(
    (E_RESTORE, E_MIGRATE, E_RESIZE, E_SNAPSHOT, E_DEFRAG)
)


class EventLog:
    """Columnar append-only log of fleet lifecycle events.

    Columns are flat numpy arrays that double on demand (no per-event
    Python object allocation); the vectorized simulator paths append
    whole batches at once.  ``job`` is the job's stable trace index
    (slot == trace index while a simulation runs; service index for
    loan/reclaim rows; -1 when not applicable).
    """

    _COLUMNS = (
        ("time", np.float64, 0.0),
        ("kind", np.int16, 0),
        ("job", np.int64, -1),
        ("cluster", np.int32, -1),
        ("tier", np.int8, -1),
        ("cause", np.int16, 0),
        ("gpus", np.int64, 0),
        ("seconds", np.float64, 0.0),
        ("flags", np.int8, 0),
    )

    def __init__(self, capacity: int = 1024):
        self._cap = max(int(capacity), 1)
        self.n = 0
        for name, dtype, fill in self._COLUMNS:
            setattr(self, "_" + name, np.full(self._cap, fill, dtype))

    def __len__(self) -> int:
        return self.n

    def column(self, name: str) -> np.ndarray:
        """The live prefix of a column (a view, not a copy)."""
        return getattr(self, "_" + name)[: self.n]

    def _grow(self, need: int) -> None:
        cap = self._cap
        while cap < need:
            cap *= 2
        for name, dtype, fill in self._COLUMNS:
            old = getattr(self, "_" + name)
            new = np.full(cap, fill, dtype)
            new[: self.n] = old[: self.n]
            setattr(self, "_" + name, new)
        self._cap = cap

    # ------------------------------------------------------------- appends
    def append(
        self,
        time: float,
        kind: int,
        job: int = -1,
        cluster: int = -1,
        tier: int = -1,
        cause: int = 0,
        gpus: int = 0,
        seconds: float = 0.0,
        flags: int = 0,
    ) -> None:
        i = self.n
        if i >= self._cap:
            self._grow(i + 1)
        self._time[i] = time
        self._kind[i] = kind
        self._job[i] = job
        self._cluster[i] = cluster
        self._tier[i] = tier
        self._cause[i] = cause
        self._gpus[i] = gpus
        self._seconds[i] = seconds
        self._flags[i] = flags
        self.n = i + 1

    def append_batch(
        self,
        time,
        kind,
        job,
        cluster=-1,
        tier=-1,
        cause=0,
        gpus=0,
        seconds=0.0,
        flags=0,
    ) -> None:
        """Append ``len(job)`` rows at once; scalars broadcast.

        Semantically identical to calling :meth:`append` per row in
        order — pinned by the batched-vs-scalar oracle test.
        """
        job = np.asarray(job)
        m = int(job.size)
        if m == 0:
            return
        i = self.n
        if i + m > self._cap:
            self._grow(i + m)
        sl = slice(i, i + m)
        self._time[sl] = time
        self._kind[sl] = kind
        self._job[sl] = job
        self._cluster[sl] = cluster
        self._tier[sl] = tier
        self._cause[sl] = cause
        self._gpus[sl] = gpus
        self._seconds[sl] = seconds
        self._flags[sl] = flags
        self.n = i + m

    # -------------------------------------------------------------- export
    def rows(self) -> Iterable[Dict]:
        """Decoded event dicts, in append order."""
        for i in range(self.n):
            yield {
                "t": float(self._time[i]),
                "kind": EVENT_KINDS[self._kind[i]],
                "job": int(self._job[i]),
                "cluster": int(self._cluster[i]),
                "tier": TIER_NAMES[self._tier[i]] if self._tier[i] >= 0 else "",
                "cause": EVENT_CAUSES[self._cause[i]],
                "gpus": int(self._gpus[i]),
                "seconds": float(self._seconds[i]),
                "cross": bool(self._flags[i] & F_CROSS_REGION),
            }

    def to_jsonl(self, path: str, meta: Optional[Dict] = None) -> None:
        """One meta header line, then one JSON object per event.

        ``json`` round-trips float64 exactly (shortest repr), so a log
        reloaded with :func:`read_jsonl` replays bit-identically.
        """
        with open(path, "w") as f:
            header = {"meta": dict(meta or {})}
            header["meta"].setdefault("version", 1)
            header["meta"].setdefault("events", self.n)
            f.write(json.dumps(header, sort_keys=True) + "\n")
            for row in self.rows():
                f.write(json.dumps(row, sort_keys=True) + "\n")


def read_jsonl(path: str) -> Tuple["EventLog", Dict]:
    """Reload a :meth:`EventLog.to_jsonl` export; returns (log, meta)."""
    log = EventLog()
    meta: Dict = {}
    tier_code = {name: i for i, name in enumerate(TIER_NAMES)}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            if "meta" in d:
                meta = d["meta"]
                continue
            log.append(
                time=d["t"],
                kind=KIND_CODE[d["kind"]],
                job=d["job"],
                cluster=d["cluster"],
                tier=tier_code.get(d["tier"], -1),
                cause=CAUSE_CODE[d["cause"]],
                gpus=d["gpus"],
                seconds=d["seconds"],
                flags=F_CROSS_REGION if d.get("cross") else 0,
            )
    return log, meta


# ----------------------------------------------------------------- metrics
class MetricsSeries:
    """Per-tick fleet metrics in doubling float64 ring columns.

    One ``record`` call per scheduler tick; every field defaults to 0.0
    when not supplied, so callers only fill what they measured.
    """

    FIELDS = (
        "time",
        "allocated_gpus",
        "utilization",
        "queue_premium",
        "queue_standard",
        "queue_basic",
        "stranded_gpus",
        "loaned_gpus",
        "goodput",
        "slo_attainment",
        "decide_seconds",
        "place_seconds",
        "apply_seconds",
    )

    def __init__(self, fields: Tuple[str, ...] = FIELDS, capacity: int = 256):
        self.fields = tuple(fields)
        self._cap = max(int(capacity), 1)
        self.n = 0
        self._cols = {f: np.zeros(self._cap, np.float64) for f in self.fields}

    def __len__(self) -> int:
        return self.n

    def column(self, name: str) -> np.ndarray:
        return self._cols[name][: self.n]

    def record(self, **values: float) -> None:
        i = self.n
        if i >= self._cap:
            cap = self._cap * 2
            for f, col in self._cols.items():
                new = np.zeros(cap, np.float64)
                new[:i] = col[:i]
                self._cols[f] = new
            self._cap = cap
        for f in self.fields:
            self._cols[f][i] = values.get(f, 0.0)
        self.n = i + 1

    def to_csv(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(",".join(self.fields) + "\n")
            for i in range(self.n):
                f.write(
                    ",".join(repr(float(self._cols[c][i])) for c in self.fields)
                    + "\n"
                )

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(
                {c: self.column(c).tolist() for c in self.fields},
                f,
                sort_keys=True,
            )


# ---------------------------------------------------------------- profiler
class _Span:
    """One live nested span; re-entered via ``with prof.span(name)``."""

    __slots__ = ("prof", "name", "t0", "depth")

    def __init__(self, prof: "Profiler", name: str):
        self.prof = prof
        self.name = name

    def __enter__(self) -> "_Span":
        p = self.prof
        self.depth = p._depth
        p._depth = self.depth + 1
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter()
        p = self.prof
        p._depth = self.depth
        p.totals[self.name] = p.totals.get(self.name, 0.0) + (t1 - self.t0)
        p.counts[self.name] = p.counts.get(self.name, 0) + 1
        if p.enabled:
            p.spans.append(
                (self.name, self.depth, p._anchor, p._anchor_wall, self.t0, t1)
            )


class Profiler:
    """Nested named wall-clock spans.

    Totals (``total(name)``) accumulate whether or not the profiler is
    enabled — they back ``ElasticPolicy.gather_seconds`` /
    ``node_seconds`` at the exact cost of the old ad-hoc
    ``perf_counter`` pairs.  Span *records* (for Perfetto export) are
    only kept when ``enabled``; a disabled profiler records nothing.

    ``set_anchor(sim_time)`` pins the current simulated time so wall
    durations can be projected onto the simulation timeline at export.
    """

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self.totals: Dict[str, float] = {}
        self.counts: Dict[str, int] = {}
        # (name, depth, anchor_sim, anchor_wall, t0, t1)
        self.spans: List[Tuple[str, int, float, float, float, float]] = []
        self._depth = 0
        self._anchor = 0.0
        self._anchor_wall = 0.0

    def span(self, name: str) -> _Span:
        return _Span(self, name)

    def total(self, name: str) -> float:
        return self.totals.get(name, 0.0)

    def set_anchor(self, sim_time: float) -> None:
        self._anchor = float(sim_time)
        self._anchor_wall = time.perf_counter()

    def reset(self) -> None:
        self.totals.clear()
        self.counts.clear()
        self.spans.clear()
        self._depth = 0


class FleetTelemetry:
    """The bundle a simulator (or executor) run emits into.

    ``events`` is the structured lifecycle log, ``metrics`` the per-tick
    series, ``prof`` the (enabled) decide-pass profiler.  ``meta``
    collects run facts (reliability on/off, cluster names, ...) that the
    JSONL export and the replay check consume.
    """

    def __init__(
        self,
        events: Optional[EventLog] = None,
        metrics: Optional[MetricsSeries] = None,
        profiler: Optional[Profiler] = None,
    ):
        self.events = events if events is not None else EventLog()
        self.metrics = metrics if metrics is not None else MetricsSeries()
        self.prof = profiler if profiler is not None else Profiler(enabled=True)
        self.meta: Dict = {}


# ------------------------------------------------------------------ replay
def replay_events(log: EventLog) -> Dict:
    """Fold an event log back into ``SimResult``-shaped aggregates.

    Pure function of the log.  Float sums follow the simulator's exact
    accumulation order — sequential in event order for lost work,
    per-job-then-per-tier for downtime — so equality against the live
    ``SimResult`` is exact, not approximate.
    """
    kind = log.column("kind")
    secs = log.column("seconds")
    jobs = log.column("job")
    tiers = log.column("tier")
    cause = log.column("cause")
    flags = log.column("flags")
    cross = (flags & F_CROSS_REGION) != 0

    def count(k: int) -> int:
        return int((kind == k).sum())

    # lost work accumulates one failure kill at a time in the simulator
    lost = 0.0
    for v in secs[kind == E_FAILURE]:
        lost += float(v)

    # downtime: the simulator sums charges per job chronologically
    # (j.downtime_seconds), then folds jobs into tiers in trace order
    per_job: Dict[int, float] = {}
    job_tier: Dict[int, int] = {}
    charge = np.isin(kind, list(CHARGE_KINDS))
    for j, t, v in zip(jobs[charge], tiers[charge], secs[charge]):
        j = int(j)
        per_job[j] = per_job.get(j, 0.0) + float(v)
        job_tier[j] = int(t)
    downtime_by_tier = {t: 0.0 for t in TIER_NAMES}
    for j in sorted(per_job):
        downtime_by_tier[TIER_NAMES[job_tier[j]]] += per_job[j]
    downtime_by_tier = {t: v for t, v in downtime_by_tier.items() if v > 0}

    restore = kind == E_RESTORE
    restarts_by_cause: Dict[str, int] = {}
    for c in cause[restore]:
        name = EVENT_CAUSES[c]
        restarts_by_cause[name] = restarts_by_cause.get(name, 0) + 1

    return {
        "preemptions": count(E_PREEMPT),
        "restores": count(E_RESTORE),
        "restores_cross_region": int(cross[restore].sum()),
        "migrations": count(E_MIGRATE) + count(E_DEFRAG),
        "migrations_cross_region": int(cross[kind == E_MIGRATE].sum()),
        "resizes": count(E_RESIZE),
        "defrag_migrations": count(E_DEFRAG),
        "snapshots": count(E_SNAPSHOT),
        "job_failures": count(E_FAILURE),
        "lost_work_gpu_seconds": lost,
        "downtime_by_tier": downtime_by_tier,
        "restarts_by_cause": restarts_by_cause,
        "completed": count(E_COMPLETE),
    }


def check_replay(log: EventLog, result, reliability: bool = True) -> List[str]:
    """Compare :func:`replay_events` against a live ``SimResult``.

    Returns a list of human-readable mismatches (empty = exact match).
    ``restarts_by_cause`` is only attributed by the simulator when the
    reliability subsystem is active, so it is only compared then.
    """
    rep = replay_events(log)
    mismatches = []

    def eq(key, got, want):
        if got != want:
            mismatches.append(f"{key}: replay={got!r} result={want!r}")

    eq("preemptions", rep["preemptions"], result.preemptions)
    eq("restores", rep["restores"], result.restores)
    eq(
        "restores_cross_region",
        rep["restores_cross_region"],
        result.restores_cross_region,
    )
    eq("migrations", rep["migrations"], result.migrations)
    eq(
        "migrations_cross_region",
        rep["migrations_cross_region"],
        result.migrations_cross_region,
    )
    eq("resizes", rep["resizes"], result.resizes)
    eq("defrag_migrations", rep["defrag_migrations"], result.defrag_migrations)
    eq("snapshots", rep["snapshots"], result.snapshots)
    eq("job_failures", rep["job_failures"], result.job_failures)
    eq(
        "lost_work_gpu_seconds",
        rep["lost_work_gpu_seconds"],
        result.lost_work_gpu_seconds,
    )
    eq("downtime_by_tier", rep["downtime_by_tier"], result.downtime_by_tier)
    eq("completed", rep["completed"], result.completed)
    if reliability:
        eq(
            "restarts_by_cause",
            rep["restarts_by_cause"],
            result.restarts_by_cause,
        )
    return mismatches


# ----------------------------------------------------------------- perfetto
def export_chrome_trace(
    path: str,
    events: Optional[EventLog] = None,
    profiler: Optional[Profiler] = None,
    cluster_names: Optional[List[str]] = None,
    job_ids: Optional[List[str]] = None,
    end_time: Optional[float] = None,
) -> int:
    """Write a Chrome/Perfetto trace-event JSON file.

    Job lifecycle spans land on per-cluster tracks: pid = cluster index
    + 1 (pid 0 is the scheduler), tid = the job's trace index.  A span
    opens at admit/restore, closes at preempt / failure / completion,
    and a migration (or defrag move) closes the span on the old cluster
    and opens one on the new — so a job's residency history reads
    directly off the timeline.  Timestamps are simulated seconds in
    microseconds.

    Decide-pass profiler spans render on the scheduler track (pid 0):
    each span is anchored at the simulated time of its tick and offset
    by its wall-clock time within the tick, so a ~10 ms decide shows as
    a 10 "µs-per-wall-ms" sliver you zoom into at each tick boundary.
    Nesting is by timestamp containment (Perfetto's rule for same-tid
    ``X`` events).

    Returns the number of trace events written.
    """
    trace: List[Dict] = []

    def pname(pid: int, name: str) -> None:
        trace.append(
            {
                "ph": "M",
                "name": "process_name",
                "pid": pid,
                "args": {"name": name},
            }
        )

    pname(0, "scheduler")
    for k, cname in enumerate(cluster_names or []):
        pname(k + 1, f"cluster {cname}")

    def job_label(slot: int) -> str:
        if job_ids is not None and 0 <= slot < len(job_ids):
            return job_ids[slot]
        return f"job{slot}"

    n_events = 0
    if events is not None:
        kinds = events.column("kind")
        times = events.column("time")
        jobs = events.column("job")
        clusters = events.column("cluster")
        gpus = events.column("gpus")
        last_t = float(times[-1]) if events.n else 0.0
        horizon = last_t if end_time is None else float(end_time)
        open_spans: Dict[int, Tuple[float, int, int]] = {}

        def close(slot: int, t: float, why: str) -> None:
            t0, cl, g = open_spans.pop(slot)
            trace.append(
                {
                    "name": job_label(slot),
                    "cat": "job",
                    "ph": "X",
                    "ts": t0 * 1e6,
                    "dur": max(t - t0, 0.0) * 1e6,
                    "pid": int(cl) + 1,
                    "tid": int(slot),
                    "args": {"gpus": int(g), "end": why},
                }
            )

        for i in range(events.n):
            k = int(kinds[i])
            slot = int(jobs[i])
            t = float(times[i])
            if k in (E_ADMIT, E_RESTORE):
                if slot in open_spans:  # defensive: restore over a live span
                    close(slot, t, "restore")
                open_spans[slot] = (t, int(clusters[i]), int(gpus[i]))
            elif k in (E_MIGRATE, E_DEFRAG):
                if slot in open_spans:
                    close(slot, t, EVENT_KINDS[k])
                open_spans[slot] = (t, int(clusters[i]), int(gpus[i]))
            elif k in (E_PREEMPT, E_FAILURE, E_COMPLETE):
                if slot in open_spans:
                    close(slot, t, EVENT_KINDS[k])
        for slot in sorted(open_spans):
            close(slot, horizon, "end-of-run")
        n_events = events.n

    if profiler is not None:
        for name, depth, anchor, anchor_wall, t0, t1 in profiler.spans:
            ts = anchor + (t0 - anchor_wall)
            trace.append(
                {
                    "name": name,
                    "cat": "decide",
                    "ph": "X",
                    "ts": ts * 1e6,
                    "dur": (t1 - t0) * 1e6,
                    "pid": 0,
                    "tid": 0,
                    "args": {"depth": int(depth)},
                }
            )

    with open(path, "w") as f:
        json.dump({"traceEvents": trace}, f)
    return len(trace)

"""Discrete-event fleet simulator (hierarchical scheduler harness).

Mirrors Figure 1's scopes: the GLOBAL scheduler owns the fleet model and
invokes the policy; REGIONAL state is the per-cluster capacity bookkeeping;
the WORKLOAD scope is each job's elastic controller (its SLA account +
resize/preempt reactions), embodied in Job/GpuFractionAccount.

Two faithfulness properties the seed simulator lacked:

1. **Costs are charged.**  Every preemption, migration, resize and restore
   consumes downtime derived from the ``CostModel`` (checkpoint bytes /
   blob bandwidth / barrier latency — the Table 4/5 machinery).  Downtime
   is dead GPU time: the allocation is held but makes no progress, so
   utilization and JCT honestly reflect the paper's "cheap but not free"
   claim.  ``SimResult`` reports realized per-tier downtime.

2. **One decision, one event.**  ``_apply`` classifies each job transition
   into exactly one of {preempt, restore, migrate, resize} and asserts
   per-cluster capacity conservation after every decision.

3. **Unplanned failures are just preemptions** (§1, §6).  With
   ``SimConfig(failures=...)`` a ``FailureTrace`` (or a sampled
   ``FailureModel``) kills domain capacity until repair and
   force-preempts every job intersecting the failed span, rolling its
   progress back to the last durable snapshot — graceful checkpoints
   from preempt/migrate events, plus periodic Young–Daly snapshots when
   a ``CheckpointCadence`` is configured.  ``SimResult`` reports
   ``goodput_fraction``, ``lost_work_gpu_seconds``, ``restarts_by_cause``
   and per-tier ETTR.  Both event loops share the reliability machinery;
   the failure-free vectorized hot path is untouched.

The default event loop is vectorized: job progress is advanced with
numpy over an arrival-sorted active window, and SLA delivery is recorded
into the fleet-wide ``FleetSLAAccounts`` ledger in two batched calls per
tick (the simulator swaps each job's scalar account for a ledger-backed
view at construction; ``SimConfig(sla_ledger=False)`` keeps per-job
scalar accounts for benchmarking the difference).  Per-job *state* lives
in a fleet ``JobTable`` the same way: the trace is adopted into shared
numpy columns at construction (slot == job index), each ``Job`` becomes
a thin ``TableJob`` view, and the loop advances the very columns the
policy slices and ``_apply`` writes — no re-materialized arrays, no
post-decide resync loops, completions detach in batches and free their
rows.  ``SimConfig(job_table=False)`` keeps plain scalar jobs; the two
configurations are property-tested indistinguishable
(``tests/test_job_table.py``).  50k–100k-job traces run in seconds.
``SimConfig(vectorized=False)`` keeps the seed's O(jobs) per-event
Python loop for apples-to-apples throughput comparisons
(``benchmarks/sched_scale.py``).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.sla import TIERS, FleetSLAAccounts, FleetSlotAccount, GpuFractionAccount
from repro.scheduler.costs import CostModel, RegionTopology, defrag_worthwhile
from repro.scheduler.curves import synth_curve_params
from repro.scheduler.job_table import TIER_CODE, JobTable, JobView, TableJob
from repro.scheduler.node_map import NodeMap, floor_gang
from repro.scheduler.policy import Decision
from repro.scheduler.reliability import CheckpointCadence, FailureModel, FailureTrace
from repro.scheduler.serving import ServingConfig, ServingTier
from repro.scheduler.telemetry import (
    C_DRAIN,
    C_FAILURE,
    C_NONE,
    C_POLICY,
    C_PREEMPT,
    C_SLOPE,
    CAUSE_CODE,
    E_ADMIT,
    E_COMPLETE,
    E_DEFRAG,
    E_FAILURE,
    E_MIGRATE,
    E_PREEMPT,
    E_RESIZE,
    E_RESTORE,
    E_SNAPSHOT,
    F_CROSS_REGION,
    FleetTelemetry,
)
from repro.scheduler.types import Cluster, Fleet, Job, Region

# tier gpu_fraction lookup by JobTable tier code (same enumeration order)
_TIER_GFRAC = np.array([TIERS[t].gpu_fraction for t in TIERS], np.float64)


@dataclasses.dataclass
class SimConfig:
    tick_seconds: float = 300.0
    horizon_seconds: float = 48 * 3600.0
    # Table 5: tens of seconds per mechanism invocation.  The scalars are
    # uniform per-event charges; ``cost_model`` (when set) derives per-job
    # costs from checkpoint size / bandwidth / barrier latency instead.
    migration_cost_seconds: float = 60.0
    preemption_cost_seconds: Optional[float] = None  # default: migration/2
    restore_cost_seconds: Optional[float] = None  # default: migration/2
    resize_cost_seconds: Optional[float] = None  # default: migration/6
    cost_model: Optional[CostModel] = None
    vectorized: bool = True  # False = seed-style O(jobs)-per-event loop
    validate: bool = True  # capacity-conservation asserts per decision
    # False = keep per-job scalar GpuFractionAccounts (the PR 2 baseline)
    # instead of the batched FleetSLAAccounts ledger
    sla_ledger: bool = True
    # False = keep plain scalar Job objects (the PR 3/4 baseline): the
    # policy's decide path gathers per-job attributes in Python instead
    # of slicing the fleet JobTable's columns
    job_table: bool = True
    # reliability: a replayable FailureTrace (or a FailureModel, sampled
    # over this fleet/horizon at construction) injects unplanned failures;
    # a CheckpointCadence adds periodic snapshots so a failure loses only
    # the work since the last one (None = checkpoint-on-preempt-only)
    failures: Optional[Union[FailureTrace, FailureModel]] = None
    cadence: Optional[CheckpointCadence] = None
    # node-granular placement: the simulator owns a fleet NodeMap (per-node
    # free counts + per-job node spans), the policy plans gang-compatible
    # spans against it, failures pick victims from the real assignments and
    # a defragmentation pass consolidates stranded fragments.  False keeps
    # the pre-NodeMap cluster-granular behaviour.
    node_placement: bool = True
    # elastic inference serving tier (scheduler/serving.py): services become
    # guaranteed jobs whose demand an autoscaler retargets every tick from a
    # seeded traffic trace, loaning idle reserved capacity to best-effort
    # training between spikes.  None = no serving tier.
    serving: Optional[ServingConfig] = None
    # observability (scheduler/telemetry.py): True builds a FleetTelemetry
    # (structured event log + per-tick metrics + enabled profiler), or pass
    # an existing FleetTelemetry to emit into.  Strictly read-only w.r.t.
    # scheduling — decision digests are pinned identical either way.
    telemetry: Union[bool, "FleetTelemetry", None] = None

    def costs(self) -> CostModel:
        if self.cost_model is not None:
            return self.cost_model
        return CostModel.uniform(
            self.migration_cost_seconds,
            preemption_cost_seconds=self.preemption_cost_seconds,
            restore_cost_seconds=self.restore_cost_seconds,
            resize_cost_seconds=self.resize_cost_seconds,
        )


@dataclasses.dataclass
class SimResult:
    utilization: float
    sla_attainment: Dict[str, float]
    mean_jct: Dict[str, float]
    completed: int
    total_jobs: int
    preemptions: int
    migrations: int
    resizes: int
    queue_seconds: float  # total job-seconds spent fully queued
    gpu_seconds_idle: float
    restores: int = 0
    gpu_seconds_dead: float = 0.0  # allocated but making no progress
    downtime_by_tier: Dict[str, float] = dataclasses.field(default_factory=dict)
    migrations_cross_region: int = 0  # subset of migrations that moved region
    restores_cross_region: int = 0  # subset of restores that moved region
    # reliability accounting (all zero / empty without injected failures)
    failure_events: int = 0  # domain failures applied (per affected cluster)
    job_failures: int = 0  # jobs killed by a failure (forced preemptions)
    snapshots: int = 0  # cadence-driven periodic snapshots taken
    lost_work_gpu_seconds: float = 0.0  # progress destroyed by failures
    # of all GPU-seconds consumed (productive + charged-dead), the
    # fraction that produced *retained* progress: failures claw back the
    # work since the last snapshot, snapshot/restore overheads are dead
    goodput_fraction: float = 1.0
    # per-tier realized goodput: mean over a tier's arrived jobs of
    # RETAINED progress (failures claw back unsnapshotted work) relative
    # to a dedicated machine's pace — the reliability analogue of the
    # GPU-fraction SLA, ordered premium >= standard >= basic by admission
    # preference even under failure storms
    goodput_by_tier: Dict[str, float] = dataclasses.field(default_factory=dict)
    restarts_by_cause: Dict[str, int] = dataclasses.field(default_factory=dict)
    # mean seconds from a job's failure to its restart (per tier)
    ettr_by_tier: Dict[str, float] = dataclasses.field(default_factory=dict)
    # fragmentation accounting (zero without node placement): time-averaged
    # free GPUs sitting in holes too small for any queued gang's smallest
    # admissible single-node piece, and the consolidation moves made
    fragmentation_stranded_gpus: float = 0.0
    defrag_migrations: int = 0  # subset of ``migrations``
    # serving-tier accounting (all zero / empty without SimConfig.serving):
    # SLO windows are (service, tick) pairs; a window is met when enough
    # WARM replicas covered the window's peak qps.  Reclaim latency is the
    # time from a loan-reclaiming retarget to warm restored capacity,
    # measured against the CostModel-charged deadline.
    serving_windows: int = 0
    serving_violations: int = 0
    serving_slo_attainment: float = 1.0
    serving_attainment_by_service: Dict[str, float] = dataclasses.field(
        default_factory=dict
    )
    serving_reclaims: int = 0
    serving_reclaim_mean_seconds: float = 0.0
    serving_reclaim_max_seconds: float = 0.0
    serving_reclaim_deadline_seconds: float = 0.0
    serving_reclaims_over_deadline: int = 0
    serving_loaned_gpu_hours: float = 0.0
    serving_gpu_hours: float = 0.0
    serving_reserved_gpus: int = 0

    def summary(self) -> str:
        """One-screen human-readable run report.

        Multi-line: a fleet header, a per-tier table (SLA, goodput,
        mean JCT, charged downtime), the mechanism counters, and — only
        when present — failure, serving and fragmentation lines.  Used
        for ``sched_scale.py`` / ``sched_sim.py`` stdout.
        """
        lines = [
            f"fleet      util {self.utilization:.3f}"
            f" | goodput {self.goodput_fraction:.3f}"
            f" | completed {self.completed}/{self.total_jobs}"
            f" | queued {self.queue_seconds / 3600:.0f} job-h",
            "tier         sla    goodput  mean-jct  downtime",
        ]
        for t in self.sla_attainment:
            jct = self.mean_jct.get(t, float("nan"))
            lines.append(
                f"  {t:<9} {self.sla_attainment[t]:>6.3f}"
                f"  {self.goodput_by_tier.get(t, 1.0):>6.3f}"
                f"  {jct / 3600:>7.1f}h"
                f"  {self.downtime_by_tier.get(t, 0.0) / 3600:>7.1f}h"
            )
        lines.append(
            f"mechanisms preempt {self.preemptions}"
            f" | migrate {self.migrations}"
            f" (cross {self.migrations_cross_region},"
            f" defrag {self.defrag_migrations})"
            f" | resize {self.resizes}"
            f" | restore {self.restores}"
            f" | snapshots {self.snapshots}"
        )
        if self.failure_events or self.job_failures:
            restarts = ", ".join(
                f"{c} {n}" for c, n in sorted(self.restarts_by_cause.items())
            )
            ettr = ", ".join(
                f"{t} {v:.0f}s" for t, v in self.ettr_by_tier.items()
            )
            lines.append(
                f"failures   events {self.failure_events}"
                f" | jobs killed {self.job_failures}"
                f" | lost {self.lost_work_gpu_seconds / 3600:.0f} gpu-h"
                + (f" | restarts[{restarts}]" if restarts else "")
                + (f" | ettr[{ettr}]" if ettr else "")
            )
        if self.serving_windows:
            lines.append(
                f"serving    slo {self.serving_slo_attainment:.4f}"
                f" ({self.serving_violations}/{self.serving_windows}"
                " windows missed)"
                f" | reclaims {self.serving_reclaims}"
                f" (max {self.serving_reclaim_max_seconds:.0f}s"
                f" <= {self.serving_reclaim_deadline_seconds:.0f}s)"
                f" | loaned {self.serving_loaned_gpu_hours:.0f} gpu-h"
                f" | reserved {self.serving_reserved_gpus} GPUs"
            )
        if self.fragmentation_stranded_gpus or self.defrag_migrations:
            lines.append(
                "fragmentation stranded"
                f" {self.fragmentation_stranded_gpus:.1f} GPUs (time-avg)"
                f" | defrag moves {self.defrag_migrations}"
            )
        return "\n".join(lines)


def make_fleet(
    n_regions: int = 2,
    clusters_per_region: int = 2,
    gpus_per_cluster: int = 512,
    with_topology: bool = True,
    gpus_per_node: int = 8,
) -> Fleet:
    """Build a synthetic fleet; by default it carries a realistic tiered
    ``RegionTopology`` (intra-region blob bandwidth, a fast tier between
    ring-adjacent regions, a slow tier for far pairs) so migrations are
    priced by region pair.  ``with_topology=False`` keeps the seed's
    region-blind pricing for controlled experiments.  Clusters carry node
    granularity (``gpus_per_node``) so device/node/cluster/region failure
    domains are real."""
    regions = []
    for r in range(n_regions):
        clusters = [
            Cluster(
                f"r{r}c{c}", f"r{r}", gpus_per_cluster, gpus_per_node=gpus_per_node
            )
            for c in range(clusters_per_region)
        ]
        regions.append(Region(f"r{r}", clusters))
    topology = None
    if with_topology:
        topology = RegionTopology.tiered([r.id for r in regions])
    return Fleet(regions, topology=topology)


def synth_workload(
    n_jobs: int,
    fleet_gpus: int,
    seed: int = 0,
    mean_interarrival: float = 600.0,
    work_scale: float = 1.0,
    curves: bool = False,
) -> List[Job]:
    """Synthetic trace: mixed tiers/sizes, load ~ fleet capacity.

    ``work_scale`` shortens/lengthens jobs without changing the arrival
    process or size mix (used by the scale benchmark to hold fleet load
    near saturation for dense traces).

    ``curves=True`` additionally draws a concave scaling curve per job
    (``curves.synth_curve_params``: a saturation knee in [demand, 2
    demand] and a shallow post-knee slope) from a SEPARATE seeded
    stream, so the base trace — arrivals, sizes, tiers, splice floors —
    stays byte-identical to ``curves=False``.
    """
    rng = np.random.Generator(np.random.Philox(seed))
    jobs = []
    t = 0.0
    tiers = ["premium", "standard", "basic"]
    tier_p = [0.2, 0.4, 0.4]
    for i in range(n_jobs):
        t += float(rng.exponential(mean_interarrival))
        demand = int(2 ** rng.integers(3, 9))  # 8..256 GPUs
        hours = float(rng.uniform(0.5, 8.0)) * demand / 64 * work_scale
        tier = str(rng.choice(tiers, p=tier_p))
        max_splice = int(2 ** rng.integers(0, 3))  # 1,2,4 (ZeRO floor)
        jobs.append(
            Job(
                id=f"j{i}",
                tier=tier,
                demand_gpus=demand,
                gpu_hours=hours * demand,
                arrival=t,
                min_gpus=max(1, demand // max_splice),
            )
        )
    if curves and jobs:
        crng = np.random.Generator(np.random.Philox(seed ^ 0xC0FFEE))
        demands = np.fromiter((j.demand_gpus for j in jobs), np.int64, len(jobs))
        knee, sat = synth_curve_params(crng, demands)
        for j, k, s in zip(jobs, knee, sat):
            j.knee_gpus = int(k)
            j.sat_slope = float(s)
    return jobs


def _release_account(j: Job) -> None:
    """Free a completed job's ledger slot (views only; scalar accounts
    have nothing to release)."""
    if isinstance(j.account, FleetSlotAccount):
        j.account.release()


class FleetSimulator:
    def __init__(
        self,
        fleet: Fleet,
        jobs: List[Job],
        policy,
        cfg: Optional[SimConfig] = None,
    ):
        self.fleet = fleet
        self.policy = policy
        self.cfg = cfg or SimConfig()
        self.costs = self.cfg.costs()
        # region-aware pricing: a fleet that declares a topology has its
        # migrations charged by (source, destination) region pair
        if fleet.topology is not None and self.costs.topology is None:
            self.costs = dataclasses.replace(self.costs, topology=fleet.topology)
        # elastic serving tier: each service becomes a guaranteed Job
        # PREPENDED to the trace (the slot == index invariants below then
        # hold for them too) whose demand column the autoscaler retargets
        # in _serving_begin before every decide
        self.serving: Optional[ServingTier] = None
        self._svc_open = False
        jobs = list(jobs)
        if self.cfg.serving is not None:
            self.serving = ServingTier(
                self.cfg.serving,
                self.cfg.tick_seconds,
                self.cfg.horizon_seconds,
                self.costs,
            )
            jobs = self.serving.jobs + jobs
            self._svc_idx = np.arange(len(self.serving.jobs))
            self._basic_mask = np.fromiter(
                (j.tier == "basic" for j in jobs), bool, len(jobs)
            )
        self._jobs_list = jobs
        self.jobs = {j.id: j for j in jobs}
        # thread the charged cost model into the policy (unless the caller
        # configured one explicitly): the scheduler should weigh the same
        # downtime the simulator charges
        if hasattr(policy, "bind_costs"):
            policy.bind_costs(self.costs, self.cfg.tick_seconds)
        # observability: build (or adopt) the telemetry bundle.  The event
        # log and metrics are emitted from the apply / reliability /
        # serving paths below; the policy's decide-pass profiler is
        # swapped for the bundle's enabled one so its spans land in the
        # exported trace.  All of it is read-only w.r.t. decisions.
        tele = self.cfg.telemetry
        if tele is True:
            tele = FleetTelemetry()
        self.tele: Optional[FleetTelemetry] = tele if tele else None
        self._ev = self.tele.events if self.tele is not None else None
        if self.tele is not None:
            if hasattr(policy, "bind_telemetry"):
                policy.bind_telemetry(self.tele)
            if self.serving is not None:
                self.serving.telemetry = self.tele.events
        self._m_prev = {"decide": 0.0, "place": 0.0, "apply": 0.0}
        self._stranded_prev = 0.0
        # fleet-wide SLA ledger: swap each job's pristine scalar account
        # for a ledger-backed view so SLA recording and the policy's
        # headroom consultation run as batched array passes.  Jobs handed
        # in with recorded history or warm caches keep their scalar
        # account (the policy falls back per job for those).
        if self.cfg.sla_ledger:
            if fleet.sla is None:
                fleet.sla = FleetSLAAccounts()
            for j in self._jobs_list:
                acc = j.account
                if (
                    isinstance(acc, GpuFractionAccount)
                    and not acc.intervals
                    and not acc._wcache
                ):
                    j.account = FleetSlotAccount(fleet.sla, j.tier, j.demand_gpus)
        self._ledger = fleet.sla if self.cfg.sla_ledger else None
        # job-state SoA: adopt the trace into a fresh fleet JobTable so
        # the decide path reads column slices (zero per-job gathering),
        # the event loop advances the same columns _apply writes (no
        # resync loops) and completed jobs release their rows.  Slots are
        # registered in job order into a fresh table, so slot == index in
        # self._jobs_list — the vectorized loop indexes columns directly.
        # A trace containing jobs already adopted elsewhere (foreign
        # TableJobs) keeps the object path end to end.
        self._table: Optional[JobTable] = None
        if self.cfg.job_table and all(type(j) is Job for j in self._jobs_list):
            table = JobTable(
                clusters=[c.id for c in fleet.clusters()],
                sla=self._ledger,
                capacity=max(1, len(self._jobs_list)),
            )
            table.adopt_batch(self._jobs_list)
            self._table = table
            # the fleet's table handle always points at the CURRENT
            # driver's table (a reused Fleet must not keep a stale one)
            fleet.jobs = table
        # node-granular placement: the fleet NodeMap holds per-node free
        # counts and per-job node spans (row == trace index == table
        # slot); the policy plans spans against it, _apply commits them,
        # and failures pick victims from the real node assignments
        self._cluster_idx = fleet.cluster_index()
        self.defrag_migrations = 0
        self._stranded_sum = 0.0
        self._frag_ticks = 0
        if self.cfg.node_placement:
            fleet.node_map = NodeMap.from_fleet(
                fleet, capacity_rows=max(1, len(self._jobs_list))
            )
            for i, j in enumerate(self._jobs_list):
                j.node_slot = i
        else:
            fleet.node_map = None
        self.now = 0.0
        self.preemptions = 0
        self.migrations = 0
        self.migrations_cross_region = 0
        self.resizes = 0
        self.restores = 0
        self.restores_cross_region = 0
        self.busy_gpu_seconds = 0.0
        self.gpu_seconds_dead = 0.0
        self.queue_seconds = 0.0
        self.events_processed = 0
        self._lost_by_tier = {t: 0.0 for t in TIERS}
        self._cluster_by_id = {c.id: c for c in fleet.clusters()}
        self._index = {j.id: i for i, j in enumerate(self._jobs_list)}
        # ids the current decision's water-filling pass slope-expanded
        # (refreshed by _apply; resize events on them carry cause=slope)
        self._slope_expanded: frozenset = frozenset()
        # ---- reliability: failure schedule + checkpoint cadence ----------
        self.failure_events = 0
        self.job_failures = 0
        self.snapshots = 0
        self.lost_work_gpu_seconds = 0.0
        self.restarts_by_cause: Dict[str, int] = {}
        self._ettr_sum = {t: 0.0 for t in TIERS}
        self._ettr_n = {t: 0 for t in TIERS}
        self.failure_trace: Optional[FailureTrace] = None
        # per-cluster (time, gpus, repair) failure entries + drain warnings,
        # consumed by advancing pointers; repairs are a (time, cid, amount)
        # heap where amount is the raw GPU count (cluster-granular) or the
        # failure's per-node claim list (node-granular)
        self._fails: List[Tuple[float, str, int, float, int]] = []
        self._warns: List[Tuple[float, str, float]] = []
        self._fail_ptr = 0
        self._warn_ptr = 0
        self._repairs: List[Tuple[float, str, object]] = []
        # outstanding failure amounts per cluster (unclamped sum): dead
        # capacity is min(total, outstanding), so overlapping failures
        # cannot resurrect capacity when the shorter one repairs first
        self._outstanding: Dict[str, int] = {}
        if self.cfg.failures is not None:
            trace = self.cfg.failures
            if isinstance(trace, FailureModel):
                trace = trace.sample(fleet, self.cfg.horizon_seconds)
            self.failure_trace = trace
            by_region = {r.id: [c.id for c in r.clusters] for r in fleet.regions}
            for e in trace.events:
                if e.level != "region":
                    cids = [e.domain]
                else:
                    cids = by_region.get(e.domain, [])
                for cid in cids:
                    if cid not in self._cluster_by_id:
                        continue
                    # the event KIND rides along so a telemetry FAILURE row
                    # can say what kind of failure killed the job
                    self._fails.append(
                        (e.time, cid, e.gpus, e.repair_seconds, CAUSE_CODE[e.kind])
                    )
                    if e.warning_seconds > 0:
                        self._warns.append((e.time - e.warning_seconds, cid, e.time))
            self._fails.sort()
            self._warns.sort()
        self._has_failures = bool(self._fails)
        self._reliability = self._has_failures or self.cfg.cadence is not None
        self._tau: Optional[np.ndarray] = None
        self._snap_cost: Optional[np.ndarray] = None
        if self.cfg.cadence is not None and self._jobs_list:
            clusters = fleet.clusters()
            gpn = clusters[0].gpus_per_node if clusters else 8
            self._tau = np.atleast_1d(
                np.asarray(
                    self.cfg.cadence.interval_seconds(
                        np.array([j.checkpoint_bytes for j in self._jobs_list], float),
                        np.array([j.demand_gpus for j in self._jobs_list], float),
                        gpn,
                    ),
                    np.float64,
                )
            )
            if self._table is not None:
                # per-job snapshot charge, precomputed for the masked
                # vector cadence update (same arithmetic as the scalar
                # per-job _charge path, element for element)
                n = len(self._jobs_list)
                self._snap_cost = np.broadcast_to(
                    np.asarray(
                        self.costs.snapshot_seconds(
                            self._table.checkpoint_bytes[:n].astype(np.float64)
                        ),
                        np.float64,
                    ),
                    (n,),
                ).copy()
        if self.tele is not None:
            self.tele.meta.update(
                reliability=self._reliability,
                clusters=[c.id for c in fleet.clusters()],
                tick_seconds=self.cfg.tick_seconds,
                jobs=len(self._jobs_list),
                job_ids=[j.id for j in self._jobs_list],
            )

    # -- cost charging ---------------------------------------------------------
    def _charge(self, j: Job, seconds: float) -> None:
        if seconds <= 0:
            return
        j.downtime_until = max(j.downtime_until, self.now) + seconds
        j.downtime_seconds += seconds

    # -- reliability tick (shared by both event loops) -------------------------
    def _tick_reliability(self, active: List[Job]) -> List[Job]:
        """Apply due repairs, drain warnings, failures and cadence
        snapshots at ``self.now``; returns the jobs whose runtime state
        (allocation / progress / downtime) changed so the vectorized loop
        can resync its arrays.  Operates purely on job objects — the
        legacy and vectorized loops share it verbatim."""
        changed = self._process_failures(active) if self._has_failures else []
        if self.cfg.cadence is not None:
            changed.extend(self._cadence_snapshots(active))
        return changed

    def _process_failures(self, active: List[Job]) -> List[Job]:
        now = self.now
        nm = self.fleet.node_map
        # repairs due: the domain's capacity comes back — but only down
        # to the other failures still outstanding on the same nodes
        # (per node under a NodeMap, per cluster otherwise)
        while self._repairs and self._repairs[0][0] <= now:
            _, cid, g = heapq.heappop(self._repairs)
            c = self._cluster_by_id[cid]
            if nm is not None:
                nm.repair_claims(g)
                c.dead_gpus = nm.cluster_dead(self._cluster_idx[cid])
            else:
                self._outstanding[cid] = max(0, self._outstanding.get(cid, 0) - g)
                c.dead_gpus = min(c.total_gpus, self._outstanding[cid])
        # drain warnings: the policy sees the domain as draining from here
        warns = self._warns
        while self._warn_ptr < len(warns) and warns[self._warn_ptr][0] <= now:
            _, cid, deadline = warns[self._warn_ptr]
            self._warn_ptr += 1
            c = self._cluster_by_id[cid]
            c.draining = True
            c.drain_deadline = deadline
        # failures due in (previous event, now]
        fired = []
        fails = self._fails
        while self._fail_ptr < len(fails) and fails[self._fail_ptr][0] <= now:
            fired.append(fails[self._fail_ptr])
            self._fail_ptr += 1
        if not fired:
            return []
        by_cluster: Dict[str, List[Job]] = {}
        if nm is None:
            for j in active:
                if j.done_at is None and j.allocated > 0 and j.cluster is not None:
                    by_cluster.setdefault(j.cluster, []).append(j)
        changed: List[Job] = []
        for e_time, cid, gpus, repair, ckind in fired:
            c = self._cluster_by_id[cid]
            want = c.total_gpus if gpus <= 0 else min(gpus, c.total_gpus)
            # repair is anchored to the FAILURE time, not the processing
            # tick; a sub-tick outage (already repaired) still kills its
            # victims but never marks capacity dead.  The UNCLAMPED
            # amount joins the outstanding sum so overlapping failures
            # never resurrect capacity early (dead capacity is
            # min(total, outstanding) until each failure's own repair).
            if nm is not None:
                # node-granular: the failure claims specific nodes, dead
                # capacity and victims both come from the real node
                # assignments — a job dies iff it holds a piece of a
                # node the claim actually takes capacity from
                k = self._cluster_idx[cid]
                claims = nm.fail_claims(k, want) if want > 0 else []
                vrows = nm.apply_claims(claims)
                if e_time + repair > now and want > 0:
                    heapq.heappush(self._repairs, (e_time + repair, cid, claims))
                else:
                    # sub-tick outage: victims died, capacity is back
                    nm.repair_claims(claims)
                c.dead_gpus = nm.cluster_dead(k)
                victims = [self._jobs_list[r] for r in vrows]
            else:
                if e_time + repair > now and want > 0:
                    self._outstanding[cid] = self._outstanding.get(cid, 0) + want
                    c.dead_gpus = min(c.total_gpus, self._outstanding[cid])
                    heapq.heappush(self._repairs, (e_time + repair, cid, want))
                # victims without a NodeMap fall back to the packing-order
                # approximation: jobs pack the cluster in (arrival, id)
                # order; a partial failure of W GPUs takes out every job
                # overlapping the first W.
                pool = sorted(
                    by_cluster.get(cid, []), key=lambda j: (j.arrival, j.id)
                )
                if want >= c.total_gpus:
                    victims = list(pool)
                else:
                    victims, cum = [], 0
                    for j in pool:
                        if cum >= want:
                            break
                        victims.append(j)
                        cum += j.allocated
                if victims:
                    vset = set(id(v) for v in victims)
                    by_cluster[cid] = [j for j in pool if id(j) not in vset]
            if c.draining and e_time >= c.drain_deadline - 1e-9:
                # the warned drain itself fired: dead capacity takes over.
                # An unrelated failure inside the warning window must NOT
                # cancel the drain — evacuation continues to the deadline.
                c.draining = False
            self.failure_events += 1
            for j in victims:
                lost = max(0.0, j.progress - j.snap_progress)
                lost_gpu_seconds = lost * j.gpu_hours * 3600.0
                self.lost_work_gpu_seconds += lost_gpu_seconds
                self._lost_by_tier[j.tier] += lost_gpu_seconds
                if self._ev is not None:
                    self._ev.append(
                        now,
                        E_FAILURE,
                        job=self._index[j.id],
                        cluster=self._cluster_idx.get(j.cluster, -1),
                        tier=TIER_CODE[j.tier],
                        cause=ckind,
                        gpus=j.allocated,
                        seconds=lost_gpu_seconds,
                    )
                j.progress = j.snap_progress
                j.allocated = 0
                j.failures += 1
                j.failed_at = now
                j.queued_since = now  # fairness aging restarts here
                self.job_failures += 1
                changed.append(j)
        return changed

    def _cadence_snapshots(self, active: List[Job]) -> List[Job]:
        """Periodic snapshots per the Young–Daly cadence: running jobs
        past their interval checkpoint now, paying the snapshot's
        downtime in exchange for bounding the work a failure can claw
        back.  ``Job.progress`` must be current (the vectorized loop
        syncs it before calling)."""
        if self._tau is None:
            return []
        now = self.now
        changed: List[Job] = []
        for j in active:
            if j.done_at is not None or j.allocated <= 0:
                continue
            i = self._index[j.id]
            if now - j.snap_time < self._tau[i] - 1e-9:
                continue
            j.snap_progress = j.progress
            j.snap_time = now
            cost = self.costs.snapshot_seconds(j.checkpoint_bytes)
            self._charge(j, cost)
            self.snapshots += 1
            if self._ev is not None:
                self._ev.append(
                    now,
                    E_SNAPSHOT,
                    job=i,
                    cluster=self._cluster_idx.get(j.cluster, -1),
                    tier=TIER_CODE[j.tier],
                    gpus=j.allocated,
                    seconds=cost,
                )
            changed.append(j)
        return changed

    def _cadence_snapshots_vec(self, act: np.ndarray) -> None:
        """The scalar ``_cadence_snapshots`` sweep as one masked update
        over the JobTable's columns: same due rule, same charge
        arithmetic (zero-cost snapshots skip the downtime write exactly
        like ``_charge``), snapshot-for-snapshot identical —
        ``tests/test_reliability.py`` pins the equivalence."""
        if self._tau is None or act.size == 0:
            return
        now = self.now
        t = self._table
        run = act[t.allocated[act] > 0]
        due = run[now - t.snap_time[run] >= self._tau[run] - 1e-9]
        if due.size == 0:
            return
        t.snap_progress[due] = t.progress[due]
        t.snap_time[due] = now
        cost = self._snap_cost[due]
        pos = cost > 0
        if pos.any():
            dp = due[pos]
            t.downtime_until[dp] = np.maximum(t.downtime_until[dp], now) + cost[pos]
            t.downtime_seconds[dp] += cost[pos]
        self.snapshots += int(due.size)
        if self._ev is not None:
            # batched append — one row per due job, identical to the
            # scalar sweep's per-job appends (zero-cost snapshots emit a
            # 0.0-second row exactly like _charge's no-op)
            self._ev.append_batch(
                now,
                E_SNAPSHOT,
                job=due,
                cluster=t.cluster_idx[due],
                tier=t.tier_code[due],
                gpus=t.allocated[due],
                seconds=cost,
            )

    # -- decision application (shared by both event loops) ---------------------
    def _apply(self, decision: Decision) -> None:
        """Apply one scheduling decision, classifying each job transition
        into exactly ONE event and charging its cost model downtime.

        Decisions carrying our JobTable's array form take the masked
        fast path: only jobs with an actual event (preempt / charged
        restore / migrate / resize — a small subset of the fleet) go
        through the per-job classifier; everyone else is updated with a
        few column writes.  Foreign or hand-built decisions walk the
        mapping per job as before."""
        tu = decision.table_update
        # resize events on these jobs this tick were granted by the
        # curve-priced water-filling pass; tag their cause accordingly
        self._slope_expanded = (
            frozenset(decision.slope_expanded)
            if decision.slope_expanded
            else frozenset()
        )
        fast = tu is not None and self._table is not None and tu[0] is self._table
        if fast:
            self._apply_table(tu[1], tu[2], tu[3])
        else:
            for jid, (gpus, cluster) in decision.alloc.items():
                self._apply_one(self.jobs[jid], gpus, cluster)
        for jid in decision.preemptions:
            # victims the policy listed without a zeroed alloc entry
            j = self.jobs[jid]
            if j.done_at is None and j.allocated > 0:
                j.preemptions += 1
                self.preemptions += 1
                j.restore_debt += self.costs.preempt_seconds(j.checkpoint_bytes)
                if self._ev is not None:
                    self._ev.append(
                        self.now,
                        E_PREEMPT,
                        job=self._index[j.id],
                        cluster=self._cluster_idx.get(j.cluster, -1),
                        tier=TIER_CODE[j.tier],
                        cause=C_POLICY,
                        gpus=j.allocated,
                    )
                j.allocated = 0
                j.queued_since = self.now
                if self._reliability:
                    j.snap_progress = j.progress
                    j.snap_time = self.now
        self._commit_node_plan(decision)
        if self.cfg.validate and not fast:
            self._check_capacity(decision)
        if self.cfg.validate:
            self._check_nodes()

    def _commit_node_plan(self, decision: Decision) -> None:
        """Write the decision's node spans into the NodeMap.  Policies
        that planned placement hand over (node map, released rows,
        assigned pieces) — committed verbatim, releases first, so spans
        are exactly what the decide pass saw.  Planless decisions (the
        static gang baseline, hand-written policies) are resynced with a
        greedy auto-fit per changed job; its per-node conservation
        assert rejects over-allocating policies below cluster
        granularity too."""
        nm = self.fleet.node_map
        if nm is None:
            return
        plan = decision.node_plan
        if plan is not None and plan[0] is nm:
            _, released, assigns = plan
            nm.release_many(np.asarray(released, np.int64))
            nm.assign_many(assigns)
            return
        for jid, (g, cid) in decision.alloc.items():
            j = self.jobs[jid]
            if j.done_at is not None:
                continue
            row = j.node_slot
            if row < 0:
                continue
            g = int(g)
            k = self._cluster_idx.get(cid, -1) if cid is not None else -1
            if nm.span_total(row) == g and (g == 0 or nm.span_cluster(row) == k):
                continue
            nm.release(row)
            if g > 0:
                assert k >= 0, f"{jid}: allocated without a cluster"
                nm.auto_fit(row, k, g)
        for jid in decision.preemptions:
            j = self.jobs[jid]
            if j.done_at is None and j.allocated == 0:
                nm.release(j.node_slot)

    def _check_nodes(self) -> None:
        """Per-node conservation, asserted every tick in both event
        loops: free + used + dead == cap on every node, the span pool
        agrees with the per-node used counts, and each live job's span
        sums to exactly its allocation (no span without an allocation,
        no allocation without a span)."""
        nm = self.fleet.node_map
        if nm is None:
            return
        nm.check()
        rows = nm.live_rows()
        n = len(self._jobs_list)
        assert rows.size == 0 or int(rows.max()) < n, "span row out of range"
        if self._table is not None:
            alloc = self._table.allocated[:n]
        else:
            alloc = np.fromiter(
                (
                    0 if j.done_at is not None else j.allocated
                    for j in self._jobs_list
                ),
                np.int64,
                n,
            )
        held = np.zeros(n, np.int64)
        held[rows] = nm.row_total[rows]
        bad = np.flatnonzero(held != alloc)
        assert bad.size == 0, (
            f"job {self._jobs_list[bad[0]].id}: node span holds "
            f"{held[bad[0]]} GPUs but allocation is {alloc[bad[0]]}"
        )

    # -- fragmentation + defragmentation ---------------------------------------
    def _frag_defrag_tick(self, active) -> None:
        """Post-decision fragmentation accounting and (at most) one
        defragmentation move: free GPUs in holes smaller than any queued
        gang's smallest admissible single-node piece are *stranded*;
        when emptying one full node would turn a shape-infeasible queued
        floor feasible and the freed capacity is worth the charged
        migration downtime, consolidate that node's pieces into best-fit
        holes elsewhere in the cluster."""
        nm = self.fleet.node_map
        if nm is None:
            return
        if isinstance(active, JobView):
            t = self._table
            slots = active.slots
            qs = slots[t.allocated[slots] == 0]
            shapes = {
                (int(d), int(m))
                for d, m in zip(t.demand_gpus[qs], t.min_gpus[qs])
            }
        else:
            shapes = {
                (j.demand_gpus, j.min_gpus)
                for j in active
                if j.done_at is None and j.allocated == 0
            }
        self._stranded_sum += nm.stranded_gpus(sorted(shapes))
        self._frag_ticks += 1
        if not shapes or getattr(self.policy, "name", "") == "static":
            return  # static never migrates; nothing queued = nothing stranded
        floors = sorted(
            {f for f in (floor_gang(d, m) for d, m in shapes) if f > 0}
        )
        if floors:
            self._maybe_defrag(nm, floors)

    def _maybe_defrag(self, nm: NodeMap, floors: List[int]) -> None:
        ov = nm.overlay()
        for k in range(nm.n_clusters):
            gpn = int(nm.cluster_gpn[k])
            for f in floors:
                w, r = divmod(f, gpn)
                if int(ov.cfree[k]) < f or ov.feasible(k, f):
                    continue  # hopeless or already feasible as-is
                empty, maxp = ov._stats(k)
                if not (empty + 1 >= w and (r == 0 or maxp >= r or empty + 1 >= w + 1)):
                    continue  # one consolidated node would not unblock it
                if self._defrag_cluster(nm, k):
                    return  # at most one consolidation per tick
                break  # no movable node here; try the next cluster

    def _defrag_cluster(self, nm: NodeMap, k: int) -> bool:
        """Empty one full-capacity node of cluster ``k`` into best-fit
        holes on other occupied nodes, gated by ``defrag_worthwhile``.
        Each moved job is charged exactly one intra-region migration."""
        lo, hi = int(nm.cluster_lo[k]), int(nm.cluster_hi[k])
        gpn = int(nm.cluster_gpn[k])
        cap = nm.node_cap[lo:hi]
        used = nm.node_used[lo:hi]
        free = nm.node_free[lo:hi]
        dead = np.minimum(cap, nm.node_out[lo:hi])
        src = np.flatnonzero((cap == gpn) & (dead == 0) & (used > 0) & (free > 0))
        src = src[np.lexsort((src, used[src]))]  # cheapest to empty first
        idx = np.arange(cap.size)
        for a in src:
            need = int(used[a])
            tgt = np.flatnonzero((free >= need) & (used > 0) & (idx != a))
            if not tgt.size:
                continue
            b = lo + int(tgt[np.lexsort((tgt, free[tgt]))[0]])  # best fit
            rows = nm.rows_on_node(lo + int(a))
            movers = [self._jobs_list[int(r)] for r in rows]
            if not defrag_worthwhile(
                self.costs,
                [j.checkpoint_bytes for j in movers],
                gpn,
                self.cfg.tick_seconds,
            ):
                continue
            for row, j in zip(rows, movers):
                nm.move_piece(int(row), lo + int(a), b)
                j.migrations += 1
                self.migrations += 1
                self.defrag_migrations += 1
                charged = self.costs.migrate_seconds(j.checkpoint_bytes)
                self._charge(j, charged)
                if self._reliability:
                    # the migration round trip checkpoints state
                    j.snap_progress = j.progress
                    j.snap_time = self.now
                if self._ev is not None:
                    self._ev.append(
                        self.now,
                        E_DEFRAG,
                        job=self._index[j.id],
                        cluster=self._cluster_idx.get(j.cluster, -1),
                        tier=TIER_CODE[j.tier],
                        gpus=j.allocated,
                        seconds=charged,
                    )
            return True
        return False

    def _apply_table(
        self, slots: np.ndarray, gpus: np.ndarray, placed: np.ndarray
    ) -> None:
        """Masked-column form of the per-job apply loop.  Event
        classification uses the same predicates as ``_apply_one``'s
        branch chain (cluster codes index ``fleet.clusters()``, which
        ``Decision.table_update`` guarantees); classified jobs run the
        identical scalar body, so charges and counters cannot drift."""
        t = self._table
        alive = np.isnan(t.done_at[slots])
        if not alive.all():
            slots, gpus, placed = slots[alive], gpus[alive], placed[alive]
        prev = t.allocated[slots]
        prev_c = t.cluster_idx[slots]
        run_on = (prev > 0) & (gpus > 0)
        event = (
            ((prev > 0) & (gpus == 0))  # preemption
            | ((prev == 0) & (gpus > 0) & t.ever_ran[slots])  # charged restore
            | (run_on & (placed >= 0) & (prev_c >= 0) & (placed != prev_c))
            | (run_on & (gpus != prev))  # migrate / resize
        )
        eidx = np.flatnonzero(event)
        if eidx.size:
            clusters = self.fleet.clusters()
            objs = t.objs
            for i in eidx:
                cid = clusters[placed[i]].id if placed[i] >= 0 else None
                self._apply_one(objs[slots[i]], int(gpus[i]), cid)
        rest = np.flatnonzero(~event)
        rs = slots[rest]
        g = gpus[rest]
        t.allocated[rs] = g
        t.ever_ran[rs] |= g > 0
        pl = placed[rest]
        hasc = pl >= 0
        t.cluster_idx[rs[hasc]] = pl[hasc]
        if self._ev is not None:
            # the only lifecycle transition left in the bulk path is the
            # free first admission (prev 0 -> g without a checkpoint);
            # everything else was classified through _apply_one above
            adm = np.flatnonzero((prev[rest] == 0) & (g > 0))
            if adm.size:
                ra = rs[adm]
                self._ev.append_batch(
                    self.now,
                    E_ADMIT,
                    job=ra,
                    cluster=t.cluster_idx[ra],
                    tier=t.tier_code[ra],
                    gpus=g[adm],
                )
        if self.cfg.validate:
            self._check_capacity_table(slots, gpus, placed)

    def _apply_one(self, j: Job, gpus: int, cluster: Optional[str]) -> None:
        if j.done_at is not None:
            return
        prev_g = j.allocated
        if prev_g > 0 and gpus == 0:
            # preemption: quiesce + dump + upload.  Work-conserving —
            # the cost is carried as debt and delays the next restore.
            # The graceful checkpoint is a durable snapshot: a later
            # failure can only claw back work past this point.
            j.preemptions += 1
            self.preemptions += 1
            j.restore_debt += self.costs.preempt_seconds(j.checkpoint_bytes)
            j.queued_since = self.now  # fairness aging restarts here
            if self._reliability:
                j.snap_progress = j.progress
                j.snap_time = self.now
            if self._ev is not None:
                self._ev.append(
                    self.now,
                    E_PREEMPT,
                    job=self._index[j.id],
                    cluster=self._cluster_idx.get(j.cluster, -1),
                    tier=TIER_CODE[j.tier],
                    cause=C_POLICY,
                    gpus=prev_g,
                )
        elif prev_g == 0 and gpus > 0:
            # (re)start.  First admission is free; a restore pays
            # download + rendezvous + the carried preempt debt.  A
            # restore onto a different cluster is still one restore —
            # but its download leg is priced by the (checkpoint
            # region, destination region) pair, like a migration's.
            if j.ever_ran:
                self.restores += 1
                src = self.fleet.region_of(j.cluster)
                dst = self.fleet.region_of(cluster) if cluster is not None else src
                cross = src is not None and dst is not None and src != dst
                if cross:
                    self.restores_cross_region += 1
                charged = j.restore_debt + self.costs.restore_seconds(
                    j.checkpoint_bytes, src, dst
                )
                self._charge(j, charged)
                j.restore_debt = 0.0
                if j.failed_at is not None:
                    # restart after an unplanned failure: ETTR sample
                    cause = "failure"
                    self._ettr_sum[j.tier] += self.now - j.failed_at
                    self._ettr_n[j.tier] += 1
                    j.failed_at = None
                else:
                    cause = "preempt"
                if self._reliability:
                    self.restarts_by_cause[cause] = (
                        self.restarts_by_cause.get(cause, 0) + 1
                    )
                if self._ev is not None:
                    dcid = cluster if cluster is not None else j.cluster
                    self._ev.append(
                        self.now,
                        E_RESTORE,
                        job=self._index[j.id],
                        cluster=self._cluster_idx.get(dcid, -1),
                        tier=TIER_CODE[j.tier],
                        cause=C_FAILURE if cause == "failure" else C_PREEMPT,
                        gpus=gpus,
                        seconds=charged,
                        flags=F_CROSS_REGION if cross else 0,
                    )
            elif self._ev is not None:
                dcid = cluster if cluster is not None else j.cluster
                self._ev.append(
                    self.now,
                    E_ADMIT,
                    job=self._index[j.id],
                    cluster=self._cluster_idx.get(dcid, -1),
                    tier=TIER_CODE[j.tier],
                    gpus=gpus,
                )
        elif (
            gpus > 0
            and cluster is not None
            and j.cluster is not None
            and cluster != j.cluster
        ):
            # live migration (possibly with a simultaneous resize —
            # still one event, one Table-5 round trip); the transfer
            # leg is priced by the (source, destination) region pair.
            # The round trip checkpoints state: snapshot refreshes.
            j.migrations += 1
            self.migrations += 1
            src = self.fleet.region_of(j.cluster)
            dst = self.fleet.region_of(cluster)
            cross = src is not None and dst is not None and src != dst
            if cross:
                self.migrations_cross_region += 1
            charged = self.costs.migrate_seconds(j.checkpoint_bytes, src, dst)
            self._charge(j, charged)
            if self._reliability:
                j.snap_progress = j.progress
                j.snap_time = self.now
            if self._ev is not None:
                # a migration off a draining cluster is a drain
                # evacuation — that's the cause the event log records
                drain = self._cluster_by_id[j.cluster].draining
                self._ev.append(
                    self.now,
                    E_MIGRATE,
                    job=self._index[j.id],
                    cluster=self._cluster_idx.get(cluster, -1),
                    tier=TIER_CODE[j.tier],
                    cause=C_DRAIN if drain else C_POLICY,
                    gpus=gpus,
                    seconds=charged,
                    flags=F_CROSS_REGION if cross else 0,
                )
        elif gpus > 0 and gpus != prev_g:
            # in-place transparent resize (splice swap)
            j.resizes += 1
            self.resizes += 1
            charged = self.costs.resize_seconds(j.checkpoint_bytes)
            self._charge(j, charged)
            if self._ev is not None:
                self._ev.append(
                    self.now,
                    E_RESIZE,
                    job=self._index[j.id],
                    cluster=self._cluster_idx.get(j.cluster, -1),
                    tier=TIER_CODE[j.tier],
                    cause=(
                        C_SLOPE if j.id in self._slope_expanded else C_NONE
                    ),
                    gpus=gpus,
                    seconds=charged,
                )
        j.allocated = gpus
        if gpus > 0:
            j.ever_ran = True
        if cluster is not None:
            j.cluster = cluster

    def _check_capacity(self, decision: Decision) -> None:
        """Fleet-capacity conservation: no decision may over-allocate any
        cluster or the fleet — counting only HEALTHY capacity, so a
        failed-out domain's GPUs cannot be handed out while it awaits
        repair."""
        used: Dict[str, int] = {}
        total = 0
        for jid, (g, c) in decision.alloc.items():
            if g <= 0 or self.jobs[jid].done_at is not None:
                continue
            total += g
            if c is not None:
                used[c] = used.get(c, 0) + g
        cap = self.fleet.capacity()
        assert total <= cap, f"fleet over-allocated: {total} > {cap}"
        for c, u in used.items():
            healthy = self._cluster_by_id[c].capacity()
            assert u <= healthy, f"cluster {c} over-allocated: {u} > {healthy}"

    def _check_capacity_table(
        self, slots: np.ndarray, gpus: np.ndarray, placed: np.ndarray
    ) -> None:
        """``_check_capacity`` over the decision's array form: one
        bincount instead of a per-job dict walk (done jobs were already
        filtered by ``_apply_table``)."""
        live = gpus > 0
        total = int(gpus[live].sum())
        cap = self.fleet.capacity()
        assert total <= cap, f"fleet over-allocated: {total} > {cap}"
        pl = placed[live]
        hasc = pl >= 0
        if not hasc.any():
            return
        clusters = self.fleet.clusters()
        used = np.bincount(pl[hasc], weights=gpus[live][hasc], minlength=len(clusters))
        healthy = np.fromiter((c.capacity() for c in clusters), np.int64, len(clusters))
        over = np.flatnonzero(used > healthy)
        assert over.size == 0, (
            f"cluster {clusters[over[0]].id} over-allocated: "
            f"{used[over[0]]:.0f} > {healthy[over[0]]}"
        )

    # ==================== legacy (seed) event loop ============================
    # O(jobs) Python scan per event; kept as the measured baseline for
    # benchmarks/sched_scale.py and as an oracle for the vectorized loop.

    def _advance_legacy(self, dt: float) -> None:
        if dt <= 0:
            return
        end = self.now + dt
        for j in self.jobs.values():
            if j.done_at is not None or j.arrival > self.now:
                continue
            # downtime split: dead GPU time delivers no SLA credit
            cut = min(max(j.downtime_until, self.now), end)
            j.account.record(self.now, cut, 0)
            j.account.record(cut, end, j.allocated)
            if j.allocated > 0:
                eff = end - cut
                self.busy_gpu_seconds += j.allocated * eff
                self.gpu_seconds_dead += j.allocated * (cut - self.now)
                if eff > 0:
                    j.progress = min(1.0, j.progress + j.rate() * eff)
                    if j.progress >= 1.0 - 1e-12:
                        if self._ev is not None:
                            self._ev.append(
                                end,
                                E_COMPLETE,
                                job=self._index[j.id],
                                cluster=self._cluster_idx.get(j.cluster, -1),
                                tier=TIER_CODE[j.tier],
                                gpus=j.allocated,
                            )
                        j.done_at = end
                        j.allocated = 0
                        _release_account(j)
                        if self.fleet.node_map is not None:
                            self.fleet.node_map.release(j.node_slot)
                        if isinstance(j, TableJob):
                            j._table.detach(j)
            else:
                self.queue_seconds += dt
        self.now = end

    # ==================== serving tier hooks ==================================

    def _serving_begin(self, now: float) -> None:
        """Once per scheduler tick, before decide: retarget each service's
        demand column from the traffic trace + autoscaler.  Both policy
        paths then see identical inputs, so decision digests stay
        equivalent with services in the mix."""
        targets = self.serving.begin_tick(now)
        self._svc_open = targets is not None
        if targets is None:
            return
        idx = self._svc_idx
        if self._table is not None:
            self._table.demand_gpus[idx] = targets
        else:
            for k in range(idx.size):
                self._jobs_list[k].demand_gpus = int(targets[k])
            demand = getattr(self, "_demand", None)
            if demand is not None:
                demand[idx] = targets.astype(demand.dtype)

    def _serving_end(self, now: float) -> None:
        """After the tick's decision is applied: score the SLO window,
        close reclaim deficits, accrue loaned GPU time."""
        self._svc_open = False
        idx = self._svc_idx
        n = len(self._jobs_list)
        if self._table is not None:
            col = self._table.allocated
            dtu = self._table.downtime_until[idx].astype(np.float64)
        else:
            col = getattr(self, "_alloc", None)
            if col is not None:
                dtu = self._downtime_until[idx].astype(np.float64)
        if col is not None:
            alloc = col[idx].astype(np.int64)
            basic = float(col[:n][self._basic_mask].sum())
        else:  # legacy loop over plain Job objects
            alloc = np.fromiter(
                (self._jobs_list[k].allocated for k in range(idx.size)),
                np.int64,
                idx.size,
            )
            dtu = np.fromiter(
                (self._jobs_list[k].downtime_until for k in range(idx.size)),
                np.float64,
                idx.size,
            )
            basic = float(
                sum(
                    j.allocated
                    for j, b in zip(self._jobs_list, self._basic_mask)
                    if b
                )
            )
        self.serving.end_tick(now, alloc, dtu, basic)

    # ==================== per-tick telemetry ==================================

    def _record_tick_metrics(self, now: float) -> None:
        """One MetricsSeries row per scheduler tick (telemetry only;
        computed OUTSIDE the decide path so the decide-time overhead gate
        measures the profiler alone)."""
        tele = self.tele
        n = len(self._jobs_list)
        nt = len(TIER_CODE)
        if self._table is not None:
            tb = self._table
            alloc = tb.allocated[:n]
            live = np.isnan(tb.done_at[:n]) & (tb.arrival[:n] <= now)
            total_alloc = int(alloc[live].sum())
            queued = live & (alloc == 0)
            counts = np.bincount(tb.tier_code[:n][queued], minlength=nt)
        else:
            counts = np.zeros(nt, np.int64)
            total_alloc = 0
            for j in self._jobs_list:
                if j.done_at is not None or j.arrival > now:
                    continue
                if j.allocated > 0:
                    total_alloc += j.allocated
                else:
                    counts[TIER_CODE[j.tier]] += 1
        cap = self.fleet.capacity()
        consumed = self.busy_gpu_seconds + self.gpu_seconds_dead
        goodput = (
            max(0.0, self.busy_gpu_seconds - self.lost_work_gpu_seconds)
            / consumed
            if consumed > 0
            else 1.0
        )
        slo, loaned = 1.0, 0.0
        if self.serving is not None:
            slo = self.serving.attainment()
            loaned = float(self.serving.last_loan_out)
        stranded = self._stranded_sum - self._stranded_prev
        self._stranded_prev = self._stranded_sum
        prof, prev = tele.prof, self._m_prev
        dec = prof.total("decide")
        plc = prof.total("place")
        app = prof.total("apply")
        tele.metrics.record(
            time=now,
            allocated_gpus=float(total_alloc),
            utilization=total_alloc / cap if cap else 0.0,
            queue_premium=float(counts[TIER_CODE["premium"]]),
            queue_standard=float(counts[TIER_CODE["standard"]]),
            queue_basic=float(counts[TIER_CODE["basic"]]),
            stranded_gpus=stranded,
            loaned_gpus=loaned,
            goodput=goodput,
            slo_attainment=slo,
            decide_seconds=dec - prev["decide"],
            place_seconds=plc - prev["place"],
            apply_seconds=app - prev["apply"],
        )
        prev["decide"], prev["place"], prev["apply"] = dec, plc, app

    def _run_legacy_loop(self) -> None:
        cfg = self.cfg
        events = [j.arrival for j in self.jobs.values()]
        t = 0.0
        while t < cfg.horizon_seconds:
            events.append(t)
            t += cfg.tick_seconds
        for t in sorted(set(events)):
            if t > cfg.horizon_seconds:
                break
            self._advance_legacy(t - self.now)
            self.events_processed += 1
            if all(j.done_at is not None for j in self.jobs.values()):
                break
            # only arrived jobs are visible to the policy (StaticGangPolicy
            # does not filter by arrival itself; the vectorized loop only
            # ever activates arrived jobs, and the two must agree)
            arrived = [j for j in self.jobs.values() if j.arrival <= self.now]
            if self._reliability:
                self._tick_reliability([j for j in arrived if j.done_at is None])
            if self.serving is not None:
                self._serving_begin(self.now)
            if self.tele is not None:
                self.tele.prof.set_anchor(self.now)
            decision = self.policy.decide(self.now, arrived, self.fleet)
            if self.tele is not None:
                with self.tele.prof.span("apply"):
                    self._apply(decision)
            else:
                self._apply(decision)
            self._frag_defrag_tick(arrived)
            if self.serving is not None and self._svc_open:
                self._serving_end(self.now)
            if self.tele is not None:
                self._record_tick_metrics(self.now)

    # ==================== vectorized event loop ===============================

    def _build_arrays(self) -> None:
        jobs = self._jobs_list
        n = len(jobs)
        if self._table is not None:
            # the JobTable IS the storage (slot == index): the loop
            # advances the very columns the policy slices and _apply's
            # property writes land in, so nothing is re-materialized
            # from the job objects and nothing needs resyncing.
            t = self._table
            t.pinned = True  # growth would decouple the bound views
            self._arrival = t.arrival
            self._demand = t.demand_gpus
            self._ideal = t.ideal
            self._ovh = t.splice_overhead
            self._knee = t.knee_gpus
            self._sat = t.sat_slope
            self._guar = _TIER_GFRAC[t.tier_code[:n]] > 0
            self._progress = t.progress
            self._alloc = t.allocated
            self._downtime_until = t.downtime_until
        else:
            self._arrival = np.array([j.arrival for j in jobs])
            self._demand = np.array([float(j.demand_gpus) for j in jobs])
            self._ideal = np.array([j.ideal_seconds for j in jobs])
            self._ovh = np.array([j.splice_overhead for j in jobs])
            self._knee = np.array([j.knee_gpus for j in jobs], np.int64)
            self._sat = np.array([j.sat_slope for j in jobs])
            self._guar = np.array([TIERS[j.tier].gpu_fraction > 0 for j in jobs])
            self._progress = np.zeros(n)
            self._alloc = np.zeros(n)
            self._downtime_until = np.zeros(n)
        self._done = np.zeros(n, dtype=bool)
        # ledger plumbing: which jobs carry a view on OUR ledger (others
        # — foreign views or history-carrying scalar accounts — record
        # through the per-job fallback), and their lazily-filled slots
        self._views = [j.account for j in jobs]
        if self._ledger is not None:
            self._is_view = np.fromiter(
                (
                    isinstance(a, FleetSlotAccount) and a.ledger is self._ledger
                    for a in self._views
                ),
                bool,
                n,
            )
        else:
            self._is_view = np.zeros(n, dtype=bool)
        self._slot = np.full(n, -1, np.int64)
        # precomputed arrival-sorted activation order (fancy indexing
        # copies, so later slot resets cannot disturb activation)
        self._arr_order = np.argsort(self._arrival[:n], kind="stable")
        self._arr_sorted = self._arrival[self._arr_order]

    def _advance_vec(self, act: np.ndarray, dt: float) -> None:
        """Numpy-batched progress update over the active window."""
        if dt <= 0 or act.size == 0:
            return
        t0, t1 = self.now, self.now + dt
        alloc = self._alloc[act]
        running = alloc > 0
        cut = np.clip(self._downtime_until[act], t0, t1)
        eff = t1 - cut  # productive seconds
        dead = cut - t0  # charged-downtime seconds
        share = np.minimum(alloc / self._demand[act], 2.0)
        # concave scaling curves (curves.scaling_eff, vector form): past
        # a job's saturation knee the marginal GPU only buys sat_slope
        # of a linear one; knee == 0 is the flat sentinel (seed model)
        k = self._knee[act]
        gf = np.minimum(alloc, 2.0 * self._demand[act])
        over = (k > 0) & (gf > k)
        if over.any():
            d = self._demand[act]
            share = np.where(
                over,
                np.minimum((k + self._sat[act] * (gf - k)) / d, 2.0),
                share,
            )
        share = np.where(
            alloc < self._demand[act], share * (1.0 - self._ovh[act]), share
        )
        dp = np.where(running, share / self._ideal[act] * eff, 0.0)
        prog = self._progress[act] + dp
        self._progress[act] = np.minimum(prog, 1.0)
        self.busy_gpu_seconds += float(np.sum(alloc * eff * running))
        self.gpu_seconds_dead += float(np.sum(alloc * dead * running))
        self.queue_seconds += float(np.count_nonzero(~running)) * dt
        # SLA delivery: only guaranteed tiers are ever consulted by the
        # policy.  Ledger-backed jobs record in two batched calls (the
        # downtime/productive split); stragglers take the per-job path.
        jobs = self._jobs_list
        gsel = np.flatnonzero(self._guar[act])
        if gsel.size:
            vmask = self._is_view[act[gsel]]
            vsel = gsel[vmask]
            if vsel.size:
                rows = act[vsel]
                slots = self._slot[rows]
                if (slots < 0).any():
                    for i in rows[slots < 0]:
                        self._slot[i] = self._views[i].ensure_slot()
                    slots = self._slot[rows]
                m = rows.size
                self._ledger.record_batch(
                    slots, np.full(m, t0), cut[vsel], np.zeros(m, np.int64)
                )
                self._ledger.record_batch(
                    slots, cut[vsel], np.full(m, t1), alloc[vsel].astype(np.int64)
                )
            for k in gsel[~vmask]:
                i = act[k]
                j = jobs[i]
                c = cut[k]
                j.account.record(t0, c, 0)
                j.account.record(c, t1, int(alloc[k]))
        # completions (done_at granularity = this advance's end, matching
        # the legacy loop's semantics)
        done_now = act[(prog >= 1.0 - 1e-12) & running]
        if done_now.size:
            if self._ev is not None:
                if self._table is not None:
                    cl = self._table.cluster_idx[done_now]
                    tc = self._table.tier_code[done_now]
                else:
                    cl = np.fromiter(
                        (
                            self._cluster_idx.get(jobs[i].cluster, -1)
                            for i in done_now
                        ),
                        np.int64,
                        done_now.size,
                    )
                    tc = np.fromiter(
                        (TIER_CODE[jobs[i].tier] for i in done_now),
                        np.int64,
                        done_now.size,
                    )
                self._ev.append_batch(
                    t1,
                    E_COMPLETE,
                    job=done_now,
                    cluster=cl,
                    tier=tc,
                    gpus=self._alloc[done_now].astype(np.int64),
                )
            self._done[done_now] = True
            self._alloc[done_now] = 0
            nm = self.fleet.node_map
            if nm is not None:
                for i in done_now:
                    nm.release(int(i))  # row == trace index
            if self._table is not None:
                # release-on-completion: final state is written to the
                # columns, then the tick's finishers detach in one batch
                # (state copied back to the instances, rows freed)
                self._progress[done_now] = 1.0
                self._table.done_at[done_now] = t1
                for i in done_now:
                    _release_account(jobs[i])
                self._table.detach_batch(done_now)
            else:
                for i in done_now:
                    jobs[i].progress = 1.0
                    jobs[i].done_at = t1
                    jobs[i].allocated = 0
                    _release_account(jobs[i])

    def _run_vectorized_loop(self) -> None:
        cfg = self.cfg
        self._build_arrays()
        jobs = self._jobs_list
        n = len(jobs)
        act = np.empty(0, dtype=np.int64)
        ptr = 0
        t = 0.0
        while t <= cfg.horizon_seconds + 1e-9:
            self._advance_vec(act, t - self.now)
            # activate arrivals in (prev tick, t]; they queued since arrival
            hi = int(np.searchsorted(self._arr_sorted, t, side="right"))
            if hi > ptr:
                newly = self._arr_order[ptr:hi]
                self.queue_seconds += float(np.sum(t - self._arrival[newly]))
                act = np.concatenate([act, newly])
                ptr = hi
            self.now = t
            self.events_processed += 1
            if self._done[act].any():
                act = act[~self._done[act]]
            if ptr >= n and act.size == 0:
                break
            if act.size:
                if self._table is not None:
                    # zero-gather decide path: the policy slices the
                    # table's columns at these slots, _apply's property
                    # writes land in the same columns — no job-object
                    # walks, no resync, and reliability mutates live
                    # state through the views
                    active_jobs = self._table.view(act)
                    if self._reliability:
                        if self._has_failures:
                            self._process_failures(active_jobs)
                        if self.cfg.cadence is not None:
                            self._cadence_snapshots_vec(act)
                else:
                    active_jobs = [jobs[i] for i in act]
                    if self._reliability:
                        # failures/cadence read and mutate per-job
                        # progress: sync the arrays out, tick
                        # reliability, sync back
                        for i in act:
                            jobs[i].progress = float(self._progress[i])
                        for j in self._tick_reliability(active_jobs):
                            i = self._index[j.id]
                            self._alloc[i] = j.allocated
                            self._progress[i] = j.progress
                            self._downtime_until[i] = j.downtime_until
                if self.serving is not None:
                    self._serving_begin(t)
                if self.tele is not None:
                    self.tele.prof.set_anchor(t)
                decision = self.policy.decide(t, active_jobs, self.fleet)
                if self.tele is not None:
                    with self.tele.prof.span("apply"):
                        self._apply(decision)
                else:
                    self._apply(decision)
                self._frag_defrag_tick(active_jobs)
                if self._table is None:
                    for i in act:
                        self._alloc[i] = jobs[i].allocated
                        self._downtime_until[i] = jobs[i].downtime_until
                if self.serving is not None and self._svc_open:
                    self._serving_end(t)
                if self.tele is not None:
                    self._record_tick_metrics(t)
            t += cfg.tick_seconds
        # final sync for jobs still in flight at the horizon (table-backed
        # jobs read the live columns; nothing to sync)
        if self._table is None:
            for i in range(n):
                if not self._done[i]:
                    jobs[i].progress = float(self._progress[i])

    # ==========================================================================

    def run(self) -> SimResult:
        if self.cfg.vectorized:
            self._run_vectorized_loop()
        else:
            self._run_legacy_loop()

        total_gpu_seconds = self.fleet.total() * self.now if self.now else 1.0
        jobs = list(self.jobs.values())
        done = [j for j in jobs if j.done_at is not None]
        sla, jct = {}, {}
        downtime = {t: 0.0 for t in TIERS}
        for j in jobs:
            downtime[j.tier] += j.downtime_seconds
        for tier in TIERS:
            tjobs = [j for j in done if j.tier == tier]
            if not tjobs:
                continue
            ok = 0
            for j in tjobs:
                real = j.done_at - j.arrival
                frac = j.ideal_seconds / real if real > 0 else 1.0
                if frac >= TIERS[tier].gpu_fraction - 1e-9:
                    ok += 1
            sla[tier] = ok / len(tjobs)
            jct[tier] = float(np.mean([j.done_at - j.arrival for j in tjobs]))
        consumed = self.busy_gpu_seconds + self.gpu_seconds_dead
        goodput = (
            max(0.0, self.busy_gpu_seconds - self.lost_work_gpu_seconds) / consumed
            if consumed > 0
            else 1.0
        )
        goodput_vals: Dict[str, List[float]] = {t: [] for t in TIERS}
        for j in jobs:
            if j.arrival >= self.now or j.service:
                continue  # services never "complete"; SLO metrics cover them
            end = j.done_at if j.done_at is not None else self.now
            if end > j.arrival:
                goodput_vals[j.tier].append(
                    min(1.0, j.progress * j.ideal_seconds / (end - j.arrival))
                )
        goodput_by_tier = {
            t: float(np.mean(v)) for t, v in goodput_vals.items() if v
        }
        return SimResult(
            utilization=self.busy_gpu_seconds / total_gpu_seconds,
            sla_attainment=sla,
            mean_jct=jct,
            completed=len(done),
            total_jobs=len(jobs),
            preemptions=self.preemptions,
            migrations=self.migrations,
            resizes=self.resizes,
            queue_seconds=self.queue_seconds,
            gpu_seconds_idle=(
                total_gpu_seconds - self.busy_gpu_seconds - self.gpu_seconds_dead
            ),
            restores=self.restores,
            gpu_seconds_dead=self.gpu_seconds_dead,
            downtime_by_tier={t: v for t, v in downtime.items() if v > 0},
            migrations_cross_region=self.migrations_cross_region,
            restores_cross_region=self.restores_cross_region,
            failure_events=self.failure_events,
            job_failures=self.job_failures,
            snapshots=self.snapshots,
            lost_work_gpu_seconds=self.lost_work_gpu_seconds,
            goodput_fraction=goodput,
            goodput_by_tier=goodput_by_tier,
            restarts_by_cause=dict(self.restarts_by_cause),
            ettr_by_tier={
                t: self._ettr_sum[t] / self._ettr_n[t]
                for t in TIERS
                if self._ettr_n[t] > 0
            },
            fragmentation_stranded_gpus=(
                self._stranded_sum / self._frag_ticks if self._frag_ticks else 0.0
            ),
            defrag_migrations=self.defrag_migrations,
            **(self.serving.summary() if self.serving is not None else {}),
        )

"""Discrete-event fleet simulator (hierarchical scheduler harness).

Mirrors Figure 1's scopes: the GLOBAL scheduler owns the fleet model and
invokes the policy; REGIONAL state is the per-cluster capacity bookkeeping;
the WORKLOAD scope is each job's elastic controller (its SLA account +
resize/preempt reactions), embodied in Job/GpuFractionAccount.

Events: job arrivals, completions and periodic scheduling ticks.  Between
events every running job progresses at its work-conserving elastic rate.
Outputs: utilization, SLA attainment per tier, JCT stats, preemption/
migration/resize counts — the quantities behind the paper's design goals
(§1.1: no idling, job-level SLAs, resilience).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional

import numpy as np

from repro.core.sla import TIERS
from repro.scheduler.policy import Decision, ElasticPolicy
from repro.scheduler.types import Cluster, Fleet, Job, Region


@dataclasses.dataclass
class SimConfig:
    tick_seconds: float = 300.0
    horizon_seconds: float = 48 * 3600.0
    migration_cost_seconds: float = 60.0    # Table 5: tens of seconds


@dataclasses.dataclass
class SimResult:
    utilization: float
    sla_attainment: Dict[str, float]
    mean_jct: Dict[str, float]
    completed: int
    total_jobs: int
    preemptions: int
    migrations: int
    resizes: int
    queue_seconds: float          # total job-seconds spent fully queued
    gpu_seconds_idle: float

    def summary(self) -> str:
        sla = ", ".join(f"{t}={v:.3f}" for t, v in self.sla_attainment.items())
        return (f"util={self.utilization:.3f} sla[{sla}] "
                f"done={self.completed}/{self.total_jobs} "
                f"preempt={self.preemptions} migr={self.migrations} "
                f"resize={self.resizes}")


def make_fleet(n_regions: int = 2, clusters_per_region: int = 2,
               gpus_per_cluster: int = 512) -> Fleet:
    regions = []
    for r in range(n_regions):
        clusters = [Cluster(f"r{r}c{c}", f"r{r}", gpus_per_cluster)
                    for c in range(clusters_per_region)]
        regions.append(Region(f"r{r}", clusters))
    return Fleet(regions)


def synth_workload(n_jobs: int, fleet_gpus: int, seed: int = 0,
                   mean_interarrival: float = 600.0) -> List[Job]:
    """Synthetic trace: mixed tiers/sizes, load ~ fleet capacity."""
    rng = np.random.Generator(np.random.Philox(seed))
    jobs = []
    t = 0.0
    tiers = ["premium", "standard", "basic"]
    tier_p = [0.2, 0.4, 0.4]
    for i in range(n_jobs):
        t += float(rng.exponential(mean_interarrival))
        demand = int(2 ** rng.integers(3, 9))          # 8..256 GPUs
        hours = float(rng.uniform(0.5, 8.0)) * demand / 64
        tier = str(rng.choice(tiers, p=tier_p))
        max_splice = int(2 ** rng.integers(0, 3))      # 1,2,4 (ZeRO floor)
        jobs.append(Job(
            id=f"j{i}", tier=tier, demand_gpus=demand,
            gpu_hours=hours * demand, arrival=t,
            min_gpus=max(1, demand // max_splice)))
    return jobs


class FleetSimulator:
    def __init__(self, fleet: Fleet, jobs: List[Job], policy,
                 cfg: Optional[SimConfig] = None):
        self.fleet = fleet
        self.jobs = {j.id: j for j in jobs}
        self.policy = policy
        self.cfg = cfg or SimConfig()
        self.now = 0.0
        self.preemptions = 0
        self.migrations = 0
        self.resizes = 0
        self.busy_gpu_seconds = 0.0
        self.queue_seconds = 0.0

    # -- progress accounting between events -----------------------------------
    def _advance(self, dt: float) -> None:
        if dt <= 0:
            return
        for j in self.jobs.values():
            if j.done_at is not None or j.arrival > self.now:
                continue
            j.account.record(self.now, self.now + dt, j.allocated)
            if j.allocated > 0:
                j.progress = min(1.0, j.progress + j.rate() * dt)
                self.busy_gpu_seconds += j.allocated * dt
                if j.progress >= 1.0 - 1e-12:
                    j.done_at = self.now + dt
            else:
                self.queue_seconds += dt
        self.now += dt

    def _apply(self, decision: Decision) -> None:
        for jid, (gpus, cluster) in decision.alloc.items():
            j = self.jobs[jid]
            if j.done_at is not None:
                continue
            if gpus != j.allocated and j.allocated > 0 and gpus > 0:
                j.resizes += 1
                self.resizes += 1
            if j.allocated > 0 and gpus == 0:
                j.preemptions += 1
                self.preemptions += 1
            j.allocated = gpus
            if cluster is not None and j.cluster is not None \
                    and cluster != j.cluster:
                j.migrations += 1
                self.migrations += 1
            if cluster is not None:
                j.cluster = cluster
        for jid in decision.preemptions:
            j = self.jobs[jid]
            if j.allocated > 0:
                j.preemptions += 1
                self.preemptions += 1
            j.allocated = 0

    def run(self) -> SimResult:
        cfg = self.cfg
        events = [j.arrival for j in self.jobs.values()]
        t = 0.0
        while t < cfg.horizon_seconds:
            events.append(t)
            t += cfg.tick_seconds
        for t in sorted(set(events)):
            if t > cfg.horizon_seconds:
                break
            self._advance(t - self.now)
            if all(j.done_at is not None for j in self.jobs.values()):
                break
            decision = self.policy.decide(
                self.now, list(self.jobs.values()), self.fleet)
            self._apply(decision)

        total_gpu_seconds = self.fleet.total() * self.now if self.now else 1.0
        jobs = list(self.jobs.values())
        done = [j for j in jobs if j.done_at is not None]
        sla, jct = {}, {}
        for tier in TIERS:
            tjobs = [j for j in done if j.tier == tier]
            if not tjobs:
                continue
            ok = 0
            for j in tjobs:
                real = j.done_at - j.arrival
                frac = j.ideal_seconds / real if real > 0 else 1.0
                if frac >= TIERS[tier].gpu_fraction - 1e-9:
                    ok += 1
            sla[tier] = ok / len(tjobs)
            jct[tier] = float(np.mean([j.done_at - j.arrival for j in tjobs]))
        return SimResult(
            utilization=self.busy_gpu_seconds / total_gpu_seconds,
            sla_attainment=sla, mean_jct=jct,
            completed=len(done), total_jobs=len(jobs),
            preemptions=self.preemptions, migrations=self.migrations,
            resizes=self.resizes, queue_seconds=self.queue_seconds,
            gpu_seconds_idle=total_gpu_seconds - self.busy_gpu_seconds)

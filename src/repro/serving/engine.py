"""Batched serving engine: prefill + decode over the generic model API.

Inference jobs are first-class in Singularity (the scheduler elastically
shrinks training to absorb inference load, §1.1b); this engine is the
serve-side workload driver.  It is also what ``serve_step`` dry-runs lower.

Two halves live here:

* ``ServingEngine`` — the real jax decode loop (jax imported lazily so the
  scheduler side can import this module on machines without an accelerator
  stack).
* The analytic batching/latency model (``GpuSpec``, ``decode_step_seconds``,
  ``max_batch_for_slo``, ``ReplicaProfile``) — a pure-numpy decode roofline
  over the model configs we already carry.  ``scheduler/serving.py`` turns a
  ``ReplicaProfile`` into a qps -> replicas demand curve; ``launch/serve.py``
  prints the same plan for a single service.

The roofline is the standard decode-step model: per step a replica streams
the (sharded) weights plus the batch's KV cache from HBM and performs
``2 * active_params * batch`` FLOPs, so

    step = max(bytes_moved / (g * hbm_bw), flops / (g * peak * mfu)) + overhead

with ``g`` the tensor-parallel degree.  p99 is a fixed multiplier over the
mean step (queueing + stragglers).  Constants default to the repo-wide v5e
targets in ``utils/constants.py``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Optional

from repro.configs.base import ModelConfig
from repro.utils import constants

if TYPE_CHECKING:  # pragma: no cover - typing only, keeps import jax-free
    import jax

BYTES_PER_PARAM = 2  # bf16 weights and KV cache


@dataclasses.dataclass(frozen=True)
class GpuSpec:
    """Per-accelerator envelope the decode roofline runs against."""

    name: str = "tpu-v5e"
    hbm_bytes: int = constants.HBM_BYTES
    hbm_bandwidth: float = constants.HBM_BANDWIDTH
    flops: float = constants.PEAK_BF16_FLOPS
    # achievable fraction of peak during decode (small-batch GEMMs).
    mfu: float = 0.4
    # dispatch + collective latency per decode step, seconds.
    step_overhead_seconds: float = 3e-4


DEFAULT_GPU = GpuSpec()


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per decode step (MoE routes ``top_k`` experts)."""
    total = cfg.param_count()
    if cfg.moe is None:
        return total
    mult = 3 if cfg.mlp == "swiglu" else 2
    expert = cfg.num_layers * mult * cfg.d_model * cfg.d_ff * cfg.moe.num_experts
    expert = min(expert, total)
    active = total - expert + expert * cfg.moe.top_k / cfg.moe.num_experts
    return int(active)


def kv_bytes_per_token(cfg: ModelConfig) -> int:
    """KV-cache bytes appended per generated token (all layers, K + V)."""
    if not cfg.num_heads:  # pure-SSM: constant state, charge nothing per token
        return 0
    hd = cfg.resolved_head_dim()
    return 2 * cfg.num_layers * cfg.num_kv_heads * hd * BYTES_PER_PARAM


def weight_bytes(cfg: ModelConfig) -> int:
    return cfg.param_count() * BYTES_PER_PARAM


def min_gpus_for_memory(
    cfg: ModelConfig,
    gpu: GpuSpec = DEFAULT_GPU,
    memory_overhead: float = 1.25,
) -> int:
    """Smallest power-of-two shard degree whose HBM fits the weights.

    ``memory_overhead`` reserves headroom for KV cache and activations.
    """
    need = weight_bytes(cfg) * memory_overhead
    g = 1
    while g * gpu.hbm_bytes < need:
        g *= 2
    return g


def decode_step_seconds(
    cfg: ModelConfig,
    batch: int,
    n_gpus: int,
    gpu: GpuSpec = DEFAULT_GPU,
    context_len: int = 1024,
) -> float:
    """Mean decode-step latency for one replica sharded over ``n_gpus``."""
    moved = weight_bytes(cfg) + batch * context_len * kv_bytes_per_token(cfg)
    mem = moved / n_gpus / gpu.hbm_bandwidth
    comp = 2.0 * active_param_count(cfg) * batch / n_gpus / (gpu.flops * gpu.mfu)
    return max(mem, comp) + gpu.step_overhead_seconds


def max_batch_for_slo(
    cfg: ModelConfig,
    slo_seconds: float,
    n_gpus: int,
    gpu: GpuSpec = DEFAULT_GPU,
    p99_factor: float = 1.4,
    context_len: int = 1024,
    max_batch: int = 256,
) -> int:
    """Largest batch whose p99 decode step stays within the SLO (0 = none).

    Step latency is monotone nondecreasing in batch, so binary search.
    """
    if decode_step_seconds(cfg, 1, n_gpus, gpu, context_len) * p99_factor > (
        slo_seconds
    ):
        return 0
    lo, hi = 1, max_batch
    while lo < hi:
        mid = (lo + hi + 1) // 2
        p99 = decode_step_seconds(cfg, mid, n_gpus, gpu, context_len) * p99_factor
        if p99 <= slo_seconds:
            lo = mid
        else:
            hi = mid - 1
    return lo


@dataclasses.dataclass(frozen=True)
class ReplicaProfile:
    """One replica group's operating point: the qps -> replicas curve.

    Derived once per (model, SLO) pair; the scheduler only ever sees these
    five numbers plus ``weight_bytes`` (the restore payload a replica must
    stream before it is warm).
    """

    name: str
    gpus_per_replica: int
    batch: int
    p99_decode_seconds: float
    tokens_per_second: float
    qps_per_replica: float
    weight_bytes: int

    @classmethod
    def from_config(
        cls,
        cfg: ModelConfig,
        slo_ms: float,
        tokens_per_request: int = 128,
        gpu: GpuSpec = DEFAULT_GPU,
        p99_factor: float = 1.4,
        context_len: int = 1024,
        max_gpus: int = 256,
    ) -> "ReplicaProfile":
        """Pick the smallest power-of-two shard degree meeting the SLO."""
        slo = slo_ms / 1e3
        g = min_gpus_for_memory(cfg, gpu)
        batch = 0
        while g <= max_gpus:
            batch = max_batch_for_slo(cfg, slo, g, gpu, p99_factor, context_len)
            if batch > 0:
                break
            g *= 2
        if batch == 0:
            raise ValueError(
                f"{cfg.name}: p99 {slo_ms}ms unreachable within "
                f"{max_gpus} gpus/replica"
            )
        step = decode_step_seconds(cfg, batch, g, gpu, context_len)
        tps = batch / step
        return cls(
            name=cfg.name,
            gpus_per_replica=g,
            batch=batch,
            p99_decode_seconds=step * p99_factor,
            tokens_per_second=tps,
            qps_per_replica=tps / tokens_per_request,
            weight_bytes=weight_bytes(cfg),
        )

    def replicas_for(self, qps: float, utilization: float = 1.0) -> int:
        """Replicas needed to serve ``qps`` at the given target utilization."""
        if qps <= 0.0:
            return 0
        return int(math.ceil(qps / (self.qps_per_replica * utilization)))


class ServingEngine:
    def __init__(
        self, cfg: ModelConfig, seed: int = 0, params: Optional[dict] = None
    ):
        import jax

        from repro.models import decode_step_fn, init_params, prefill_fn
        from repro.models.frontend import synth_extra_inputs

        self._jax = jax
        self._prefill_fn = prefill_fn
        self._synth_extra_inputs = synth_extra_inputs
        self.cfg = cfg
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else init_params(cfg, key)
        self._prefills = {}
        self._decode = jax.jit(lambda p, s, t: decode_step_fn(p, s, t, cfg))
        self._extra_key = jax.random.PRNGKey(seed + 7)

    def _prefill(self, params, batch, cache_len: int):
        if cache_len not in self._prefills:
            cfg = self.cfg
            prefill_fn = self._prefill_fn
            self._prefills[cache_len] = self._jax.jit(
                lambda p, b: prefill_fn(p, b, cfg, cache_len=cache_len)
            )
        return self._prefills[cache_len](params, batch)

    def generate(
        self,
        prompts: "jax.Array",
        max_new_tokens: int,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> "jax.Array":
        """prompts: (B, S) int32 -> generated (B, max_new_tokens) int32."""
        jax = self._jax
        b = prompts.shape[0]
        batch = {"tokens": prompts}
        batch.update(self._synth_extra_inputs(self.cfg, b, self._extra_key))
        logits, state = self._prefill(
            self.params, batch, prompts.shape[1] + max_new_tokens
        )
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits, temperature, key)
        out.append(tok)
        for _ in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            logits, state = self._decode(self.params, state, tok)
            tok = self._sample(logits, temperature, sub)
            out.append(tok)
        return jax.numpy.stack(out, axis=1)

    def _sample(self, logits: "jax.Array", temperature: float, key) -> "jax.Array":
        jnp = self._jax.numpy
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return self._jax.random.categorical(
            key, logits / temperature, axis=-1
        ).astype(jnp.int32)

"""Batched serving engine: prefill + decode over the generic model API.

Inference jobs are first-class in Singularity (the scheduler elastically
shrinks training to absorb inference load, §1.1b); this engine is the
serve-side workload driver.  It is also what ``serve_step`` dry-runs lower.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step_fn, init_params, prefill_fn
from repro.models.frontend import synth_extra_inputs


class ServingEngine:
    def __init__(self, cfg: ModelConfig, seed: int = 0,
                 params: Optional[dict] = None):
        self.cfg = cfg
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else init_params(cfg, key)
        self._prefills = {}
        self._decode = jax.jit(lambda p, s, t: decode_step_fn(p, s, t, cfg))
        self._extra_key = jax.random.PRNGKey(seed + 7)

    def _prefill(self, params, batch, cache_len: int):
        if cache_len not in self._prefills:
            cfg = self.cfg
            self._prefills[cache_len] = jax.jit(
                lambda p, b: prefill_fn(p, b, cfg, cache_len=cache_len))
        return self._prefills[cache_len](params, batch)

    def generate(self, prompts: jax.Array, max_new_tokens: int,
                 temperature: float = 0.0, seed: int = 0) -> jax.Array:
        """prompts: (B, S) int32 -> generated (B, max_new_tokens) int32."""
        b = prompts.shape[0]
        batch = {"tokens": prompts}
        batch.update(synth_extra_inputs(self.cfg, b, self._extra_key))
        logits, state = self._prefill(self.params, batch,
                                      prompts.shape[1] + max_new_tokens)
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits, temperature, key)
        out.append(tok)
        for i in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            logits, state = self._decode(self.params, state, tok)
            tok = self._sample(logits, temperature, sub)
            out.append(tok)
        return jnp.stack(out, axis=1)

    @staticmethod
    def _sample(logits: jax.Array, temperature: float, key) -> jax.Array:
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)

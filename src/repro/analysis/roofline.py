"""Roofline terms from a compiled dry-run artifact (TPU v5e target).

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip)

``cost_analysis`` is measured on the SPMD-partitioned per-device module, so
terms are per-chip step latencies; the dominant term is the bottleneck.
MODEL_FLOPS (6·N·D train / 2·N·D prefill / 2·N_active·B decode) over
HLO_FLOPs measures how much compiled compute is "useful" (catches remat and
padding waste; can exceed 1 when XLA's flop counting under-counts fused
ops).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.analysis.hlo_cost import HloCost, analyze_hlo
from repro.configs.base import ModelConfig, ShapeConfig
from repro.utils import constants


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float               # per chip
    hlo_bytes: float               # per chip
    coll_bytes: float              # per chip
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_total: float       # whole job, analytic
    useful_flop_ratio: float       # model_flops/chips / hlo_flops
    bytes_per_device: Optional[float] = None
    coll_breakdown: Optional[Dict[str, int]] = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_seconds(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_flop_ratio": self.useful_flop_ratio,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "bytes_per_device": self.bytes_per_device,
        }


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic useful FLOPs per step for the whole job."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def build_report(arch: str, shape_cfg: ShapeConfig, mesh_name: str,
                 chips: int, cost: Dict, hlo_text: str,
                 cfg: ModelConfig, memory_stats: Optional[Dict] = None,
                 hlo_cost: Optional[HloCost] = None) -> RooflineReport:
    """Terms from the trip-count-aware HLO analysis (``analyze_hlo``);
    XLA's own cost_analysis (which counts while bodies once) is kept in the
    dry-run record for cross-checking."""
    hc = hlo_cost if hlo_cost is not None else analyze_hlo(hlo_text)
    flops = hc.flops
    bytes_accessed = hc.bytes
    mf = model_flops(cfg, shape_cfg)
    return RooflineReport(
        arch=arch, shape=shape_cfg.name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=bytes_accessed,
        coll_bytes=hc.coll_bytes,
        compute_s=flops / constants.PEAK_BF16_FLOPS,
        memory_s=bytes_accessed / constants.HBM_BANDWIDTH,
        collective_s=hc.coll_bytes / constants.ICI_LINK_BANDWIDTH,
        model_flops_total=mf,
        useful_flop_ratio=(mf / chips) / flops if flops else 0.0,
        bytes_per_device=(memory_stats or {}).get("bytes_per_device"),
        coll_breakdown=dict(hc.coll_by_type))

"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
program built on ``lax.scan`` (layer stacks, splices, attention blocks)
under-reports FLOPs/bytes by the trip count.  The compiled HLO text,
however, carries ``backend_config={"known_trip_count":{"n":...}}`` on every
counted loop — so we parse the module and recursively weight each
computation by its loop multiplicity:

- FLOPs: every ``dot`` = 2 x prod(result dims) x prod(lhs contracting dims)
  (convolutions are absent from these models).
- Bytes: per instruction, result + operand bytes — fusion regions count at
  the call site only (internal traffic stays in registers, matching how
  XLA's own analysis models fusions).
- Collective bytes: result-shape bytes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute, times loop multiplicity.

``conditional`` ops take the max-cost branch (we structure models to avoid
conditionals on the hot path — group-scans instead of lax.cond — so this
is a rarely-used conservative fallback).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id",
               "while", "conditional", "call", "iota"}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        b = _DTYPE_BYTES.get(dtype)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * b
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    defs: Dict[str, str]          # instr name -> result shape str


def _match_paren(s: str, start: int) -> int:
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return -1


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def _split_operands(s: str) -> List[str]:
    """Split an operand list on top-level commas only: operand entries may
    carry typed shapes with layouts (``f32[32,64]{1,0} %lhs``) whose braces
    and brackets contain commas of their own.  The operand NAME is the last
    whitespace-separated token of each entry."""
    parts: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in s:
        if ch in "{[(":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    names = []
    for p in parts:
        p = p.strip()
        if p:
            names.append(p.split()[-1].lstrip("%"))
    return names


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        if not raw:
            continue
        if not raw.startswith(" ") and raw.rstrip().endswith("{") \
                and not raw.startswith("HloModule"):
            m = _COMP_HEADER.match(raw.strip())
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if raw.strip().startswith("ENTRY"):
                    entry = cur.name
            continue
        if raw.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(raw)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        # result type: tuple or single shape (no spaces in single shapes)
        if rest.startswith("("):
            end = _match_paren(rest, 0)
            shape = rest[:end + 1]
            rest2 = rest[end + 1:].strip()
        else:
            sp = rest.find(" ")
            shape = rest[:sp]
            rest2 = rest[sp + 1:].strip()
        om = re.match(r"([\w\-]+)\(", rest2)
        if not om:
            continue
        op = om.group(1)
        ostart = om.end() - 1
        oend = _match_paren(rest2, ostart)
        operand_str = rest2[ostart + 1:oend]
        attrs = rest2[oend + 1:]
        operands = _split_operands(operand_str)
        instr = Instr(name, shape, op, operands, attrs)
        cur.instrs.append(instr)
        cur.defs[name] = shape
    return comps, entry


def _trip_count(attrs: str) -> int:
    m = re.search(r'known_trip_count[\\"]*:?\s*{\\?"n\\?":\\?"(\d+)', attrs)
    if m:
        return int(m.group(1))
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', attrs)
    return int(m.group(1)) if m else 1


def _called(attrs: str, key: str) -> List[str]:
    out = []
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    if m:
        out.append(m.group(1))
    m = re.search(key + r"=\{([^}]*)\}", attrs)
    if m:
        out.extend(x.strip().lstrip("%") for x in m.group(1).split(","))
    return out


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0            # XLA convention: operands + results
    bytes_lower: float = 0.0      # write-once/read-once (perfect fusion)
    coll_bytes: float = 0.0
    coll_by_type: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})

    def scaled(self, k: float) -> "HloCost":
        return HloCost(self.flops * k, self.bytes * k, self.bytes_lower * k,
                       self.coll_bytes * k,
                       {c: v * k for c, v in self.coll_by_type.items()})

    def add(self, o: "HloCost") -> None:
        self.flops += o.flops
        self.bytes += o.bytes
        self.bytes_lower += o.bytes_lower
        self.coll_bytes += o.coll_bytes
        for c, v in o.coll_by_type.items():
            self.coll_by_type[c] += v

    def as_dict(self) -> Dict:
        return {"flops": self.flops, "bytes": self.bytes,
                "bytes_lower": self.bytes_lower,
                "coll_bytes": self.coll_bytes,
                "coll_by_type": dict(self.coll_by_type)}


def _dot_flops(instr: Instr, comp: Computation) -> float:
    res = 1
    for d in _shape_dims(instr.shape):
        res *= d
    lhs_shape = comp.defs.get(instr.operands[0], "")
    lhs_dims = _shape_dims(lhs_shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    k = 1
    if m and lhs_dims:
        for di in m.group(1).split(","):
            if di:
                k *= lhs_dims[int(di)]
    return 2.0 * res * k


def _instr_bytes(instr: Instr, comp: Computation) -> float:
    if instr.op in _SKIP_BYTES:
        return 0.0
    if instr.op == "dynamic-update-slice":
        # in-place on TPU: write the update slice + read the update operand
        upd = instr.operands[1] if len(instr.operands) > 1 else None
        return 2.0 * _shape_bytes(comp.defs.get(upd, "")) if upd else 0.0
    if instr.op == "dynamic-slice":
        return 2.0 * float(_shape_bytes(instr.shape))
    total = float(_shape_bytes(instr.shape))
    for o in instr.operands:
        if o in comp.defs:
            total += _shape_bytes(comp.defs[o])
    return total


def _fusion_bytes(instr: Instr, comp: Computation,
                  comps: Dict[str, "Computation"],
                  called_names: List[str]) -> Tuple[float, float]:
    """(bytes, bytes_lower) for a fusion call site.

    In-place dynamic-update-slice fusions write only the update slice (TPU
    updates aliased buffers in place), so counting the full result shape
    would overstate traffic by the stacked-buffer factor.
    """
    root: Optional[Instr] = None
    for cn in called_names:
        fused = comps.get(cn)
        if fused and fused.instrs:
            root = fused.instrs[-1]
            break
    if root is not None and root.op == "dynamic-update-slice":
        fused = comps[called_names[0]]
        upd = root.operands[1] if len(root.operands) > 1 else None
        ub = _shape_bytes(fused.defs.get(upd, "")) if upd else 0
        if ub == 0:
            ub = _shape_bytes(root.shape)  # fallback
        return 2.0 * ub, 2.0 * ub
    if root is not None and root.op == "dynamic-slice":
        b = 2.0 * _shape_bytes(instr.shape)
        return b, b
    return (_instr_bytes(instr, comp), _instr_bytes_lower(instr, comp))


def _instr_bytes_lower(instr: Instr, comp: Computation) -> float:
    """Write-once lower bound: each buffer written once, read once (the
    traffic a perfectly-fused TPU lowering would see)."""
    if instr.op in _SKIP_BYTES:
        return 0.0
    if instr.op == "dynamic-update-slice":
        upd = instr.operands[1] if len(instr.operands) > 1 else None
        return 2.0 * _shape_bytes(comp.defs.get(upd, "")) if upd else 0.0
    return 2.0 * float(_shape_bytes(instr.shape))


def _comp_cost(name: str, comps: Dict[str, Computation],
               cache: Dict[str, HloCost], fusion_ctx: bool = False) -> HloCost:
    key = name + ("#f" if fusion_ctx else "")
    if key in cache:
        return cache[key]
    cost = HloCost()
    comp = comps.get(name)
    if comp is None:
        cache[key] = cost
        return cost
    for instr in comp.instrs:
        if instr.op == "dot":
            cost.flops += _dot_flops(instr, comp)
            if not fusion_ctx:
                cost.bytes += _instr_bytes(instr, comp)
                cost.bytes_lower += _instr_bytes(instr, comp)  # dot reads real
        elif instr.op == "while":
            trips = _trip_count(instr.attrs)
            for body in _called(instr.attrs, "body"):
                cost.add(_comp_cost(body, comps, cache).scaled(trips))
        elif instr.op == "conditional":
            branches = _called(instr.attrs, "branch_computations") \
                or (_called(instr.attrs, "true_computation")
                    + _called(instr.attrs, "false_computation"))
            if branches:
                worst = max((_comp_cost(b, comps, cache) for b in branches),
                            key=lambda c: c.flops + c.bytes)
                cost.add(worst)
        elif instr.op == "fusion":
            called_names = _called(instr.attrs, "calls")
            if not fusion_ctx:
                b, bl = _fusion_bytes(instr, comp, comps, called_names)
                cost.bytes += b
                cost.bytes_lower += bl
            for called in called_names:
                # only dots/collectives inside fusions (bytes at call site)
                cost.add(_comp_cost(called, comps, cache, fusion_ctx=True))
        elif instr.op == "call":
            for called in _called(instr.attrs, "to_apply"):
                cost.add(_comp_cost(called, comps, cache, fusion_ctx))
        else:
            base = instr.op[:-6] if instr.op.endswith("-start") else instr.op
            if base in COLLECTIVES and not instr.op.endswith("-done"):
                b = float(_shape_bytes(instr.shape))
                cost.coll_bytes += b
                cost.coll_by_type[base] += b
            if not fusion_ctx:
                cost.bytes += _instr_bytes(instr, comp)
                cost.bytes_lower += _instr_bytes_lower(instr, comp)
    cache[key] = cost
    return cost


def analyze_hlo(text: str) -> HloCost:
    """Full-module cost with loop trip multiplicities (per device)."""
    comps, entry = parse_module(text)
    if entry is None:
        return HloCost()
    return _comp_cost(entry, comps, {})

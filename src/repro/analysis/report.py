"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from results/.

    PYTHONPATH=src python -m repro.analysis.report > results/roofline.md
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

from repro.configs import ASSIGNED_ARCHS
from repro.configs.base import INPUT_SHAPES

RESULTS = "results/dryrun"


def load(arch: str, shape: str, mesh: str) -> Optional[Dict]:
    p = os.path.join(RESULTS, f"{arch}.{shape}.{mesh}.json")
    if not os.path.exists(p):
        return None
    return json.load(open(p))


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.3g}us"
    if x < 1:
        return f"{x*1e3:.3g}ms"
    return f"{x:.3g}s"


def one_liner(r: Dict) -> str:
    """What would move the dominant term down (per-pair §Roofline note)."""
    rf = r["roofline"]
    dom = rf["dominant"]
    shape = r["shape"]
    if dom == "memory":
        if "decode" in shape or shape == "long_500k":
            return ("memory-bound on cache reads: quantize the KV cache / "
                    "fuse the per-layer cache update (loop-carried copies "
                    "dominate)")
        return ("memory-bound on activations: larger fusion (Pallas attention "
                "kernel on TPU), higher splice factor to shrink live set, "
                "bf16 norm statistics")
    if dom == "collective":
        return ("collective-bound: reduce-scatter gradients instead of "
                "all-reduce, overlap FSDP all-gathers with compute, shard "
                "experts deeper")
    return ("compute-bound (near roofline): raise arithmetic intensity via "
            "longer per-slice microbatches; MXU-align head_dim")


def table() -> str:
    lines = [
        "| arch | shape | mesh | chips | compute | memory | collective | "
        "dominant | useful flops | bytes/device |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    notes = []
    for a in ASSIGNED_ARCHS:
        for s in INPUT_SHAPES:
            for m in ("single", "multi"):
                r = load(a, s.name, m)
                if r is None:
                    lines.append(f"| {a} | {s.name} | {m} | - | MISSING |"
                                 " | | | | |")
                    continue
                if r.get("status") == "skipped":
                    lines.append(f"| {a} | {s.name} | {m} | - | SKIPPED |"
                                 f" | | | | {r['reason'][:60]} |")
                    continue
                if r.get("status") != "ok":
                    lines.append(f"| {a} | {s.name} | {m} | - | "
                                 f"{r['status'].upper()} | | | | | |")
                    continue
                rf = r["roofline"]
                bpd = r["memory"]["bytes_per_device"] if r.get("memory") else 0
                swa = " (SWA variant)" if r.get("swa_variant") else ""
                lines.append(
                    f"| {a}{swa} | {s.name} | {m} | {r['chips']} | "
                    f"{fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} | "
                    f"{fmt_s(rf['collective_s'])} | **{rf['dominant']}** | "
                    f"{rf['useful_flop_ratio']:.3f} | {bpd/1e9:.2f} GB |")
                if m == "single":
                    notes.append(f"- **{a} x {s.name}**: {one_liner(r)}")
    return "\n".join(lines) + "\n\n### Per-pair bottleneck notes (single-pod)\n" \
        + "\n".join(notes)


def main() -> None:
    print(table())


if __name__ == "__main__":
    main()

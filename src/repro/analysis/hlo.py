"""HLO text analysis: collective-communication byte accounting.

``compiled.cost_analysis()`` has no collective term, so we parse the
optimized HLO and sum the RESULT-shape bytes of every collective op
(all-gather, all-reduce, reduce-scatter, all-to-all, collective-permute).
This approximates per-device bytes crossing the interconnect per op; ring
algorithms move ~2x for all-reduce — we report raw payload bytes and note
the convention in EXPERIMENTS.md.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'f32[2,4]' shape or a '(f32[..], s8[..])' tuple."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-type result bytes summed over all instructions."""
    out: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        shape_str, opname = m.group(1), m.group(2)
        for c in COLLECTIVES:
            # count only the -start (or plain) form to avoid double counting
            if opname == c or opname == f"{c}-start":
                out[c] += _shape_bytes(shape_str)
                out["count"] += 1
                break
    out["total"] = sum(out[c] for c in COLLECTIVES)
    return out


def op_histogram(hlo_text: str) -> Dict[str, int]:
    """Instruction-name histogram (fusion/remat forensics)."""
    hist: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*.+?\s+([\w\-]+)\(",
                     line)
        if m:
            hist[m.group(1)] = hist.get(m.group(1), 0) + 1
    return hist

"""Table 5 reproduction: end-to-end latency of migration and resizing.

Migration (m-to-m), scale-down (m-to-n) and scale-up (n-to-m) with the
barrier / dump / transfer / restore breakdown.  Dump/restore are measured;
transfer is modeled as deduped bytes over the blob-store link (the paper's
dominant term).
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.core.checkpoint import CheckpointStore
from repro.core.elastic import ElasticRuntime
from repro.core.migration import migrate

MODELS = ["olmo-1b", "mamba2-130m"]
MOVES = [(4, 4), (4, 2), (2, 4)]      # migrate / scale-down / scale-up


def run() -> List[Dict]:
    rows = []
    for arch in MODELS:
        cfg = get_smoke_config(arch)
        tcfg = TrainConfig(total_steps=20, warmup_steps=1)
        for m, n in MOVES:
            rt = ElasticRuntime(cfg, tcfg, 4, m, 8, 32)
            rt.run_steps(2)
            store = CheckpointStore()
            _, rep = migrate(rt, store, f"{arch}-{m}to{n}", n, cfg, tcfg,
                             8, 32)
            rows.append({
                "name": f"table5/{arch}/{m}to{n}",
                "us_per_call": rep.total_seconds * 1e6,
                "derived": (f"barrier_s={rep.barrier_seconds:.2f};"
                            f"dump_s={rep.dump_seconds:.2f};"
                            f"transfer_s={rep.transfer_seconds():.3f};"
                            f"restore_s={rep.restore_seconds:.2f};"
                            f"bytes_MB={rep.device_stored_bytes/1e6:.1f};"
                            f"work_conserving={rep.work_conserving}"),
            })
    return rows

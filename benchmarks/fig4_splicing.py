"""Figure 4 reproduction: overhead of N-way time-slicing (replica splicing).

On one device, an s-way spliced step does exactly the work of s fully
scaled-up per-device steps, so ``time(splice=s) / time(splice=1)`` is the
paper's overhead-beyond-ideal metric directly.  The squashing-disabled
comparison (the paper reports 18-163% blowups) comes from the buffer-level
splicing engine: redundant optimizer updates + swap traffic, converted to
time via host-link bandwidth.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.core.splicing import SplicedTrainer
from repro.models.frontend import synth_extra_inputs
from repro.training.state import init_train_state
from repro.training.step import build_train_step
from repro.utils import constants

MODELS = ["olmo-1b", "mamba2-130m", "paper-gpt2-1.8b"]
STEPS = 8


def _time(fn, *args) -> float:
    out = fn(*args)
    jax.block_until_ready(out[1]["loss"])
    t0 = time.perf_counter()
    for _ in range(STEPS):
        out = fn(*args)
        jax.block_until_ready(out[1]["loss"])
    return (time.perf_counter() - t0) / STEPS


def run() -> List[Dict]:
    rows = []
    key = jax.random.PRNGKey(0)
    for arch in MODELS:
        cfg = get_smoke_config(arch)
        tcfg = TrainConfig(total_steps=100, warmup_steps=1)
        state = init_train_state(cfg, tcfg, key)
        for splice in (2, 4):
            g = 4 * splice
            tokens = jax.random.randint(key, (g, 64), 0, cfg.vocab_size)
            batch = {"tokens": tokens, "labels": tokens}
            batch.update(synth_extra_inputs(cfg, g, key))
            t1 = _time(jax.jit(build_train_step(cfg, tcfg, splice=1)),
                       state, batch)
            ts = _time(jax.jit(build_train_step(cfg, tcfg, splice=splice)),
                       state, batch)
            overhead = (ts - t1) / t1 * 100
            rows.append({
                "name": f"fig4/{arch}/splice{splice}",
                "us_per_call": ts * 1e6,
                "derived": f"overhead_pct={overhead:.2f}",
            })

        # squashing on/off at the buffer level (swap bytes -> modeled time)
        for squash in (True, False):
            t = SplicedTrainer(n_ranks=4, dim=4096, seed=1, squash=squash)
            for _ in range(6):
                t.run_minibatch()
            m = t.device.metrics
            swap_s = (m.swapout_bytes + m.swapin_bytes) \
                / constants.HOST_DEVICE_BANDWIDTH
            rows.append({
                "name": f"fig4/{arch}/buffers/"
                        f"{'squash' if squash else 'nosquash'}",
                "us_per_call": swap_s / 6 * 1e6,
                "derived": (f"swap_MB={(m.swapout_bytes+m.swapin_bytes)/1e6:.3f};"
                            f"updates={m.executed_update_ops};"
                            f"elided={m.elided_swapins}"),
            })
    return rows

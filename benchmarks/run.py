"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

- table3_overhead  — device-proxy/barrier steady-state overhead (Table 3)
- table4_checkpoint — checkpoint sizes, S_G dedup + incremental (Table 4)
- fig4_splicing    — N-way time-slicing overhead, squash on/off (Figure 4)
- table5_migration — migration/resize latency breakdown (Table 5)
- sched_sim        — fleet utilization + SLA vs static baseline (§1.1)
- sched_scale      — simulator throughput on a 50k-job trace vs seed loop
- kernels_bench    — Pallas kernel micro-benchmarks

    PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = ["table3_overhead", "table4_checkpoint", "fig4_splicing",
           "table5_migration", "sched_sim", "sched_scale", "kernels_bench"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    mods = [m for m in MODULES if args.only is None or args.only in m]

    print("name,us_per_call,derived")
    failed = []
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row in mod.run():
                print(f"{row['name']},{row['us_per_call']:.1f},"
                      f"\"{row['derived']}\"", flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Fleet-scheduler benchmark: elastic (Singularity) vs static gang policy.

Quantifies the paper's design goals (§1.1): higher aggregate utilization /
no idling, SLA attainment per tier, preemption/migration/resize counts.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.scheduler.policy import ElasticPolicy, StaticGangPolicy
from repro.scheduler.simulator import (FleetSimulator, SimConfig, make_fleet,
                                       synth_workload)

SEEDS = (3, 7, 11)


def run() -> List[Dict]:
    rows = []
    for seed in SEEDS:
        for pol in (StaticGangPolicy(), ElasticPolicy()):
            sim = FleetSimulator(
                make_fleet(), synth_workload(120, 2048, seed=seed), pol,
                SimConfig(horizon_seconds=36 * 3600))
            t0 = time.perf_counter()
            res = sim.run()
            dt = time.perf_counter() - t0
            sla = ";".join(f"{t}={v:.2f}"
                           for t, v in res.sla_attainment.items())
            rows.append({
                "name": f"sched/{pol.name}/seed{seed}",
                "us_per_call": dt * 1e6,
                "derived": (f"util={res.utilization:.3f};{sla};"
                            f"done={res.completed}/{res.total_jobs};"
                            f"preempt={res.preemptions};"
                            f"migr={res.migrations};resize={res.resizes}"),
            })
    return rows

"""Fleet-scheduler benchmark: elastic (Singularity) vs static gang policy.

Quantifies the paper's design goals (§1.1): higher aggregate utilization /
no idling, SLA attainment per tier, preemption/migration/resize counts —
with the mechanisms' costs CHARGED (Table 5: tens of seconds each).  The
cost ablation row runs the same trace with free mechanisms, so the gap
between the two is exactly what preemption/migration/resize downtime
costs the elastic policy; the headline comparison stays honest because
elastic-with-costs must still beat static.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.scheduler.policy import ElasticPolicy, StaticGangPolicy
from repro.scheduler.simulator import (FleetSimulator, SimConfig, make_fleet,
                                       synth_workload)

SEEDS = (3, 7, 11)


def _row(name: str, pol, seed: int, cfg: SimConfig) -> Dict:
    sim = FleetSimulator(
        make_fleet(), synth_workload(120, 2048, seed=seed), pol, cfg)
    t0 = time.perf_counter()
    res = sim.run()
    dt = time.perf_counter() - t0
    sla = ";".join(f"{t}={v:.2f}" for t, v in res.sla_attainment.items())
    down = ";".join(f"down_{t}={v / 3600:.2f}h"
                    for t, v in res.downtime_by_tier.items())
    return {
        "name": name,
        "us_per_call": dt * 1e6,
        "derived": (f"util={res.utilization:.3f};{sla};"
                    f"done={res.completed}/{res.total_jobs};"
                    f"preempt={res.preemptions};"
                    f"migr={res.migrations};resize={res.resizes};"
                    f"restore={res.restores};"
                    f"dead_gpu_h={res.gpu_seconds_dead / 3600:.1f}"
                    + (";" + down if down else "")),
    }


def run() -> List[Dict]:
    rows = []
    for seed in SEEDS:
        for pol in (StaticGangPolicy(), ElasticPolicy()):
            rows.append(_row(f"sched/{pol.name}/seed{seed}", pol, seed,
                             SimConfig(horizon_seconds=36 * 3600)))
        # ablation: what the costs cost — same trace, free mechanisms
        rows.append(_row(f"sched/elastic_costfree/seed{seed}",
                         ElasticPolicy(), seed,
                         SimConfig(horizon_seconds=36 * 3600,
                                   migration_cost_seconds=0.0)))
    return rows


def main() -> int:
    """CLI entry: run the first-seed elastic vs static comparison and
    print each run's one-screen ``SimResult.summary()`` report."""
    for pol in (StaticGangPolicy(), ElasticPolicy()):
        sim = FleetSimulator(
            make_fleet(),
            synth_workload(120, 2048, seed=SEEDS[0]),
            pol,
            SimConfig(horizon_seconds=36 * 3600),
        )
        res = sim.run()
        print(f"== {pol.name} ==")
        print(res.summary())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

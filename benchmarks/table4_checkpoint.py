"""Table 4 reproduction: checkpoint sizes.

Compares user-level checkpointing (one replica's params+opt, pickled) with
Singularity's transparent checkpoint: S_G (content-deduped device state
across DP workers — independent of DP degree), first host dump, and the
incremental (temporal-dedup) dump.
"""
from __future__ import annotations

import pickle
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.core.checkpoint import CheckpointStore
from repro.core.elastic import ElasticRuntime
from repro.core.migration import checkpoint_job

MODELS = ["olmo-1b", "mamba2-130m", "granite-moe-3b-a800m"]


def run() -> List[Dict]:
    rows = []
    for arch in MODELS:
        cfg = get_smoke_config(arch)
        tcfg = TrainConfig(total_steps=20, warmup_steps=1)
        for workers in (4, 8):
            rt = ElasticRuntime(cfg, tcfg, workers, workers,
                                workers * 2, 32)
            rt.run_steps(1)
            user_bytes = len(pickle.dumps(jax.tree_util.tree_map(
                np.asarray, {"params": rt.state["params"],
                             "opt": rt.state["opt"]})))
            store = CheckpointStore()
            t0 = time.perf_counter()
            stats = checkpoint_job(rt, store, f"{arch}-{workers}")
            dt = time.perf_counter() - t0
            rt.run_steps(1)
            inc = checkpoint_job(rt, store, f"{arch}-{workers}")
            rows.append({
                "name": f"table4/{arch}/w{workers}",
                "us_per_call": dt * 1e6,
                "derived": (
                    f"user_MB={user_bytes/1e6:.1f};"
                    f"S_G_MB={stats.device_stored_bytes/1e6:.1f};"
                    f"logical_MB={stats.device_logical_bytes/1e6:.1f};"
                    f"host_first_KB={stats.host_stored_bytes/1e3:.1f};"
                    f"incr_MB={inc.device_stored_bytes/1e6:.1f}"),
            })
    return rows

"""Kernel micro-benchmarks (interpret mode on CPU — correctness-path cost;
TPU is the target for absolute numbers)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.kernels.checksum.ops import fingerprint
from repro.kernels.ssd_scan.ops import ssd_chunked_pallas
from repro.kernels.swa_attention.ops import swa_attention


def _time(fn, n=3) -> float:
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n


def run() -> List[Dict]:
    key = jax.random.PRNGKey(0)
    rows = []

    x = jax.random.normal(key, (1 << 20,), jnp.float32)
    t = _time(lambda: fingerprint(x))
    rows.append({"name": "kernel/checksum/4MB", "us_per_call": t * 1e6,
                 "derived": f"GBps={x.nbytes/t/1e9:.2f}"})

    q = jax.random.normal(key, (1, 512, 4, 64), jnp.float32)
    t = _time(lambda: swa_attention(q, q, q, window=128))
    rows.append({"name": "kernel/swa_attn/512x4x64_w128",
                 "us_per_call": t * 1e6, "derived": "interpret=True"})

    xs = jax.random.normal(key, (1, 256, 4, 32), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (1, 256, 4)))
    a = -jnp.ones((4,))
    b = jax.random.normal(key, (1, 256, 16))
    t = _time(lambda: ssd_chunked_pallas(xs, dt, a, b, b, 64)[0])
    rows.append({"name": "kernel/ssd_scan/256x4x32",
                 "us_per_call": t * 1e6, "derived": "interpret=True"})
    return rows

"""Simulator throughput at planet scale: vectorized vs seed event loop.

The refactored ``FleetSimulator`` advances progress with numpy over an
arrival-sorted active window; the seed loop rescans every job (arrived or
not, done or not) at every event with per-job Python SLA bookkeeping.
This benchmark runs a dense 50k-job trace through both:

- vectorized: the full trace, end to end (jobs/sec = jobs / wall).
- legacy:     the same trace truncated to a short horizon (it would take
              tens of minutes whole); its measured per-event cost is
              extrapolated over its full event count (arrivals + ticks),
              which UNDERSTATES the true cost — per-event work grows with
              the live-job count later in the trace — so the reported
              speedup is a floor.

    PYTHONPATH=src python -m benchmarks.run --only sched_scale
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.scheduler.policy import ElasticPolicy
from repro.scheduler.simulator import (FleetSimulator, SimConfig, make_fleet,
                                       synth_workload)

N_JOBS = 50_000
SEED = 5
MEAN_INTERARRIVAL = 1.2        # dense arrivals: 50k jobs over ~16.7h
WORK_SCALE = 0.018            # keeps the 65k-GPU fleet ~80% loaded (stable backlog)
HORIZON = 24 * 3600.0
LEGACY_HORIZON = 900.0         # seed loop gets a slice, then extrapolate


def _fleet():
    return make_fleet(n_regions=4, clusters_per_region=4,
                      gpus_per_cluster=4096)


def _trace():
    return synth_workload(N_JOBS, _fleet().total(), seed=SEED,
                          mean_interarrival=MEAN_INTERARRIVAL,
                          work_scale=WORK_SCALE)


def run() -> List[Dict]:
    rows = []

    # -- vectorized loop, full trace --------------------------------------
    sim = FleetSimulator(_fleet(), _trace(), ElasticPolicy(),
                         SimConfig(horizon_seconds=HORIZON))
    t0 = time.perf_counter()
    res = sim.run()
    vec_wall = time.perf_counter() - t0
    vec_jobs_per_sec = N_JOBS / vec_wall
    rows.append({
        "name": "sched_scale/vectorized_50k",
        "us_per_call": vec_wall * 1e6,
        "derived": (f"jobs_per_sec={vec_jobs_per_sec:.0f};"
                    f"events={sim.events_processed};"
                    f"done={res.completed}/{res.total_jobs};"
                    f"util={res.utilization:.3f}"),
    })

    # -- seed event loop, truncated + extrapolated ------------------------
    legacy = FleetSimulator(_fleet(), _trace(), ElasticPolicy(),
                            SimConfig(horizon_seconds=LEGACY_HORIZON,
                                      vectorized=False))
    t0 = time.perf_counter()
    legacy.run()
    leg_wall = time.perf_counter() - t0
    # full legacy event count: one event per arrival + one per tick
    leg_total_events = N_JOBS + int(HORIZON / legacy.cfg.tick_seconds)
    leg_full_wall = leg_wall / max(legacy.events_processed, 1) \
        * leg_total_events
    leg_jobs_per_sec = N_JOBS / leg_full_wall
    speedup = leg_full_wall / vec_wall
    rows.append({
        "name": "sched_scale/seed_loop_50k_extrapolated",
        "us_per_call": leg_full_wall * 1e6,
        "derived": (f"jobs_per_sec={leg_jobs_per_sec:.1f};"
                    f"measured_events={legacy.events_processed};"
                    f"measured_wall_s={leg_wall:.1f};"
                    f"speedup_vectorized={speedup:.0f}x"),
    })
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']:.1f},\"{row['derived']}\"")

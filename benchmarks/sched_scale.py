"""Scheduler throughput at planet scale: vectorized policy + event loop.

The cost-aware ``ElasticPolicy`` runs its admission, expansion and
placement passes as numpy lexsort/cumsum over job arrays, consults the
fleet-wide ``FleetSLAAccounts`` ledger in ONE batched call per tick, and
the simulator advances progress with numpy over an arrival-sorted active
window.  This benchmark drives dense synthetic traces end to end and
reports jobs/sec plus the decide-path seconds (time inside
``policy.decide``):

- ``vectorized``      — full trace, vectorized policy + vectorized loop,
                        batched SLA ledger, fleet JobTable (column-slice
                        decide path; ``gather_seconds`` reports the
                        per-tick state-gather share of the decide time).
- ``--no-job-table``  — same, but plain scalar Job objects: the decide
                        path rebuilds its per-job base arrays in Python
                        every tick (the pre-JobTable baseline).
- ``--no-sla-ledger`` — same, but per-job scalar SLA accounts (the PR 2
                        baseline): the decide path falls back to one
                        Python ``headroom`` query per guaranteed job.
- ``scalar_policy``   — same trace, the pure-Python reference-oracle
                        policy (full run; the gap versus vectorized
                        grows with backlog depth).
- ``seed_loop``       — the seed's O(jobs)-per-event simulator loop,
                        truncated to a short horizon and extrapolated
                        over the full event count (a floor: per-event
                        cost grows with the live-job count).

CLI (CI's bench-smoke job runs the 20k config; the 1M config is the
planet-scale acceptance run, with and without the ledger):

    PYTHONPATH=src python benchmarks/sched_scale.py \\
        --jobs 20000 --check-equivalence --failure-trace storm \\
        --serving --curves --json BENCH_sched.json
    PYTHONPATH=src python benchmarks/sched_scale.py \\
        --jobs 1000000 --regions 8 --clusters-per-region 8
    PYTHONPATH=src python benchmarks/sched_scale.py \\
        --jobs 1000000 --regions 8 --clusters-per-region 8 --no-sla-ledger

``--check-equivalence`` re-runs the whole trace under every other
{JobTable, plain jobs} x {vectorized, scalar reference} combination
(fairness aging enabled throughout, as in production), plus one run
with the batched placement core disabled (``node_batch=False`` — the
per-job loop oracle), and exits non-zero unless the aggregates and the
hash of the full decision sequence — node span plans included — match
the main run exactly — the CI gate that keeps the numpy passes honest.
When the ``--json`` target already exists (the committed
``BENCH_sched.json``), its ``decide_seconds`` is the budget: the run
also fails if the new decide time exceeds it by more than
``DECIDE_BUDGET_FACTOR`` (2x — host noise passes, a reintroduced
per-job gather does not).  The node-placement share of the decide path
is timed separately (``node_seconds``) and gated against its own
committed budget at the same factor, so a placement-core regression
cannot hide inside decide-time headroom left by the other passes.
Node-granular placement is on throughout (every policy decision
carries a span plan), so the decision digest, the decide-time budget
and the reported ``fragmentation_stranded_gpus`` /
``defrag_migrations`` fields all gate the node path.

With ``--check-equivalence`` (or ``--trace-out`` / ``--events-out``)
the trace is also replayed once with the full observability stack on
(``scheduler/telemetry.py``: structured event log, per-tick metrics,
decide-pass profiler), gating that telemetry (a) changes no decision
(identical digest + result signature), (b) costs at most
``TELEMETRY_OVERHEAD_FACTOR`` on the decide path, and (c) produces an
event log whose replay reproduces the run's mechanism aggregates
exactly.  ``--trace-out`` exports a Perfetto/chrome://tracing JSON of
that run; ``--events-out`` dumps the raw JSONL event log.

``--curves`` adds the concave-scaling row: the base trace is reshaped
into arrival waves (load oscillates so spare capacity is repeatedly
*contested* — on steady traces expansion happens for free at admission
and both arms rationally take every idle GPU), synthetic concave
throughput curves are attached (saturation knee at demand, wide
post-knee slope spread) and the curve-aware water-filling allocator is
A/B'd against the curve-blind arm (``curve_aware=False`` — the seed's
linear whole-prefix expansion) at equal capacity.  The run exits
non-zero unless curve-aware strictly realizes more goodput — nominal
work delivered (progress x ideal GPU-hours summed over the trace) per
busy GPU-hour occupied to deliver it — and, with
``--check-equivalence``, unless all four {JobTable, plain jobs} x
{vectorized, scalar} combinations replay the same decision digest with
curves on.

``--failure-trace storm`` adds a reliability row: a long-job variant of
the trace (``RELIABILITY_WORK_FACTOR`` x the work per job — node-accurate
blast radii mean short jobs rarely die mid-run, and periodic
checkpointing is a long-job mechanism) is replayed under a seeded
failure storm (sampled device/node/cluster failures plus a
whole-cluster outage at 6h, or a saved ``FailureTrace`` JSON), once
checkpoint-on-preempt-only and once with the Young–Daly
``CheckpointCadence``; the run exits non-zero unless cadence strictly
improves ``goodput_fraction`` (enforced for the named ``storm`` — on
sparse scenarios a correctly-calibrated cadence may rightly take zero
snapshots, so the gate is advisory there), and (with
``--check-equivalence``) unless the vectorized and scalar policies
produce identical decision digests under the storm.

Harness entry point (``python -m benchmarks.run --only sched_scale``)
keeps the historical 50k rows.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.scheduler.costs import CostModel
from repro.scheduler.curves import synth_curve_params
from repro.scheduler.policy import ElasticPolicy
from repro.scheduler.reliability import CheckpointCadence, FailureModel, FailureTrace
from repro.scheduler.serving import ServiceSpec, ServingConfig, TrafficConfig
from repro.scheduler.simulator import (
    FleetSimulator,
    SimConfig,
    make_fleet,
    synth_workload,
)
from repro.scheduler.telemetry import (
    FleetTelemetry,
    check_replay,
    export_chrome_trace,
)

SEED = 5
BASE_INTERARRIVAL = 1.2  # 50k-job baseline on a 65,536-GPU fleet
BASE_FLEET_GPUS = 4 * 4 * 4096
WORK_SCALE = 0.018  # holds the fleet ~80% loaded (stable backlog)
LEGACY_HORIZON = 900.0  # truncated slice for extrapolated baselines


def _fleet(regions=4, clusters_per_region=4, gpus_per_cluster=4096):
    return make_fleet(
        n_regions=regions,
        clusters_per_region=clusters_per_region,
        gpus_per_cluster=gpus_per_cluster,
    )


def _interarrival(fleet_gpus: int) -> float:
    # keep per-GPU arrival density at the 50k baseline so load stays at
    # the same operating point whatever the trace/fleet size
    return BASE_INTERARRIVAL * BASE_FLEET_GPUS / fleet_gpus


def _trace(
    n_jobs: int, fleet_gpus: int, work_factor: float = 1.0, curves: bool = False
):
    return synth_workload(
        n_jobs,
        fleet_gpus,
        seed=SEED,
        mean_interarrival=_interarrival(fleet_gpus),
        work_scale=WORK_SCALE * work_factor,
        curves=curves,
    )


def _horizon(n_jobs: int, fleet_gpus: int) -> float:
    span = n_jobs * _interarrival(fleet_gpus)
    return max(24 * 3600.0, 1.25 * span + 12 * 3600.0)


class _TimedPolicy:
    """Wraps a policy, accumulating wall time spent inside ``decide`` (the
    decide-path metric) and — when ``digest`` is on — folding every
    Decision into a running hash, so the equivalence gate compares the
    full decision sequences, not just end-of-run aggregates that could
    mask compensating divergences."""

    def __init__(self, inner, digest: bool = False):
        self.inner = inner
        self.name = inner.name
        self.decide_seconds = 0.0
        self._digest = hashlib.sha256() if digest else None

    @property
    def gather_seconds(self) -> float:
        """Seconds of ``decide_seconds`` spent gathering per-job state
        into arrays (the JobTable column slices, or the per-job
        base-array build they replace)."""
        return getattr(self.inner, "gather_seconds", 0.0)

    @property
    def node_seconds(self) -> float:
        """Seconds of ``decide_seconds`` spent inside the node-granular
        placement pass (``_place_nodes``): the batched segment-reduce
        core, or the per-job loop oracle when ``node_batch=False``."""
        return getattr(self.inner, "node_seconds", 0.0)

    def bind_costs(self, cost_model, interval_hint) -> None:
        self.inner.bind_costs(cost_model, interval_hint)

    def bind_telemetry(self, telemetry) -> None:
        """Forward the simulator's telemetry bundle to the wrapped
        policy so its decide-pass spans land in the exported trace;
        this wrapper's own ``decide_seconds`` stays an independent
        outside-in measurement (it excludes digest hashing)."""
        if hasattr(self.inner, "bind_telemetry"):
            self.inner.bind_telemetry(telemetry)

    def decide(self, now, jobs, fleet):
        t0 = time.perf_counter()
        decision = self.inner.decide(now, jobs, fleet)
        self.decide_seconds += time.perf_counter() - t0
        if self._digest is not None:
            spans = None
            if decision.node_plan is not None:
                _, released, assigns = decision.node_plan
                spans = (
                    sorted(int(r) for r in released),
                    [
                        (int(r), [int(n) for n in ns], [int(g) for g in gs])
                        for r, ns, gs in assigns
                    ],
                )
            payload = repr(
                (
                    sorted(decision.alloc.items()),
                    decision.preemptions,
                    decision.migrations,
                    spans,
                )
            )
            self._digest.update(payload.encode())
        return decision

    def digest(self) -> str:
        if self._digest is None:
            raise ValueError("digest disabled: construct with digest=True")
        return self._digest.hexdigest()


def _result_signature(res) -> Dict:
    return {
        "utilization": res.utilization,
        "completed": res.completed,
        "preemptions": res.preemptions,
        "migrations": res.migrations,
        "migrations_cross_region": res.migrations_cross_region,
        "resizes": res.resizes,
        "restores": res.restores,
        "gpu_seconds_dead": res.gpu_seconds_dead,
        "queue_seconds": res.queue_seconds,
        "fragmentation_stranded_gpus": res.fragmentation_stranded_gpus,
        "defrag_migrations": res.defrag_migrations,
    }


def _failure_trace(spec: str, fleet, horizon: float) -> FailureTrace:
    """Resolve ``--failure-trace``: a named seeded scenario (named
    scenarios win over same-named files), or a saved FailureTrace JSON
    path.  ``storm`` = sampled device/node/cluster failures plus a
    whole-cluster outage at 6h; ``outage`` = the outage alone."""
    first = fleet.clusters()[0].id
    outage = FailureTrace.cluster_outage(
        first, at=6 * 3600.0, repair_seconds=8 * 3600.0
    )
    if spec == "outage":
        return outage
    if spec == "storm":
        return FailureTrace.merge(_storm_model().sample(fleet, horizon), outage)
    if os.path.exists(spec):
        return FailureTrace.load(spec)
    raise SystemExit(f"unknown failure trace/scenario: {spec!r}")


def _storm_model() -> FailureModel:
    """The seeded storm's rates — also what the cadence is told, so the
    Young–Daly intervals reflect the failure density actually replayed."""
    return FailureModel(
        device_mtbf_seconds=60 * 24 * 3600.0,
        node_mtbf_seconds=90 * 24 * 3600.0,
        cluster_mtbf_seconds=180 * 24 * 3600.0,
        seed=SEED,
    )


def _cadence_for(spec: str, fleet, horizon: float) -> CheckpointCadence:
    """Calibrate the cadence to the scenario actually replayed: the
    storm uses its generating model's rates; any other trace gets an
    empirical MTTI (GPU-time at risk per affected GPU) so Young–Daly
    intervals match the replayed failure density, not the storm's."""
    if spec == "storm":
        return CheckpointCadence(
            cost_model=CostModel(), failure_model=_storm_model()
        )
    trace = _failure_trace(spec, fleet, horizon)
    cluster_gpus = {c.id: c.total_gpus for c in fleet.clusters()}
    region_gpus = {r.id: r.total() for r in fleet.regions}
    affected = 0
    for e in trace:
        if e.gpus > 0:
            affected += e.gpus
        elif e.level == "region":
            affected += region_gpus.get(e.domain, 0)
        else:
            affected += cluster_gpus.get(e.domain, 0)
    mtti = horizon * fleet.total() / max(affected, 1)
    return CheckpointCadence(cost_model=CostModel(), mtti_seconds=mtti)


def bench_failures(
    n_jobs: int,
    regions: int,
    clusters_per_region: int,
    gpus_per_cluster: int,
    check_equivalence: bool,
    spec: str,
) -> Dict:
    """Reliability row: replay a seeded failure scenario on a long-job
    variant of the trace (``RELIABILITY_WORK_FACTOR`` x work per job:
    with node-accurate blast radii a short job rarely meets a failure,
    and periodic checkpointing is a long-job mechanism), with and
    without the Young–Daly checkpoint cadence, gating (a) the
    vectorized==scalar, JobTable==plain-job and batched==loop-oracle
    decision digests under the storm and (b) the strict goodput win
    cadence must deliver over checkpoint-on-preempt-only."""

    def _run(policy, cadence, job_table: bool = True, work_factor: float = 1.0):
        fleet = _fleet(regions, clusters_per_region, gpus_per_cluster)
        horizon = _horizon(n_jobs, fleet.total())
        sim = FleetSimulator(
            fleet,
            _trace(n_jobs, fleet.total(), work_factor),
            policy,
            SimConfig(
                horizon_seconds=horizon,
                cost_model=CostModel(),
                failures=_failure_trace(spec, fleet, horizon),
                cadence=cadence,
                job_table=job_table,
            ),
        )
        res = sim.run()
        return res, fleet

    ref_fleet = _fleet(regions, clusters_per_region, gpus_per_cluster)
    cadence = _cadence_for(spec, ref_fleet, _horizon(n_jobs, ref_fleet.total()))
    base, fleet = _run(
        _TimedPolicy(ElasticPolicy()), None, work_factor=RELIABILITY_WORK_FACTOR
    )
    cad_res, _ = _run(
        _TimedPolicy(ElasticPolicy()), cadence, work_factor=RELIABILITY_WORK_FACTOR
    )
    out = {
        "scenario": spec,
        "failure_events": base.failure_events,
        "job_failures": base.job_failures,
        "lost_work_gpu_hours": base.lost_work_gpu_seconds / 3600.0,
        "goodput_fraction": base.goodput_fraction,
        "restarts_by_cause": base.restarts_by_cause,
        "ettr_by_tier": base.ettr_by_tier,
        "cadence_snapshots": cad_res.snapshots,
        "cadence_lost_work_gpu_hours": cad_res.lost_work_gpu_seconds / 3600.0,
        "cadence_goodput_fraction": cad_res.goodput_fraction,
        "goodput_gain": cad_res.goodput_fraction - base.goodput_fraction,
        "cadence_improves_goodput": (
            cad_res.goodput_fraction > base.goodput_fraction
        ),
        # strict-improvement is the CI acceptance gate for the seeded
        # storm; on sparse scenarios a correctly-calibrated cadence may
        # rightly take zero snapshots, so the gate is advisory there
        "goodput_gate": "enforced" if spec == "storm" else "advisory",
        "equivalence": "skipped",
    }
    print(
        f"failures[{spec}]: events={base.failure_events} "
        f"killed={base.job_failures} "
        f"lost={out['lost_work_gpu_hours']:.0f} gpu-h "
        f"goodput={base.goodput_fraction:.4f} -> "
        f"{cad_res.goodput_fraction:.4f} with cadence "
        f"({cad_res.snapshots} snapshots, "
        f"lost {out['cadence_lost_work_gpu_hours']:.0f} gpu-h)"
    )
    if check_equivalence:
        # the digest gate replays the storm on the BASE trace: it checks
        # that every representation x policy-path combination walks the
        # same node-granular decision sequence under failures (the
        # long-job goodput rows above would make the scalar reference
        # run for minutes against a deep backlog for no extra coverage)
        vec = _TimedPolicy(ElasticPolicy(), digest=True)
        vec_res, _ = _run(vec, None)
        ref = _TimedPolicy(ElasticPolicy(vectorized=False), digest=True)
        ref_res, _ = _run(ref, None)
        plain = _TimedPolicy(ElasticPolicy(), digest=True)
        plain_res, _ = _run(plain, None, job_table=False)
        loop = _TimedPolicy(ElasticPolicy(node_batch=False), digest=True)
        loop_res, _ = _run(loop, None)
        same = (
            vec.digest() == ref.digest()
            and vec.digest() == plain.digest()
            and vec.digest() == loop.digest()
            and _result_signature(vec_res) == _result_signature(ref_res)
            and _result_signature(vec_res) == _result_signature(plain_res)
            and _result_signature(vec_res) == _result_signature(loop_res)
            and vec_res.lost_work_gpu_seconds == ref_res.lost_work_gpu_seconds
            and vec_res.lost_work_gpu_seconds == plain_res.lost_work_gpu_seconds
            and vec_res.lost_work_gpu_seconds == loop_res.lost_work_gpu_seconds
        )
        out["decision_digest"] = vec.digest()
        out["equivalence"] = "ok" if same else "FAILED"
        print(
            f"failure-storm equivalence (scalar policy + plain jobs + "
            f"placement loop oracle): "
            f"{out['equivalence']} (digest {vec.digest()[:12]}...)"
        )
    return out


# a regression must exceed the committed decide_seconds by this factor
# before the gate trips: CI hosts vary run to run, and the gate should
# catch a reintroduced per-job gather (a multi-x regression), not noise
DECIDE_BUDGET_FACTOR = 2.0

# telemetry must be near-free on the decide path: the telemetry-on
# re-run's decide time may exceed the telemetry-off run's by at most
# this factor (plus an absolute slack floor — at bench-smoke scale the
# whole decide path is sub-second and host noise dwarfs any ratio)
TELEMETRY_OVERHEAD_FACTOR = 1.05
TELEMETRY_OVERHEAD_SLACK_SECONDS = 0.5

# -- serving row ----------------------------------------------------------
# the mixed-workload acceptance bar: fraction of per-service scheduler
# windows meeting p99 latency via sufficient warm replicas
SERVING_SLO_GATE = 0.99
SERVING_TRAFFIC_SEED = 9
# the serving row replaces the bursty base trace with one spanning the
# full 24h serving day at this oversubscription factor (x the ~80% base
# operating point): the training backlog then persists all day, so GPUs
# loaned off-peak are visible as best-effort throughput, not absorbed by
# an already-drained queue
SERVING_HORIZON = 24 * 3600.0
SERVING_TRAINING_LOAD = 1.4
# gate-failure artifact: the full qps trace plus per-service attainment,
# so a CI failure is debuggable without re-running the bench
SERVING_TRACE_JSON = "SERVING_trace.json"
# (service, arch, slo_ms, diurnal peak qps): operating points derived
# from the real model configs via ReplicaProfile.from_config, with peaks
# sized so the reserved quota lands at ~13% of the 65,536-GPU bench fleet
SERVING_MIX = [
    ("chat", "yi-9b", 40.0, 44000.0),
    ("code", "granite-8b", 40.0, 24000.0),
    ("agent", "qwen3-moe-30b-a3b", 50.0, 36000.0),
    ("embed", "olmo-1b", 30.0, 72000.0),
]


def _serving_services() -> List[ServiceSpec]:
    from repro.configs import get_config
    from repro.serving.engine import ReplicaProfile

    return [
        ServiceSpec(
            name,
            ReplicaProfile.from_config(get_config(arch), slo_ms=slo),
            peak_qps=peak,
        )
        for name, arch, slo, peak in SERVING_MIX
    ]


def _serving_signature(res) -> Dict:
    return {
        "serving_windows": res.serving_windows,
        "serving_violations": res.serving_violations,
        "serving_reclaims": res.serving_reclaims,
        "serving_loaned_gpu_hours": round(res.serving_loaned_gpu_hours, 6),
    }


def bench_serving(
    n_jobs: int,
    regions: int,
    clusters_per_region: int,
    gpus_per_cluster: int,
    check_equivalence: bool,
) -> Dict:
    """Serving row: mix a 24h diurnal+spike inference trace (four real
    model operating points) into the training trace and gate the elastic
    serving tier's acceptance criteria:

    * p99 SLO attainment >= ``SERVING_SLO_GATE`` over all scheduler
      windows, with the predictive autoscaler never behind the reactive
      baseline;
    * every reclaim (spike retarget clawing back loaned GPUs) lands
      within the CostModel-charged deadline;
    * loaning is real: loaned GPU-hours > 0 AND best-effort training
      banks more busy GPU-hours than the no-loaning baseline (idle
      reserved capacity converted to training throughput, not just
      moved);
    * (with ``--check-equivalence``) all four {JobTable, plain jobs} x
      {vectorized, scalar} combinations — plus the per-job placement
      loop oracle (``node_batch=False``) — replay the same decision
      digest with services active.

    On any gate failure the full qps trace and per-service attainment
    are dumped to ``SERVING_trace.json`` for offline debugging.
    """

    def _run(
        autoscaler: str, loaning: bool, vec=True, jt=True, nb=True, digest=False
    ):
        fleet = _fleet(regions, clusters_per_region, gpus_per_cluster)
        inter = SERVING_HORIZON / n_jobs
        work = (
            WORK_SCALE * (inter / _interarrival(fleet.total())) * SERVING_TRAINING_LOAD
        )
        jobs = synth_workload(
            n_jobs,
            fleet.total(),
            seed=SEED,
            mean_interarrival=inter,
            work_scale=work,
        )
        scfg = ServingConfig(
            services=_serving_services(),
            traffic=TrafficConfig(seed=SERVING_TRAFFIC_SEED),
            autoscaler=autoscaler,
            loaning=loaning,
        )
        policy = _TimedPolicy(
            ElasticPolicy(vectorized=vec, node_batch=nb), digest=digest
        )
        sim = FleetSimulator(
            fleet,
            jobs,
            policy,
            SimConfig(horizon_seconds=SERVING_HORIZON, job_table=jt, serving=scfg),
        )
        res = sim.run()
        return res, sim, policy

    t0 = time.perf_counter()
    res, sim, policy = _run("predictive", loaning=True)
    react, _, _ = _run("reactive", loaning=True)
    noloan, sim_n, _ = _run("predictive", loaning=False)
    wall = time.perf_counter() - t0
    training = sim.busy_gpu_seconds / 3600.0 - res.serving_gpu_hours
    training_noloan = sim_n.busy_gpu_seconds / 3600.0 - noloan.serving_gpu_hours
    out = {
        "services": [
            {"name": n, "arch": a, "slo_ms": s, "peak_qps": p}
            for n, a, s, p in SERVING_MIX
        ],
        "traffic_seed": SERVING_TRAFFIC_SEED,
        "wall_seconds": wall,
        "reserved_gpus": res.serving_reserved_gpus,
        "slo_attainment": res.serving_slo_attainment,
        "slo_gate_threshold": SERVING_SLO_GATE,
        "attainment_by_service": res.serving_attainment_by_service,
        "windows": res.serving_windows,
        "violations": res.serving_violations,
        "reclaims": res.serving_reclaims,
        "reclaim_mean_seconds": res.serving_reclaim_mean_seconds,
        "reclaim_max_seconds": res.serving_reclaim_max_seconds,
        "reclaim_deadline_seconds": res.serving_reclaim_deadline_seconds,
        "reclaims_over_deadline": res.serving_reclaims_over_deadline,
        "loaned_gpu_hours": res.serving_loaned_gpu_hours,
        "serving_gpu_hours": res.serving_gpu_hours,
        "training_busy_gpu_hours": training,
        "reactive_slo_attainment": react.serving_slo_attainment,
        "noloan_slo_attainment": noloan.serving_slo_attainment,
        "noloan_training_busy_gpu_hours": training_noloan,
        "loaning_training_gain_gpu_hours": training - training_noloan,
        "completed_jobs": res.completed,
        "noloan_completed_jobs": noloan.completed,
        "equivalence": "skipped",
    }
    gates = {
        "slo": res.serving_slo_attainment >= SERVING_SLO_GATE,
        "reclaim": res.serving_reclaims_over_deadline == 0,
        "predictive_vs_reactive": (
            res.serving_slo_attainment >= react.serving_slo_attainment
        ),
        "loaning": (
            res.serving_loaned_gpu_hours > 0.0 and training > training_noloan
        ),
    }
    print(
        f"serving: {len(SERVING_MIX)} services reserved={out['reserved_gpus']} "
        f"gpus, slo={res.serving_slo_attainment:.4f} "
        f"({res.serving_violations}/{res.serving_windows} windows violated), "
        f"reclaims={res.serving_reclaims} "
        f"max={res.serving_reclaim_max_seconds:.0f}s "
        f"(deadline {res.serving_reclaim_deadline_seconds:.0f}s), "
        f"loaned={res.serving_loaned_gpu_hours:.0f} gpu-h, "
        f"training +{out['loaning_training_gain_gpu_hours']:.0f} gpu-h vs "
        f"no-loaning, reactive slo={react.serving_slo_attainment:.4f}"
    )
    if check_equivalence:
        sig = _serving_signature(res) | _result_signature(res)
        main_digest = None
        out["equivalence"] = "ok"
        for vec, jt, nb in [
            (True, True, True),
            (True, False, True),
            (False, True, True),
            (False, False, True),
            (True, True, False),
        ]:
            other_res, _, other = _run(
                "predictive", loaning=True, vec=vec, jt=jt, nb=nb, digest=True
            )
            if main_digest is None:
                main_digest = other.digest()
                out["decision_digest"] = main_digest
            osig = _serving_signature(other_res) | _result_signature(other_res)
            if other.digest() != main_digest or osig != sig:
                out["equivalence"] = "FAILED"
                print(
                    f"SERVING EQUIVALENCE FAILURE: "
                    f"{'vectorized' if vec else 'scalar'}+"
                    f"{'table' if jt else 'plain'}"
                    f"{'' if nb else '+loop-oracle'} diverged:\n"
                    f"  main:  digest={main_digest} {sig}\n"
                    f"  other: digest={other.digest()} {osig}",
                    file=sys.stderr,
                )
        if out["equivalence"] == "ok":
            print(
                "serving equivalence: all four policy/representation "
                "combinations and the placement loop oracle match "
                f"(digest {main_digest[:12]}...)"
            )
    failed = [k for k, ok in gates.items() if not ok]
    out["gates"] = {k: ("ok" if ok else "FAILED") for k, ok in gates.items()}
    if failed or out["equivalence"] == "FAILED":
        trace = sim.serving.trace
        artifact = {
            "failed_gates": failed,
            "summary": {k: v for k, v in out.items() if k != "services"},
            "sample_seconds": trace.sample_seconds,
            "qps": {
                name: [round(float(q), 3) for q in trace.qps[i]]
                for i, name in enumerate(sim.serving.table.names)
            },
        }
        with open(SERVING_TRACE_JSON, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(
            f"SERVING GATE FAILURE: {failed or ['equivalence']} — trace "
            f"dumped to {SERVING_TRACE_JSON}",
            file=sys.stderr,
        )
    return out

# -- curves row -----------------------------------------------------------
# the concave-scaling row reshapes the base trace into arrival waves:
# each window's arrivals are compressed into its first
# CURVES_WAVE_DUTY fraction, so load oscillates every window — the
# back half of each wave frees capacity by completions while the next
# wave's backlog was admitted un-expanded, which is the regime where
# the allocators actually differ (steady traces expand jobs for free
# at admission, where both arms rationally take every spare GPU)
CURVES_WAVE_SECONDS = 3 * 3600.0
CURVES_WAVE_DUTY = 0.5
# the row's curve family: saturation knee AT demand (every elastic GPU
# sits on the saturated segment — also exact under the splice-ladder
# snap) with a wide slope spread, so the water-fill's marginal-utility
# ordering is what the A/B measures
CURVES_KNEE_RANGE = (1.0, 1.0)
CURVES_SAT_RANGE = (0.02, 0.95)


def bench_curves(
    n_jobs: int,
    regions: int,
    clusters_per_region: int,
    gpus_per_cluster: int,
    check_equivalence: bool,
) -> Dict:
    """Concave-scaling row: replay the base trace reshaped into arrival
    waves with synthetic concave throughput curves attached (saturation
    knee at demand, post-knee slope spread over ``CURVES_SAT_RANGE``)
    and A/B the curve-aware water-filling allocator against the
    curve-blind arm (``curve_aware=False``: the seed's linear
    whole-prefix expansion) at equal capacity.

    The gate is strict: curve-aware must realize MORE goodput per
    occupied GPU-hour — nominal work delivered (sum over jobs of
    progress x ideal GPU-hours; the simulator advances progress over the
    same curves in both arms) divided by the busy GPU-hours the arm
    occupied to deliver it.  The linear arm parks GPUs on post-knee
    tails where a GPU-hour buys only ``sat_slope`` of a nominal one;
    curve-aware aims spare at slope-1.0 pre-knee chunks first and
    refuses expansions whose marginal slope cannot pay the
    CostModel-charged resize downtime, so at equal capacity it delivers
    the trace's work while occupying strictly fewer GPU-hours (or
    strictly more work when the backlog is capacity-bound).

    With ``--check-equivalence`` all four {JobTable, plain jobs} x
    {vectorized, scalar reference} combinations must also replay the
    same decision digest with curves on (the water-filling pass is the
    one place the two policy paths diverge structurally, so the flat
    base-trace digest alone no longer pins it)."""

    def _curved_trace(fleet_gpus: int):
        jobs = _trace(n_jobs, fleet_gpus)
        wave, duty = CURVES_WAVE_SECONDS, CURVES_WAVE_DUTY
        for j in jobs:
            w = j.arrival // wave
            j.arrival = w * wave + (j.arrival % wave) * duty
        crng = np.random.Generator(np.random.Philox(SEED ^ 0xC0FFEE))
        demands = np.fromiter(
            (j.demand_gpus for j in jobs), np.int64, len(jobs)
        )
        knee, sat = synth_curve_params(
            crng,
            demands,
            knee_range=CURVES_KNEE_RANGE,
            sat_range=CURVES_SAT_RANGE,
        )
        for j, k, s in zip(jobs, knee, sat):
            j.knee_gpus = int(k)
            j.sat_slope = float(s)
        return jobs

    def _run(curve_aware=True, vec=True, jt=True, digest=False):
        fleet = _fleet(regions, clusters_per_region, gpus_per_cluster)
        horizon = _horizon(n_jobs, fleet.total())
        policy = _TimedPolicy(
            ElasticPolicy(vectorized=vec, curve_aware=curve_aware),
            digest=digest,
        )
        sim = FleetSimulator(
            fleet,
            _curved_trace(fleet.total()),
            policy,
            SimConfig(
                horizon_seconds=horizon,
                cost_model=CostModel(),
                job_table=jt,
            ),
        )
        res = sim.run()
        # nominal GPU-hours of useful work delivered: progress advances
        # over the concave curve, so a GPU parked past a knee inflates
        # busy_gpu_seconds without showing up here — realized goodput is
        # this divided by the busy GPU-hours occupied to deliver it
        work = sum(j.progress * j.gpu_hours for j in sim.jobs.values())
        busy = sim.busy_gpu_seconds / 3600.0
        return res, work, work / max(busy, 1e-9), policy

    t0 = time.perf_counter()
    res_a, work_a, goodput_a, pol_a = _run(digest=check_equivalence)
    res_l, work_l, goodput_l, _ = _run(curve_aware=False)
    wall = time.perf_counter() - t0
    out = {
        "wall_seconds": wall,
        "work_gpu_hours_curve_aware": work_a,
        "work_gpu_hours_linear": work_l,
        "goodput_per_busy_gpu_hour_curve_aware": goodput_a,
        "goodput_per_busy_gpu_hour_linear": goodput_l,
        "goodput_gain": goodput_a - goodput_l,
        "completed_curve_aware": res_a.completed,
        "completed_linear": res_l.completed,
        "utilization_curve_aware": res_a.utilization,
        "utilization_linear": res_l.utilization,
        "resizes_curve_aware": res_a.resizes,
        "resizes_linear": res_l.resizes,
        "goodput_gate": "ok" if goodput_a > goodput_l else "FAILED",
        "equivalence": "skipped",
    }
    print(
        f"curves: goodput={goodput_a:.4f} work-gpu-h per busy-gpu-h "
        f"curve-aware vs {goodput_l:.4f} linear "
        f"(work {work_a:.0f} vs {work_l:.0f} gpu-h, "
        f"done={res_a.completed} vs {res_l.completed}, "
        f"util={res_a.utilization:.3f} vs {res_l.utilization:.3f}, "
        f"resizes={res_a.resizes} vs {res_l.resizes}) "
        f"— goodput gate {out['goodput_gate']}"
    )
    if out["goodput_gate"] == "FAILED":
        print(
            f"CURVES GOODPUT FAILURE: curve-aware allocation realized "
            f"{goodput_a:.6f} work-gpu-h per busy-gpu-h <= linear's "
            f"{goodput_l:.6f} on the curved trace",
            file=sys.stderr,
        )
    if check_equivalence:
        sig = _result_signature(res_a)
        out["decision_digest"] = pol_a.digest()
        out["equivalence"] = "ok"
        for vec, jt in [(True, False), (False, True), (False, False)]:
            other_res, _, _, other = _run(vec=vec, jt=jt, digest=True)
            label = (
                f"{'vectorized' if vec else 'scalar'}+"
                f"{'table' if jt else 'plain'}"
            )
            osig = _result_signature(other_res)
            if other.digest() != pol_a.digest() or osig != sig:
                out["equivalence"] = "FAILED"
                print(
                    f"CURVES EQUIVALENCE FAILURE: {label} diverged on "
                    f"the curved trace:\n"
                    f"  main:  digest={pol_a.digest()} {sig}\n"
                    f"  other: digest={other.digest()} {osig}",
                    file=sys.stderr,
                )
        if out["equivalence"] == "ok":
            print(
                "curves equivalence: all four policy/representation "
                "combinations replay the water-filling decisions "
                f"identically (digest {pol_a.digest()[:12]}...)"
            )
    return out


# the reliability row multiplies per-job work by this much: periodic
# checkpointing only pays off for jobs long enough to meet a failure,
# and node-accurate blast radii make the base trace's short jobs
# nearly failure-free
RELIABILITY_WORK_FACTOR = 20.0


def bench(
    n_jobs: int,
    regions: int,
    clusters_per_region: int,
    gpus_per_cluster: int,
    check_equivalence: bool,
    json_path: Optional[str],
    sla_ledger: bool = True,
    failure_spec: Optional[str] = None,
    job_table: bool = True,
    serving: bool = False,
    curves: bool = False,
    trace_out: Optional[str] = None,
    events_out: Optional[str] = None,
) -> Dict:
    # the committed BENCH_sched.json (if the target already exists) is
    # the decide-time budget the new run is gated against; the node-pass
    # share carries its own budget so a placement-core regression cannot
    # hide inside decide-time headroom left by the other passes
    budget = None
    node_budget = None
    if json_path and os.path.exists(json_path):
        try:
            with open(json_path) as f:
                committed = json.load(f)
            if committed.get("jobs") == n_jobs:
                budget = float(committed["decide_seconds"])
                if "node_seconds" in committed:
                    node_budget = float(committed["node_seconds"])
        except (ValueError, KeyError, OSError):
            budget = None
            node_budget = None
    fleet = _fleet(regions, clusters_per_region, gpus_per_cluster)
    horizon = _horizon(n_jobs, fleet.total())
    policy = _TimedPolicy(ElasticPolicy(), digest=check_equivalence)
    sim = FleetSimulator(
        fleet,
        _trace(n_jobs, fleet.total()),
        policy,
        SimConfig(horizon_seconds=horizon, sla_ledger=sla_ledger, job_table=job_table),
    )
    t0 = time.perf_counter()
    res = sim.run()
    wall = time.perf_counter() - t0
    out = {
        "jobs": n_jobs,
        "fleet_gpus": fleet.total(),
        "wall_seconds": wall,
        "jobs_per_sec": n_jobs / wall,
        "decide_seconds": policy.decide_seconds,
        "gather_seconds": policy.gather_seconds,
        "node_seconds": policy.node_seconds,
        "sla_ledger": sla_ledger,
        "job_table": job_table,
        "events": sim.events_processed,
        "equivalence": "skipped",
        "decide_gate": "skipped",
        "node_gate": "skipped",
        "telemetry_gate": "skipped",
        "telemetry_replay": "skipped",
        "telemetry_equivalence": "skipped",
        **_result_signature(res),
    }
    msg = (
        f"vectorized[ledger={'on' if sla_ledger else 'off'}, "
        f"table={'on' if job_table else 'off'}]: "
        f"{n_jobs} jobs in {wall:.1f}s "
        f"({out['jobs_per_sec']:.0f} jobs/sec, "
        f"decide-path {policy.decide_seconds:.1f}s, "
        f"gather {policy.gather_seconds:.2f}s, "
        f"node-pass {policy.node_seconds:.1f}s), "
        f"util={res.utilization:.3f} done={res.completed} "
        f"dead={res.gpu_seconds_dead / 3600:.0f} gpu-h "
        f"migr={res.migrations} ({res.migrations_cross_region} cross)"
    )
    print(msg)
    print(res.summary())

    if check_equivalence:
        # every representation x policy-path combination must reproduce
        # the main run's decision sequence — span plans included —
        # exactly: {JobTable, plain jobs} x {vectorized, scalar
        # reference}, plus the per-job placement loop oracle
        # (node_batch=False) pinning the batched segment-reduce core
        combos = [
            (True, True, True),
            (True, False, True),
            (False, True, True),
            (False, False, True),
            (True, job_table, False),
        ]
        combos.remove((True, job_table, True))
        out["decision_digest"] = policy.digest()
        out["equivalence"] = "ok"
        sig = _result_signature(res)
        for vec, jt, nb in combos:
            fleet2 = _fleet(regions, clusters_per_region, gpus_per_cluster)
            other = _TimedPolicy(
                ElasticPolicy(vectorized=vec, node_batch=nb), digest=True
            )
            other_res = FleetSimulator(
                fleet2,
                _trace(n_jobs, fleet2.total()),
                other,
                SimConfig(
                    horizon_seconds=horizon,
                    sla_ledger=sla_ledger,
                    job_table=jt,
                ),
            ).run()
            label = (
                f"{'vectorized' if vec else 'scalar'}+"
                f"{'table' if jt else 'plain'}"
                f"{'' if nb else '+loop-oracle'}"
            )
            osig = _result_signature(other_res)
            if osig != sig or other.digest() != policy.digest():
                out["equivalence"] = "FAILED"
                err = (
                    f"EQUIVALENCE FAILURE: {label} diverged on the same "
                    "trace:\n"
                    f"  main:  digest={policy.digest()} {sig}\n"
                    f"  other: digest={other.digest()} {osig}"
                )
                print(err, file=sys.stderr)
        if out["equivalence"] == "ok":
            msg = (
                f"equivalence: scalar-policy, plain-job and placement "
                f"loop-oracle runs match decision-for-decision, span "
                f"plans included ({res.preemptions} preempts, "
                f"{res.migrations} migrations, {res.resizes} resizes)"
            )
            print(msg)

    if budget is not None and job_table:
        out["decide_budget_seconds"] = budget * DECIDE_BUDGET_FACTOR
        if policy.decide_seconds > budget * DECIDE_BUDGET_FACTOR:
            out["decide_gate"] = "FAILED"
            print(
                f"DECIDE-TIME REGRESSION: {policy.decide_seconds:.2f}s > "
                f"{DECIDE_BUDGET_FACTOR:.1f}x the committed "
                f"{budget:.2f}s baseline",
                file=sys.stderr,
            )
        else:
            out["decide_gate"] = "ok"
            print(
                f"decide-time gate: {policy.decide_seconds:.2f}s within "
                f"{DECIDE_BUDGET_FACTOR:.1f}x of the committed "
                f"{budget:.2f}s baseline"
            )

    if node_budget is not None and job_table:
        out["node_budget_seconds"] = node_budget * DECIDE_BUDGET_FACTOR
        if policy.node_seconds > node_budget * DECIDE_BUDGET_FACTOR:
            out["node_gate"] = "FAILED"
            print(
                f"NODE-PASS REGRESSION: {policy.node_seconds:.2f}s > "
                f"{DECIDE_BUDGET_FACTOR:.1f}x the committed "
                f"{node_budget:.2f}s baseline",
                file=sys.stderr,
            )
        else:
            out["node_gate"] = "ok"
            print(
                f"node-pass gate: {policy.node_seconds:.2f}s within "
                f"{DECIDE_BUDGET_FACTOR:.1f}x of the committed "
                f"{node_budget:.2f}s baseline"
            )

    if check_equivalence or trace_out or events_out:
        # telemetry pass: replay the main trace with the full
        # observability stack on (event log + metrics + profiler) and
        # gate three properties — (a) telemetry changes NOTHING: the
        # decision digest and result signature match the telemetry-off
        # run byte for byte; (b) telemetry is near-free on the decide
        # path (TELEMETRY_OVERHEAD_FACTOR); (c) the event log is
        # complete: replaying it reproduces the run's mechanism and
        # reliability aggregates exactly (telemetry.check_replay).
        # Exports the Perfetto trace / JSONL event log on request.
        fleet_t = _fleet(regions, clusters_per_region, gpus_per_cluster)
        tele = FleetTelemetry()
        tpolicy = _TimedPolicy(ElasticPolicy(), digest=check_equivalence)
        res_t = FleetSimulator(
            fleet_t,
            _trace(n_jobs, fleet_t.total()),
            tpolicy,
            SimConfig(
                horizon_seconds=horizon,
                sla_ledger=sla_ledger,
                job_table=job_table,
                telemetry=tele,
            ),
        ).run()
        out["telemetry_decide_seconds"] = tpolicy.decide_seconds
        ratio = tpolicy.decide_seconds / max(policy.decide_seconds, 1e-9)
        out["telemetry_overhead_ratio"] = ratio
        allowed = max(
            policy.decide_seconds * TELEMETRY_OVERHEAD_FACTOR,
            policy.decide_seconds + TELEMETRY_OVERHEAD_SLACK_SECONDS,
        )
        out["telemetry_gate"] = (
            "ok" if tpolicy.decide_seconds <= allowed else "FAILED"
        )
        mismatches = check_replay(tele.events, res_t, reliability=False)
        out["telemetry_replay"] = "ok" if not mismatches else "FAILED"
        if check_equivalence:
            same = tpolicy.digest() == policy.digest() and _result_signature(
                res_t
            ) == _result_signature(res)
            out["telemetry_equivalence"] = "ok" if same else "FAILED"
        print(
            f"telemetry: decide {tpolicy.decide_seconds:.2f}s "
            f"({ratio:.2f}x of off), {len(tele.events)} events, "
            f"{len(tele.metrics)} metric ticks — "
            f"overhead {out['telemetry_gate']}, "
            f"replay {out['telemetry_replay']}, "
            f"digest {out['telemetry_equivalence']}"
        )
        if mismatches:
            print(
                "TELEMETRY REPLAY FAILURE (event log does not reproduce "
                "the run's aggregates):\n  " + "\n  ".join(mismatches),
                file=sys.stderr,
            )
        if out["telemetry_gate"] == "FAILED":
            print(
                f"TELEMETRY OVERHEAD REGRESSION: decide "
                f"{tpolicy.decide_seconds:.2f}s > allowed {allowed:.2f}s "
                f"({TELEMETRY_OVERHEAD_FACTOR:.2f}x the telemetry-off "
                f"{policy.decide_seconds:.2f}s)",
                file=sys.stderr,
            )
        if out["telemetry_equivalence"] == "FAILED":
            print(
                f"TELEMETRY EQUIVALENCE FAILURE: telemetry-on run "
                f"diverged from telemetry-off:\n"
                f"  off: digest={policy.digest()} {_result_signature(res)}\n"
                f"  on:  digest={tpolicy.digest()} "
                f"{_result_signature(res_t)}",
                file=sys.stderr,
            )
        if trace_out:
            n_spans = export_chrome_trace(
                trace_out,
                events=tele.events,
                profiler=tele.prof,
                cluster_names=[c.id for c in fleet_t.clusters()],
                job_ids=tele.meta.get("job_ids"),
                end_time=horizon,
            )
            print(f"wrote {trace_out} ({n_spans} trace events)")
        if events_out:
            tele.events.to_jsonl(events_out, meta=tele.meta)
            print(f"wrote {events_out} ({len(tele.events)} event rows)")

    if serving:
        out["serving"] = bench_serving(
            n_jobs,
            regions,
            clusters_per_region,
            gpus_per_cluster,
            check_equivalence,
        )

    if curves:
        out["curves"] = bench_curves(
            n_jobs,
            regions,
            clusters_per_region,
            gpus_per_cluster,
            check_equivalence,
        )

    if failure_spec:
        out["reliability"] = bench_failures(
            n_jobs,
            regions,
            clusters_per_region,
            gpus_per_cluster,
            check_equivalence,
            failure_spec,
        )

    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return out


def run() -> List[Dict]:
    """Benchmark-harness entry: 50k rows incl. extrapolated baselines."""
    n_jobs = 50_000
    rows = []
    fleet = _fleet()
    horizon = _horizon(n_jobs, fleet.total())

    # -- vectorized policy + loop + batched SLA ledger, full trace --------
    timed = _TimedPolicy(ElasticPolicy())
    sim = FleetSimulator(
        fleet,
        _trace(n_jobs, fleet.total()),
        timed,
        SimConfig(horizon_seconds=horizon),
    )
    t0 = time.perf_counter()
    res = sim.run()
    vec_wall = time.perf_counter() - t0
    derived = (
        f"jobs_per_sec={n_jobs / vec_wall:.0f};"
        f"decide_s={timed.decide_seconds:.1f};"
        f"gather_s={timed.gather_seconds:.2f};"
        f"events={sim.events_processed};"
        f"done={res.completed}/{res.total_jobs};"
        f"util={res.utilization:.3f}"
    )
    rows.append(
        {
            "name": "sched_scale/vectorized_50k",
            "us_per_call": vec_wall * 1e6,
            "derived": derived,
        }
    )

    # -- same, plain scalar Job objects (the pre-JobTable decide path:
    #    per-job attribute gathering rebuilt every tick) ------------------
    fleet_nt = _fleet()
    timed_nt = _TimedPolicy(ElasticPolicy())
    sim_nt = FleetSimulator(
        fleet_nt,
        _trace(n_jobs, fleet_nt.total()),
        timed_nt,
        SimConfig(horizon_seconds=horizon, job_table=False),
    )
    t0 = time.perf_counter()
    sim_nt.run()
    nt_wall = time.perf_counter() - t0
    derived = (
        f"jobs_per_sec={n_jobs / nt_wall:.0f};"
        f"decide_s={timed_nt.decide_seconds:.1f};"
        f"gather_s={timed_nt.gather_seconds:.2f};"
        f"decide_speedup_table="
        f"{timed_nt.decide_seconds / max(timed.decide_seconds, 1e-9):.2f}x"
    )
    rows.append(
        {
            "name": "sched_scale/no_job_table_50k",
            "us_per_call": nt_wall * 1e6,
            "derived": derived,
        }
    )

    # -- same, per-job scalar SLA accounts (PR 2 decide-path baseline) ----
    fleet_nl = _fleet()
    timed_nl = _TimedPolicy(ElasticPolicy())
    sim_nl = FleetSimulator(
        fleet_nl,
        _trace(n_jobs, fleet_nl.total()),
        timed_nl,
        SimConfig(horizon_seconds=horizon, sla_ledger=False, job_table=False),
    )
    t0 = time.perf_counter()
    sim_nl.run()
    nl_wall = time.perf_counter() - t0
    derived = (
        f"jobs_per_sec={n_jobs / nl_wall:.0f};"
        f"decide_s={timed_nl.decide_seconds:.1f};"
        f"decide_speedup_ledger="
        f"{timed_nl.decide_seconds / max(timed.decide_seconds, 1e-9):.2f}x"
    )
    rows.append(
        {
            "name": "sched_scale/scalar_accounts_50k",
            "us_per_call": nl_wall * 1e6,
            "derived": derived,
        }
    )

    # -- scalar reference policy, full trace (fast enough to measure);
    #    scalar accounts too, so the row stays the PR 2 baseline --------
    fleet_s = _fleet()
    scalar = FleetSimulator(
        fleet_s,
        _trace(n_jobs, fleet_s.total()),
        ElasticPolicy(vectorized=False),
        SimConfig(horizon_seconds=horizon, sla_ledger=False, job_table=False),
    )
    t0 = time.perf_counter()
    scalar.run()
    scalar_wall = time.perf_counter() - t0
    derived = (
        f"jobs_per_sec={n_jobs / scalar_wall:.0f};"
        f"events={scalar.events_processed};"
        f"speedup_vectorized={scalar_wall / vec_wall:.2f}x"
    )
    rows.append(
        {
            "name": "sched_scale/scalar_policy_50k",
            "us_per_call": scalar_wall * 1e6,
            "derived": derived,
        }
    )

    # -- seed event loop, truncated + extrapolated ------------------------
    fleet_i = _fleet()
    legacy = FleetSimulator(
        fleet_i,
        _trace(n_jobs, fleet_i.total()),
        ElasticPolicy(vectorized=False),
        # seed configuration throughout: per-event loop, scalar accounts
        SimConfig(
            horizon_seconds=LEGACY_HORIZON,
            vectorized=False,
            sla_ledger=False,
            job_table=False,
        ),
    )
    t0 = time.perf_counter()
    legacy.run()
    wall = time.perf_counter() - t0
    # full event count: one per arrival + one per tick; per-event cost
    # grows with live-job count later in the trace, so this UNDERSTATES
    # the true cost and the reported speedup is a floor
    total_events = n_jobs + int(horizon / legacy.cfg.tick_seconds)
    full_wall = wall / max(legacy.events_processed, 1) * total_events
    derived = (
        f"jobs_per_sec={n_jobs / full_wall:.1f};"
        f"measured_events={legacy.events_processed};"
        f"measured_wall_s={wall:.1f};"
        f"speedup_vectorized={full_wall / vec_wall:.0f}x"
    )
    rows.append(
        {
            "name": "sched_scale/seed_loop_50k_extrapolated",
            "us_per_call": full_wall * 1e6,
            "derived": derived,
        }
    )
    return rows


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--jobs", type=int, default=50_000)
    parser.add_argument("--regions", type=int, default=4)
    parser.add_argument("--clusters-per-region", type=int, default=4)
    parser.add_argument("--gpus-per-cluster", type=int, default=4096)
    parser.add_argument(
        "--json",
        type=str,
        default=None,
        help="write benchmark metrics to this JSON file",
    )
    parser.add_argument(
        "--check-equivalence",
        action="store_true",
        help="re-run under the scalar reference policy and fail unless "
        "results match exactly",
    )
    parser.add_argument(
        "--no-sla-ledger",
        action="store_true",
        help="use per-job scalar SLA accounts instead of the batched "
        "fleet ledger (the PR 2 decide-path baseline)",
    )
    parser.add_argument(
        "--no-job-table",
        action="store_true",
        help="keep plain scalar Job objects instead of the fleet "
        "JobTable (the PR 4 decide-path baseline: per-job attribute "
        "gathering in Python)",
    )
    parser.add_argument(
        "--failure-trace",
        type=str,
        default=None,
        metavar="SPEC",
        help="add a reliability row: replay a failure scenario (a saved "
        "FailureTrace JSON path, or the named seeded scenarios 'storm' / "
        "'outage') with and without checkpoint cadence; with "
        "--check-equivalence the storm run also gates vec==scalar "
        "decision digests",
    )
    parser.add_argument(
        "--serving",
        action="store_true",
        help="add the elastic serving row: mix a 24h diurnal+spike "
        "inference trace into the training trace and gate p99 SLO "
        "attainment, reclaim latency against the CostModel deadline, "
        "and the loaning training-throughput gain (docs/serving.md)",
    )
    parser.add_argument(
        "--curves",
        action="store_true",
        help="add the concave-scaling row: replay the trace with "
        "synthetic concave throughput curves and fail unless the "
        "curve-aware water-filling allocator strictly beats the "
        "curve-blind linear arm on realized goodput at equal capacity; "
        "with --check-equivalence also gates the {table, plain} x "
        "{vectorized, scalar} decision digests with curves on",
    )
    parser.add_argument(
        "--trace-out",
        type=str,
        default=None,
        metavar="PATH",
        help="export a Perfetto/chrome://tracing JSON trace of the "
        "telemetry re-run: job lifecycle spans on per-cluster tracks "
        "plus decide-pass profiler phases (docs/observability.md)",
    )
    parser.add_argument(
        "--events-out",
        type=str,
        default=None,
        metavar="PATH",
        help="export the telemetry re-run's structured event log as "
        "JSONL (one lifecycle event per line; replayable via "
        "telemetry.read_jsonl/replay_events)",
    )
    parser.add_argument(
        "--harness",
        action="store_true",
        help="print the benchmark-harness CSV rows instead",
    )
    args = parser.parse_args(argv)
    if args.harness:
        for row in run():
            quoted = '"' + row["derived"] + '"'
            print(f"{row['name']},{row['us_per_call']:.1f},{quoted}")
        return 0
    out = bench(
        args.jobs,
        args.regions,
        args.clusters_per_region,
        args.gpus_per_cluster,
        args.check_equivalence,
        args.json,
        sla_ledger=not args.no_sla_ledger,
        failure_spec=args.failure_trace,
        job_table=not args.no_job_table,
        serving=args.serving,
        curves=args.curves,
        trace_out=args.trace_out,
        events_out=args.events_out,
    )
    if (
        out["equivalence"] == "FAILED"
        or out["decide_gate"] == "FAILED"
        or out["node_gate"] == "FAILED"
        or out["telemetry_gate"] == "FAILED"
        or out["telemetry_replay"] == "FAILED"
        or out["telemetry_equivalence"] == "FAILED"
    ):
        return 1
    srv = out.get("serving")
    if srv is not None:
        if srv["equivalence"] == "FAILED":
            return 1
        bad = [k for k, v in srv["gates"].items() if v != "ok"]
        if bad:
            print(f"SERVING GATES FAILED: {bad}", file=sys.stderr)
            return 1
    cur = out.get("curves")
    if cur is not None:
        if cur["equivalence"] == "FAILED" or cur["goodput_gate"] == "FAILED":
            return 1
    rel = out.get("reliability")
    if rel is not None:
        if rel["equivalence"] == "FAILED":
            return 1
        if rel["goodput_gate"] == "enforced" and not rel["cadence_improves_goodput"]:
            print(
                "RELIABILITY FAILURE: checkpoint cadence did not improve "
                f"goodput ({rel['goodput_fraction']} -> "
                f"{rel['cadence_goodput_fraction']})",
                file=sys.stderr,
            )
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

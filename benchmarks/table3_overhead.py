"""Table 3 reproduction: steady-state overhead of the device-proxy layer.

Paper claim: dynamic interception + the in-graph tandem meta-allreduce add
<3% to mini-batch time.  Here the "proxy" path is the production step —
dispatch through the elastic-runtime boundary WITH the 2-int barrier
payload — versus a bare jitted train step.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.models.frontend import synth_extra_inputs
from repro.training.state import init_train_state
from repro.training.step import build_train_step

MODELS = ["olmo-1b", "h2o-danube-3-4b", "mamba2-130m", "granite-moe-3b-a800m",
          "paper-gpt2-1.8b"]
B, S, STEPS = 4, 64, 12


def _time_step(fn, state, batch, flags=None) -> float:
    # warmup + compile
    out = fn(state, batch, flags) if flags is not None else fn(state, batch)
    jax.block_until_ready(out[1]["loss"])
    t0 = time.perf_counter()
    for _ in range(STEPS):
        out = fn(state, batch, flags) if flags is not None \
            else fn(state, batch)
        jax.block_until_ready(out[1]["loss"])
    return (time.perf_counter() - t0) / STEPS


def run() -> List[Dict]:
    rows = []
    key = jax.random.PRNGKey(0)
    for arch in MODELS:
        cfg = get_smoke_config(arch)
        tcfg = TrainConfig(total_steps=100, warmup_steps=1)
        state = init_train_state(cfg, tcfg, key)
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens}
        batch.update(synth_extra_inputs(cfg, B, key))

        base = jax.jit(build_train_step(cfg, tcfg, splice=1))
        proxy = jax.jit(build_train_step(cfg, tcfg, splice=1,
                                         with_barrier=True))
        flags = jnp.zeros((1, 2), jnp.int32)

        t_base = _time_step(base, state, batch)
        t_proxy = _time_step(proxy, state, batch, flags)
        overhead = (t_proxy - t_base) / t_base * 100
        rows.append({
            "name": f"table3/{arch}",
            "us_per_call": t_proxy * 1e6,
            "derived": f"overhead_pct={overhead:.2f}",
            "baseline_us": t_base * 1e6,
        })
    return rows

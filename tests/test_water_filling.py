"""Water-filling expansion: property-tested against a marginal-utility
oracle.

The policy's pass 3 fills spare capacity over the jobs' concave scaling
curves in two vectorized blocks (pre-knee chunks in scale-up order, then
post-knee chunks by descending slope).  The oracle here is the
*specification* it implements: grant spare GPUs one at a time, each to
the gated candidate whose next GPU has the highest marginal utility
(slope x interval), ties broken by (scale-up priority, index).  With
strictly-concave curves (``sat_slope < 1``) the two formulations must
agree exactly; with flat curves both must reduce to the seed's linear
expansion.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.sla import TIERS
from repro.scheduler.costs import CostModel
from repro.scheduler.policy import ElasticPolicy
from repro.scheduler.simulator import (
    FleetSimulator,
    SimConfig,
    make_fleet,
    synth_workload,
)
from repro.scheduler.types import Cluster, Fleet, Job, Region

INTERVAL = 300.0
TIER_NAMES = ["premium", "standard", "basic"]


def _running_job(i, tier, demand, knee, sat):
    """A job running steadily at full demand with a healthy SLA history
    (min_gpus == demand keeps passes 1/1b/2 trivial: everyone sits at
    exactly ``demand`` when pass 3 starts)."""
    j = Job(
        id=f"j{i:03d}",
        tier=tier,
        demand_gpus=demand,
        gpu_hours=demand * 4.0,
        arrival=0.0,
        min_gpus=demand,
        knee_gpus=knee,
        sat_slope=sat,
    )
    j.allocated = demand
    j.cluster = "c0"
    j.ever_ran = True
    j.account.record(0.0, 1800.0, demand)
    return j


def _oracle(spec, spare, resize_s):
    """Per-GPU marginal-utility greedy over the jobs' curves.

    ``spec`` rows are (demand, knee, sat, sup) for jobs running at
    ``galloc == demand``; ``resize_s`` None means no cost model (every
    gate open).  Returns the expansion grant per job."""
    n = len(spec)
    grants = [0] * n
    chunks = []
    for demand, knee, sat, _sup in spec:
        galloc = demand
        target = 2 * demand  # expand_factor == 2
        end_a = min(max(knee, galloc), target) if knee > 0 else target
        d_a = end_a - galloc
        d_b = target - end_a
        if resize_s is None:
            gate_a = gate_b = True
        else:
            gate_a = resize_s * (galloc + d_a) < d_a * INTERVAL
            if d_a > 0:
                gate_b = gate_a and sat * INTERVAL > resize_s
            else:
                gate_b = resize_s * (galloc + d_b) < sat * INTERVAL * d_b
        chunks.append((d_a, d_b, gate_a, gate_b))
    rem = spare
    while rem > 0:
        best, best_key = None, None
        for i, (demand, knee, sat, sup) in enumerate(spec):
            d_a, d_b, gate_a, gate_b = chunks[i]
            g = grants[i]
            if g < d_a:
                if not gate_a:
                    continue  # an ungated pre-knee chunk blocks the job
                slope = INTERVAL
            elif g < d_a + d_b:
                if not gate_b:
                    continue
                slope = sat * INTERVAL
            else:
                continue
            key = (-slope, sup, i)
            if best_key is None or key < best_key:
                best, best_key = i, key
        if best is None:
            break
        grants[best] += 1
        rem -= 1
    return grants


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 50_000), n=st.integers(1, 12), costed=st.booleans())
def test_expansion_matches_marginal_utility_oracle(seed, n, costed):
    rng = np.random.Generator(np.random.Philox(seed))
    jobs, spec = [], []
    for i in range(n):
        demand = int(2 ** rng.integers(2, 6))  # 4..32
        if rng.integers(0, 2):
            knee = int(rng.integers(demand, 2 * demand + 1))
            sat = float(rng.uniform(0.05, 0.95))  # strictly concave
        else:
            knee, sat = 0, 1.0
        tier = str(rng.choice(TIER_NAMES))
        jobs.append(_running_job(i, tier, demand, knee, sat))
        spec.append((demand, knee, sat, TIERS[tier].scaleup_priority))
    total_demand = sum(s[0] for s in spec)
    # spare must clear the 10%-slack threshold or pass 3 never runs
    spare = max(
        int(rng.integers(1, total_demand + 1)), total_demand // 9 + 1
    )
    fleet = Fleet([Region("r0", [Cluster("c0", "r0", total_demand + spare)])])
    if costed:
        resize_s = float(rng.uniform(1.0, INTERVAL))
        cm = CostModel.uniform(1.0, resize_cost_seconds=resize_s)
    else:
        resize_s, cm = None, None
    expect = _oracle(spec, spare, resize_s)
    for vectorized in (True, False):
        pol = ElasticPolicy(
            cost_model=cm, interval_hint=INTERVAL, vectorized=vectorized
        )
        d = pol.decide(1800.0, jobs, fleet)
        got = [d.alloc[j.id][0] - j.demand_gpus for j in jobs]
        assert got == expect, (vectorized, spec, spare, resize_s)
        # curve-granted jobs are tagged for slope-cause telemetry
        tagged = set(d.slope_expanded or ())
        want_tagged = {
            jobs[i].id for i in range(n) if spec[i][1] > 0 and expect[i] > 0
        }
        assert tagged == want_tagged


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 50_000), n=st.integers(1, 10))
def test_curve_unaware_policy_reduces_to_flat(seed, n):
    """``curve_aware=False`` on curved jobs must decide exactly like the
    default policy on flattened clones — the seed's linear expansion."""
    rng = np.random.Generator(np.random.Philox(seed))
    curved, flat = [], []
    for i in range(n):
        demand = int(2 ** rng.integers(2, 6))
        knee = int(rng.integers(demand, 2 * demand + 1))
        sat = float(rng.uniform(0.0, 1.0))
        tier = str(rng.choice(TIER_NAMES))
        curved.append(_running_job(i, tier, demand, knee, sat))
        flat.append(_running_job(i, tier, demand, 0, 1.0))
    total = sum(j.demand_gpus for j in curved)
    fleet_a = Fleet([Region("r0", [Cluster("c0", "r0", 2 * total)])])
    fleet_b = Fleet([Region("r0", [Cluster("c0", "r0", 2 * total)])])
    cm = CostModel()
    blind = ElasticPolicy(cost_model=cm, interval_hint=INTERVAL, curve_aware=False)
    seed_pol = ElasticPolicy(cost_model=cm, interval_hint=INTERVAL)
    d_blind = blind.decide(1800.0, curved, fleet_a)
    d_seed = seed_pol.decide(1800.0, flat, fleet_b)
    assert dict(d_blind.alloc) == dict(d_seed.alloc)
    assert d_blind.slope_expanded is None
    assert d_seed.slope_expanded is None


def test_expansion_stops_at_the_knee_when_slope_below_burn():
    """The slope-vs-burn gate: a curved job expands to its knee and no
    further when the post-knee slope cannot pay the resize burn, while a
    flat twin under the same costs expands fully (the legacy gate)."""
    # resize 60s, interval 300s: pre-knee chunk gains 5*300 = 1500 >
    # burn 60*15 = 900 -> granted; post-knee slope 0.1*300 = 30 < 60 ->
    # refused.  The flat twin's whole chunk gains 10*300 > 60*20 -> full.
    cm = CostModel.uniform(360.0, resize_cost_seconds=60.0)
    fleet = Fleet([Region("r0", [Cluster("c0", "r0", 100)])])
    curved = _running_job(0, "standard", 10, 15, 0.1)
    d = ElasticPolicy(cost_model=cm, interval_hint=INTERVAL).decide(
        1800.0, [curved], fleet
    )
    assert d.alloc[curved.id][0] == 15  # stopped exactly at the knee
    assert d.slope_expanded == (curved.id,)

    flat = _running_job(0, "standard", 10, 0, 1.0)
    d = ElasticPolicy(cost_model=cm, interval_hint=INTERVAL).decide(
        1800.0, [flat], fleet
    )
    assert d.alloc[flat.id][0] == 20
    assert d.slope_expanded is None

    # a steeper curve clears the marginal gate and fills past the knee
    steep = _running_job(0, "standard", 10, 15, 0.5)  # 150 s/GPU > 60 s
    d = ElasticPolicy(cost_model=cm, interval_hint=INTERVAL).decide(
        1800.0, [steep], fleet
    )
    assert d.alloc[steep.id][0] == 20
    assert d.slope_expanded == (steep.id,)


def test_shrink_gate_prices_the_shrunk_operating_point():
    """Shrink-before-queue on a curved job is only worth a restart whose
    downtime beats the *shrunk* slice's productive value, not a full
    interval."""
    # standard tier: shrunk = demand * (0.7 + 0.1) = 16 of 20.  A
    # preempted job carrying 270s restore debt: 270 >= 300 * 16/20 = 240
    # -> a curved job stays queued; the flat twin (priced at the full
    # interval, 270 < 300) shrinks in.
    def _queued(knee):
        j = Job(
            id="q",
            tier="standard",
            demand_gpus=20,
            gpu_hours=80.0,
            arrival=0.0,
            min_gpus=1,
            knee_gpus=knee,
            sat_slope=0.5 if knee else 1.0,
        )
        j.ever_ran = True
        j.restore_debt = 270.0
        j.account.record(0.0, 1800.0, 20)
        return j

    # capacity 12: pass 1's all-or-nothing shrunk slice (16) cannot fit,
    # so admission falls to the shrink-before-queue pass
    fleet = Fleet([Region("r0", [Cluster("c0", "r0", 12)])])
    cm = CostModel.uniform(0.0, restore_cost_seconds=0.0, resize_cost_seconds=0.0)
    pol = ElasticPolicy(cost_model=cm, interval_hint=INTERVAL)
    d_flat = pol.decide(1800.0, [_queued(0)], fleet)
    assert d_flat.alloc["q"][0] == 12  # legacy gate: 270 < 300
    d_curved = pol.decide(1800.0, [_queued(40)], fleet)
    assert d_curved.alloc["q"][0] == 0  # curve gate: 270 >= 240


def test_full_simulation_identical_under_both_paths_with_curves():
    """End to end on a curved trace (node-granular placement included):
    vectorized and reference decisions must stay byte-identical."""
    results = {}
    for vectorized in (True, False):
        sim = FleetSimulator(
            make_fleet(),
            synth_workload(60, 2048, seed=13, curves=True),
            ElasticPolicy(vectorized=vectorized),
            SimConfig(horizon_seconds=12 * 3600),
        )
        results[vectorized] = sim.run()
    a, b = results[True], results[False]
    assert a.utilization == b.utilization
    assert a.completed == b.completed
    assert (a.preemptions, a.migrations, a.resizes, a.restores) == (
        b.preemptions,
        b.migrations,
        b.resizes,
        b.restores,
    )
    assert a.gpu_seconds_dead == b.gpu_seconds_dead

"""Observability stack (scheduler/telemetry.py): event-log ring
mechanics, batched-vs-scalar append equivalence, the nested-span
profiler, Perfetto export validity, and the replay differential —
an exported event log must fold back into the exact SimResult
aggregates of the run that emitted it, with and without telemetry
changing nothing about the schedule itself.
"""
import dataclasses
import json

import numpy as np

from repro.scheduler.costs import CostModel
from repro.scheduler.policy import ElasticPolicy
from repro.scheduler.reliability import CheckpointCadence, FailureModel
from repro.scheduler.simulator import (
    FleetSimulator,
    SimConfig,
    make_fleet,
    synth_workload,
)
from repro.scheduler.telemetry import (
    EVENT_KINDS,
    EventLog,
    FleetTelemetry,
    Profiler,
    check_replay,
    export_chrome_trace,
    read_jsonl,
    replay_events,
)

HORIZON = 30 * 3600.0


def _storm_sim(telemetry=True):
    """A small fleet under a dense failure storm: exercises every event
    family (admit/preempt/restore/migrate/resize/failure/snapshot)."""
    fleet = make_fleet()
    jobs = synth_workload(160, fleet.total(), seed=11)
    model = FailureModel(
        device_mtbf_seconds=20 * 24 * 3600.0,
        node_mtbf_seconds=30 * 24 * 3600.0,
        cluster_mtbf_seconds=60 * 24 * 3600.0,
        seed=5,
    )
    cfg = SimConfig(
        horizon_seconds=HORIZON,
        cost_model=CostModel(),
        failures=model,
        cadence=CheckpointCadence(cost_model=CostModel()),
        telemetry=telemetry,
    )
    sim = FleetSimulator(fleet, jobs, ElasticPolicy(), cfg)
    return sim, sim.run()


_CACHE = {}


def _cached_storm():
    if "storm" not in _CACHE:
        _CACHE["storm"] = _storm_sim(telemetry=True)
    return _CACHE["storm"]


# ------------------------------------------------------------ event log
def test_event_log_ring_growth():
    log = EventLog(capacity=2)
    for i in range(100):
        log.append(float(i), i % len(EVENT_KINDS), job=i, gpus=2 * i)
    assert len(log) == 100
    assert log._cap >= 100  # doubled past the initial capacity
    assert log.column("time").tolist() == [float(i) for i in range(100)]
    assert log.column("gpus").tolist() == [2 * i for i in range(100)]
    # the live view never exposes unwritten tail slots
    assert log.column("job").shape == (100,)


def test_append_batch_matches_scalar_appends():
    rng = np.random.default_rng(3)
    batched, scalar = EventLog(capacity=4), EventLog(capacity=4)
    for _ in range(7):
        m = int(rng.integers(1, 40))
        jobs = rng.integers(0, 1000, m)
        gpus = rng.integers(0, 64, m)
        secs = rng.random(m)
        t = float(rng.random() * 1e5)
        kind = int(rng.integers(0, len(EVENT_KINDS)))
        tier = int(rng.integers(0, 3))
        batched.append_batch(
            t, kind, job=jobs, cluster=2, tier=tier, gpus=gpus, seconds=secs
        )
        for j, g, s in zip(jobs, gpus, secs):
            scalar.append(
                t,
                kind,
                job=int(j),
                cluster=2,
                tier=tier,
                gpus=int(g),
                seconds=float(s),
            )
    assert len(batched) == len(scalar)
    for name, _, _ in EventLog._COLUMNS:
        assert (batched.column(name) == scalar.column(name)).all(), name


def test_append_batch_empty_is_noop():
    log = EventLog()
    log.append_batch(1.0, 0, job=np.array([], np.int64))
    assert len(log) == 0


def test_jsonl_roundtrip_is_exact():
    sim, _ = _cached_storm()
    path = "/tmp/test_telemetry_events.jsonl"
    sim.tele.events.to_jsonl(path, meta=sim.tele.meta)
    log2, meta = read_jsonl(path)
    assert len(log2) == len(sim.tele.events)
    assert meta["events"] == len(sim.tele.events)
    assert meta["reliability"] is True
    # bit-exact: every column round-trips through JSON untouched
    for name, _, _ in EventLog._COLUMNS:
        assert (log2.column(name) == sim.tele.events.column(name)).all(), name
    assert replay_events(log2) == replay_events(sim.tele.events)


# ------------------------------------------------------------- profiler
def test_profiler_nesting_depth_and_totals():
    prof = Profiler(enabled=True)
    with prof.span("outer"):
        with prof.span("inner"):
            pass
        with prof.span("inner"):
            pass
    assert prof.counts == {"outer": 1, "inner": 2}
    assert prof.total("outer") >= prof.total("inner") > 0.0
    depths = {name: depth for name, depth, *_ in prof.spans}
    assert depths == {"outer": 0, "inner": 1}
    assert prof._depth == 0  # fully unwound


def test_profiler_disabled_accumulates_totals_but_records_nothing():
    prof = Profiler()  # disabled: the telemetry-off configuration
    with prof.span("decide"):
        pass
    assert prof.total("decide") > 0.0
    assert prof.counts["decide"] == 1
    assert prof.spans == []  # no per-span memory growth when off


def test_policy_profiler_backs_timing_properties():
    sim, _ = _cached_storm()
    pol = sim.policy
    assert pol.decide_seconds == pol.prof.total("decide") > 0.0
    assert pol.gather_seconds == pol.prof.total("gather") > 0.0
    assert pol.node_seconds == pol.prof.total("place") > 0.0
    # the sub-passes are nested inside decide
    assert pol.gather_seconds + pol.node_seconds < pol.decide_seconds
    # the bundle's profiler IS the policy's (bind_telemetry)
    assert pol.prof is sim.tele.prof


# ------------------------------------------------------------ replay
def test_replay_reproduces_simresult_aggregates():
    sim, res = _cached_storm()
    assert res.job_failures > 0  # the storm actually bit
    assert res.preemptions > 0
    assert check_replay(sim.tele.events, res) == []


def test_replay_detects_a_dropped_event():
    sim, res = _cached_storm()
    log = sim.tele.events
    truncated = EventLog()
    kept = 0
    for row in log.rows():
        if row["kind"] == "preempt" and kept == 0:
            kept = 1  # silently drop one preemption
            continue
        truncated.append(
            row["t"],
            EVENT_KINDS.index(row["kind"]),
            job=row["job"],
            seconds=row["seconds"],
        )
    mism = check_replay(truncated, res, reliability=False)
    assert any(m.startswith("preemptions") for m in mism)


def test_telemetry_changes_nothing():
    _, res_on = _cached_storm()
    _, res_off = _storm_sim(telemetry=False)
    assert dataclasses.asdict(res_off) == dataclasses.asdict(res_on)


# ------------------------------------------------------------- metrics
def test_metrics_series_per_tick():
    sim, _ = _cached_storm()
    m = sim.tele.metrics
    assert len(m) > 0
    t = m.column("time")
    assert (np.diff(t) > 0).all()  # strictly increasing tick times
    util = m.column("utilization")
    assert (util >= 0.0).all() and (util <= 1.0).all()
    # per-tick decide deltas sum back to the profiler's total
    assert np.isclose(
        m.column("decide_seconds").sum(),
        sim.tele.prof.total("decide"),
        rtol=1e-9,
    )
    path = "/tmp/test_telemetry_metrics.csv"
    m.to_csv(path)
    header = open(path).readline().strip().split(",")
    assert header == list(m.fields)


# ------------------------------------------------------------- perfetto
def test_chrome_trace_is_valid_and_loadable():
    sim, _ = _cached_storm()
    path = "/tmp/test_telemetry_trace.json"
    n = export_chrome_trace(
        path,
        events=sim.tele.events,
        profiler=sim.tele.prof,
        cluster_names=[c.id for c in sim.fleet.clusters()],
        job_ids=sim.tele.meta["job_ids"],
        end_time=HORIZON,
    )
    doc = json.load(open(path))
    trace = doc["traceEvents"]
    assert len(trace) == n > 0
    phases = {e["ph"] for e in trace}
    assert phases <= {"M", "X"}
    names = {e["args"]["name"] for e in trace if e["name"] == "process_name"}
    assert "scheduler" in names
    assert any(name.startswith("cluster ") for name in names)
    for e in trace:
        if e["ph"] != "X":
            continue
        assert e["ts"] >= 0.0 and e["dur"] >= 0.0
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
    # job spans land on cluster tracks (pid >= 1), profiler spans on 0
    cats = {e.get("cat") for e in trace if e["ph"] == "X"}
    assert cats == {"job", "decide"}
    assert all(e["pid"] >= 1 for e in trace if e.get("cat") == "job")
    assert all(e["pid"] == 0 for e in trace if e.get("cat") == "decide")
    # decide-pass phases made it into the trace
    span_names = {e["name"] for e in trace if e.get("cat") == "decide"}
    assert {"decide", "gather", "apply"} <= span_names


# ------------------------------------------------------------- summary
def test_summary_one_screen_report():
    _, res = _cached_storm()
    text = res.summary()
    assert text.count("\n") < 12  # one screen
    for token in ("fleet", "mechanisms", "failures", "premium", "basic"):
        assert token in text, token
    assert f"completed {res.completed}/{res.total_jobs}" in text


def test_event_causes_cover_failure_kinds():
    # the cause vocabulary must stay a superset of reliability's kinds
    from repro.scheduler.reliability import FAILURE_KINDS
    from repro.scheduler.telemetry import CAUSE_CODE

    assert all(k in CAUSE_CODE for k in FAILURE_KINDS)


def test_telemetry_bundle_defaults():
    tele = FleetTelemetry()
    assert tele.prof.enabled
    assert len(tele.events) == 0 and len(tele.metrics) == 0
    assert tele.meta == {}

"""Batched node-placement core: exact equivalence against the per-job
loop oracle, and the overlay machinery it leans on.

The decide-pass node pass (`ElasticPolicy._place_nodes`) dispatches to a
batched core built from array passes (`_place_nodes_batched`); the old
per-job loop survives as `_place_nodes_loop`, the oracle.  These tests
pin the contract that made the rewrite safe:

- full-simulation digest equivalence batched == loop, spans included,
  storm on and off, over both job representations;
- `PlacementOverlay.fit_batch` / `release_rows` replay exactly the
  sequential `fit_any` / `release_row` calls they batch;
- the overlay's histogram-backed incremental stats always agree with a
  brute-force rescan of the segment;
- `fit_any`'s scattered order is pinned (stable sort, lowest node index
  on ties) so decision digests cannot drift across numpy versions;
- degenerate gang-helper inputs (`min_gpus > demand`, zero demand) are
  clamped, property-tested against brute force;
- `PlacementOverlay.undo` tombstones survive span-pool compaction: a
  mid-decide `_compact` must not resurrect released or undone spans;
- `NodeMap.release_many`/`assign_many` commit a plan identically to the
  sequential release/assign loop.
"""

import hashlib

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.scheduler.costs import CostModel
from repro.scheduler.node_map import (
    NodeMap,
    floor_gang,
    gang_values,
    min_piece,
    splice_divisors,
)
from repro.scheduler.policy import ElasticPolicy
from repro.scheduler.reliability import FailureModel, FailureTrace
from repro.scheduler.simulator import (
    FleetSimulator,
    SimConfig,
    make_fleet,
    synth_workload,
)
from repro.scheduler.types import Cluster, Fleet, Region


class _PlanDigestPolicy:
    """Hashes every decision INCLUDING its node span plan (the
    test_node_map recipe), so batched-vs-oracle drift in any span is
    fatal, not hidden behind identical aggregate allocations."""

    def __init__(self, inner):
        self.inner = inner
        self.name = inner.name
        self.digest = hashlib.sha256()

    def bind_costs(self, cost_model, interval_hint):
        self.inner.bind_costs(cost_model, interval_hint)

    def decide(self, now, jobs, fleet):
        decision = self.inner.decide(now, jobs, fleet)
        plan = decision.node_plan
        spans = None
        if plan is not None:
            _, released, assigns = plan
            spans = (
                sorted(released),
                [(r, list(n), list(g)) for r, n, g in assigns],
            )
        self.digest.update(
            repr(
                (
                    sorted(decision.alloc.items()),
                    decision.preemptions,
                    decision.migrations,
                    spans,
                )
            ).encode()
        )
        return decision


def _storm_run(node_batch: bool, job_table: bool) -> tuple:
    fleet = make_fleet(n_regions=2, clusters_per_region=2, gpus_per_cluster=256)
    storm = FailureTrace.merge(
        FailureModel(
            device_mtbf_seconds=10 * 24 * 3600.0,
            node_mtbf_seconds=15 * 24 * 3600.0,
            cluster_mtbf_seconds=45 * 24 * 3600.0,
            seed=11,
        ).sample(fleet, 12 * 3600.0),
        FailureTrace.cluster_outage("r0c0", at=4 * 3600.0),
    )
    wrapper = _PlanDigestPolicy(ElasticPolicy(node_batch=node_batch))
    sim = FleetSimulator(
        fleet,
        synth_workload(80, fleet.total(), seed=5, mean_interarrival=180.0),
        wrapper,
        SimConfig(
            horizon_seconds=12 * 3600.0,
            cost_model=CostModel(),
            failures=storm,
            validate=True,  # per-node conservation asserted every tick
            job_table=job_table,
        ),
    )
    res = sim.run()
    return res, wrapper.digest.hexdigest()


def test_batched_equals_loop_oracle_under_storm():
    res_b, dig_b = _storm_run(node_batch=True, job_table=True)
    res_l, dig_l = _storm_run(node_batch=False, job_table=True)
    res_p, dig_p = _storm_run(node_batch=True, job_table=False)
    assert res_b.job_failures > 0  # the storm actually stormed
    assert dig_b == dig_l == dig_p
    assert res_b.utilization == res_l.utilization
    assert (res_b.preemptions, res_b.migrations, res_b.resizes) == (
        res_l.preemptions,
        res_l.migrations,
        res_l.resizes,
    )


def test_batched_equals_loop_oracle_calm_sea():
    digests = {}
    for nb in (True, False):
        fleet = make_fleet(n_regions=2, clusters_per_region=2, gpus_per_cluster=256)
        wrapper = _PlanDigestPolicy(ElasticPolicy(node_batch=nb))
        sim = FleetSimulator(
            fleet,
            synth_workload(60, fleet.total(), seed=2, mean_interarrival=240.0),
            wrapper,
            SimConfig(horizon_seconds=8 * 3600.0, validate=True),
        )
        sim.run()
        digests[nb] = wrapper.digest.hexdigest()
    assert digests[True] == digests[False]


# ----------------------------------------- overlay batched-op equivalence
def _toy_map(caps=(48, 20), gpn=8, rows=16) -> NodeMap:
    fleet = Fleet(
        [
            Region(
                "r0",
                [
                    Cluster(f"r0c{k}", "r0", c, gpus_per_node=gpn)
                    for k, c in enumerate(caps)
                ],
            )
        ]
    )
    return NodeMap.from_fleet(fleet, capacity_rows=rows)


def _occupy(nm: NodeMap, rng, rows: int) -> list:
    """Scatter some rows into the map so overlays start non-trivial."""
    placed = []
    for row in range(rows):
        k = int(rng.integers(0, nm.n_clusters))
        free = int(nm.cluster_free_vector()[k])
        if free <= 0:
            continue
        nm.auto_fit(row, k, int(rng.integers(1, free + 1)))
        placed.append(row)
    return placed


def _stats_brute(ov, k: int):
    nm = ov.nm
    seg = ov.free[int(nm.cluster_lo[k]) : int(nm.cluster_hi[k])]
    gpn = int(nm.cluster_gpn[k])
    empty = int(np.count_nonzero(seg == gpn))
    part = seg[seg < gpn]
    return empty, (int(part.max()) if part.size else 0)


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 100_000), n_ops=st.integers(1, 40))
def test_overlay_hist_stats_match_brute_force(seed, n_ops):
    """The incrementally-maintained (empty, maxp) stats agree with a
    rescan of the free-count segment after every fit/release/undo."""
    rng = np.random.Generator(np.random.Philox(seed))
    nm = _toy_map()
    placed = _occupy(nm, rng, 8)
    ov = nm.overlay()
    next_row = 100
    fits = []
    for _ in range(n_ops):
        op = int(rng.integers(0, 3))
        if op == 0:
            k = int(rng.integers(0, nm.n_clusters))
            free = int(ov.cfree[k])
            if free > 0:
                ov.fit_any(next_row, k, int(rng.integers(1, free + 1)))
                fits.append(len(ov.assigns) - 1)
                next_row += 1
        elif op == 1 and placed:
            ov.release_row(placed.pop())
        elif op == 2 and fits:
            idx = fits.pop(int(rng.integers(0, len(fits))))
            ov.undo(idx)
        for k in range(nm.n_clusters):
            assert ov._stats(k) == _stats_brute(ov, k), (seed, k)
        assert bool(ov.feasible(0, 8)) == (
            _stats_brute(ov, 0)[0] >= 1
        )  # whole-node gang sanity


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 100_000), n_fits=st.integers(1, 24))
def test_fit_batch_replays_sequential_fit_any(seed, n_fits):
    rng = np.random.Generator(np.random.Philox(seed))
    nm = _toy_map(caps=(64, 48, 20))
    _occupy(nm, rng, int(rng.integers(0, 6)))
    # one shared request sequence; runs of whole-node shapes appear often
    reqs = []
    a, b = nm.overlay(), nm.overlay()
    for t in range(n_fits):
        k = int(rng.integers(0, nm.n_clusters))
        gpn = int(nm.cluster_gpn[k])
        free = int(a.cfree[k])
        if free <= 0:
            continue
        if rng.random() < 0.6:  # whole-node gang (exercises the run path)
            w = int(rng.integers(1, max(1, free // gpn) + 1))
            g = min(free, w * gpn)
            if g == 0 or g % gpn:
                g = min(free, gpn) if free >= gpn else free
        else:
            g = int(rng.integers(1, free + 1))
        reqs.append((200 + t, k, g))
        a.fit_any(200 + t, k, g)
    if not reqs:
        return
    rows = np.array([r for r, _, _ in reqs], np.int64)
    ks = np.array([k for _, k, _ in reqs], np.int64)
    gs = np.array([g for _, _, g in reqs], np.int64)
    b.fit_batch(rows, ks, gs)
    assert a.assigns == b.assigns
    assert (a.free == b.free).all()
    assert (a.cfree == b.cfree).all()
    for k in range(nm.n_clusters):
        assert a._stats(k) == b._stats(k)


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_release_rows_replays_sequential_release_row(seed):
    rng = np.random.Generator(np.random.Philox(seed))
    nm = _toy_map(caps=(64, 48))
    placed = _occupy(nm, rng, 10)
    if not placed:
        return
    take = [r for r in placed if rng.random() < 0.7] or placed[:1]
    a, b = nm.overlay(), nm.overlay()
    for r in take:
        a.release_row(r)
    b.release_rows(np.asarray(take, np.int64))
    assert a.released == b.released
    assert all(isinstance(r, int) for r in b.released)
    assert (a.free == b.free).all()
    assert (a.cfree == b.cfree).all()
    for k in range(nm.n_clusters):
        assert a._stats(k) == b._stats(k)


def test_fit_any_scattered_order_is_stable():
    """Equal-sized holes fill lowest node index first: the tie-break is
    an explicit stable sort, pinned here because the committed decision
    digests depend on it."""
    nm = _toy_map(caps=(32,), gpn=8)
    nm.assign(0, [0, 1, 2, 3], [3, 3, 3, 3])  # four equal 5-GPU holes
    ov = nm.overlay()
    assert not ov.feasible(0, 12)  # no empty node: scattered path
    ov.fit_any(9, 0, 12)
    row, nodes, gpus = ov.assigns[0]
    assert (row, nodes, gpus) == (9, [0, 1, 2], [5, 5, 2])


# ----------------------------------------------- degenerate gang helpers
@settings(max_examples=150, deadline=None)
@given(
    demand=st.integers(0, 64),
    min_gpus=st.integers(0, 160),
    gpn=st.integers(1, 16),
)
def test_degenerate_gang_helpers_clamp(demand, min_gpus, gpn):
    d = max(1, demand)
    lo = max(1, min_gpus)
    fg = floor_gang(demand, min_gpus)
    mp = min_piece(demand, min_gpus, gpn)
    if lo > d:
        # no admissible world size: never a gang beyond demand, and no
        # sub-node hole is ever usable by this shape
        assert fg == 0
        assert mp == gpn
        return
    # brute force over the compatible ladder
    compat = sorted(v for v in gang_values(d, lo, 2 * d) if v >= lo)
    divs_ge = [v for v in splice_divisors(d) if v >= lo]
    assert fg == (divs_ge[0] if divs_ge else 0)
    assert fg <= d
    pieces = [g if g < gpn else (g % gpn or gpn) for g in compat]
    assert mp == min([gpn] + pieces)


# ------------------------------- undo x compaction x release_row survival
def test_undo_tombstones_survive_pool_compaction():
    """A mid-decide plan full of releases and undone fits commits through
    release_many/assign_many while the span pool compacts underneath:
    released rows must stay dead, undone fits must never materialize."""
    nm = _toy_map(caps=(64,), gpn=8, rows=2)  # tiny pool: compaction soon
    for row in range(6):
        nm.auto_fit(row, 0, 8)
    # churn to build garbage so the commit's _pool_reserve compacts
    for _ in range(6):
        for row in range(6):
            nm.release(row)
        for row in range(6):
            nm.auto_fit(row, 0, 8)
    ov = nm.overlay()
    ov.release_row(0)
    ov.release_row(2)
    ov.fit_any(0, 0, 8)  # refit row 0 ...
    ov.fit_any(10, 0, 8)
    ov.undo(0)  # ... then change our mind: row 0 stays released
    ov.fit_any(11, 0, 4)
    assert ov.assigns[0] is None
    assigns = [a for a in ov.assigns if a is not None]
    nm.release_many(np.asarray(ov.released, np.int64))
    nm.assign_many(assigns)
    nm.check()
    assert not nm.has_span(0)  # the undone fit did not resurrect row 0
    assert not nm.has_span(2)
    assert nm.span_total(10) == 8
    assert nm.span_total(11) == 4
    # force compaction explicitly; survivors must be byte-identical
    before = {r: tuple(map(tuple, nm.row_pieces(r))) for r in (1, 3, 4, 5, 10, 11)}
    nm._compact()
    nm.check()
    assert not nm.has_span(0) and not nm.has_span(2)
    for r, pieces in before.items():
        assert tuple(map(tuple, nm.row_pieces(r))) == pieces


def test_release_many_assign_many_match_sequential():
    rng = np.random.Generator(np.random.Philox(7))
    seq = _toy_map(caps=(64, 48), rows=4)
    bat = _toy_map(caps=(64, 48), rows=4)
    for nmx in (seq, bat):
        r = np.random.Generator(np.random.Philox(3))
        _occupy(nmx, r, 8)
    live = sorted(int(r) for r in seq.live_rows())
    rel = [r for r in live if rng.random() < 0.5]
    for r in rel:
        seq.release(r)
    bat.release_many(np.asarray(rel, np.int64))
    # build the plan against the (identical) post-release free state
    plan = []
    for t, node in enumerate(np.flatnonzero(seq.node_free > 0)[:5]):
        plan.append((50 + t, [int(node)], [1]))
    for r, nodes, gpus in plan:
        seq.assign(r, nodes, gpus)
    bat.assign_many(plan)
    seq.check()
    bat.check()
    assert (seq.node_free == bat.node_free).all()
    assert (seq.node_used == bat.node_used).all()
    for r in list(live) + [p[0] for p in plan]:
        a = tuple(map(tuple, seq.row_pieces(r)))
        b = tuple(map(tuple, bat.row_pieces(r)))
        assert a == b

"""Device proxy (§3, §4.2): handle virtualization, log & replay."""
import numpy as np

from repro.core.device_proxy import DeviceProxyClient, DeviceProxyServer


def _session():
    server = DeviceProxyServer(1 << 20)
    client = DeviceProxyClient(server)
    stream = client.call("create_stream")
    event = client.call("create_event")
    comm = client.call("create_communicator", 4, 0)
    buf = client.call("malloc", 1024, True)
    client.call("memcpy_h2d", buf, np.arange(256, dtype=np.float32))
    return server, client, stream, event, comm, buf


def test_virtual_handles_stable_across_restore():
    server, client, stream, event, comm, buf = _session()
    state = client.snapshot_device_state()
    old_phys = dict(client.v2p)

    fresh = DeviceProxyServer(1 << 20, device_id=1)
    client.restore(fresh, state)

    # virtual handles unchanged, physical handles remapped
    assert set(client.v2p) == set(old_phys)
    data = client.call("memcpy_d2h", buf)
    np.testing.assert_array_equal(data, np.arange(256, dtype=np.float32))
    # stateful objects were replayed on the fresh server
    assert len(fresh.streams) == 1
    assert len(fresh.communicators) == 1


def test_stable_buffers_same_address_after_restore():
    """The mmap SA_Int maps stable buffers at the same device address, so
    host-held device pointers stay valid (§4.2)."""
    server, client, *_, buf = _session()
    addr_before = client.v2p[buf]
    state = client.snapshot_device_state()
    fresh = DeviceProxyServer(1 << 20)
    client.restore(fresh, state)
    assert client.v2p[buf] == addr_before


def test_log_compaction_drops_freed_mallocs():
    server = DeviceProxyServer(1 << 20)
    client = DeviceProxyClient(server)
    keep = client.call("malloc", 64, True)
    drop = client.call("malloc", 64, False)
    client.call("free", drop)
    entries = client.compact_log()
    mallocs = [e for e in entries if e.api == "malloc"]
    assert len(mallocs) == 1 and mallocs[0].virtual_handle == keep


def test_kernel_launch_executes_on_server_memory():
    server = DeviceProxyServer(1 << 20)
    client = DeviceProxyClient(server)
    a = client.call("malloc", 64, False)
    o = client.call("malloc", 64, False)
    client.call("memcpy_h2d", a, np.full(16, 2.0, np.float32))
    client.call("launch_kernel", lambda x: x * 3.0,
                (client.v2p[a],), (client.v2p[o],))
    np.testing.assert_allclose(client.call("memcpy_d2h", o), 6.0)
    assert server.kernel_launches == 1


def test_file_io_tracking():
    client = DeviceProxyClient(DeviceProxyServer(1 << 10))
    client.open_file("/tmp/x", "r")
    client.open_file("/tmp/y", "w")
    client.open_file("/tmp/z", "a+")
    assert client.written_files == ["/tmp/y", "/tmp/z"]

"""Bidirectional allocator (§5.2.2): stable-address invariant."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.buffers import DeviceMemory, OutOfMemory


@settings(max_examples=40, deadline=None)
@given(stable_sizes=st.lists(st.integers(1, 64).map(lambda x: x * 16),
                             min_size=1, max_size=8),
       transient_a=st.lists(st.integers(1, 32).map(lambda x: x * 8),
                            min_size=0, max_size=8),
       transient_b=st.lists(st.integers(1, 32).map(lambda x: x * 8),
                            min_size=0, max_size=8),
       seed=st.integers(0, 1000))
def test_stable_addresses_invariant_to_transient_interleaving(
        stable_sizes, transient_a, transient_b, seed):
    """Two replicas perform the SAME stable allocation sequence but
    arbitrarily different transient allocations — stable buffers must land
    at identical addresses (the paper's consistent-allocation property)."""
    rng = np.random.Generator(np.random.Philox(seed))

    def run(transients):
        mem = DeviceMemory(1 << 20)
        stable_addrs = []
        t_queue = list(transients)
        live_transients = []
        for size in stable_sizes:
            # random transient churn between stable allocations
            while t_queue and rng.random() < 0.6:
                b = mem.alloc(t_queue.pop(), stable=False)
                live_transients.append(b.addr)
            if live_transients and rng.random() < 0.5:
                mem.free(live_transients.pop())
            stable_addrs.append(mem.alloc(size, stable=True).addr)
        return stable_addrs

    a = run(transient_a)
    b = run(transient_b)
    assert a == b


def test_regions_never_collide():
    mem = DeviceMemory(1024)
    s = mem.alloc(256, stable=True)
    t = mem.alloc(256, stable=False)
    assert t.addr + t.size <= s.addr
    with pytest.raises(OutOfMemory):
        mem.alloc(1024, stable=False)


def test_lazy_free_content_cached():
    mem = DeviceMemory(1024)
    b = mem.alloc(64, stable=True)
    mem.write(b.addr, np.arange(16, dtype=np.float32))
    cs = b.checksum()
    mem.free(b.addr, lazy=True)
    found = mem.find_by_checksum(cs)
    assert found is not None          # opportunistically cached (§5.2.1)


def test_transient_reclaim():
    mem = DeviceMemory(1024)
    a = mem.alloc(512, stable=False)
    mem.free(a.addr)
    b = mem.alloc(1024 - 16, stable=False)   # fits again after reclaim
    assert b.addr == 0

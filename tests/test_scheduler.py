"""Hierarchical scheduler + GPU-fraction SLA (§2.5, Table 1)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sla import TIERS, GpuFractionAccount
from repro.scheduler.costs import CostModel, default_checkpoint_bytes
from repro.scheduler.policy import ElasticPolicy, StaticGangPolicy
from repro.scheduler.simulator import (FleetSimulator, SimConfig, make_fleet,
                                       synth_workload)
from repro.scheduler.types import Cluster, Fleet, Job, Region


# --------------------------------------------------------------------- SLA
def test_gpu_fraction_accounting():
    acc = GpuFractionAccount("standard", demand_gpus=8)
    acc.record(0, 1800, 8)       # half hour full
    acc.record(1800, 3600, 4)    # half hour at half
    assert abs(acc.fraction(0, 3600) - 0.75) < 1e-9
    assert not acc.violated(3600)        # 0.75 >= 0.70
    acc.record(3600, 7200, 0)            # an hour starved
    assert acc.violated(7200)


def test_tier_table_matches_paper():
    assert TIERS["premium"].gpu_fraction == 0.95
    assert TIERS["standard"].gpu_fraction == 0.70
    assert TIERS["basic"].gpu_fraction == 0.0
    # preemption order: basic first, premium last
    assert TIERS["basic"].preempt_priority < TIERS["standard"].preempt_priority \
        < TIERS["premium"].preempt_priority


# ------------------------------------------------------------------ policy
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n_jobs=st.integers(5, 40))
def test_allocations_never_exceed_capacity(seed, n_jobs):
    fleet = make_fleet()
    jobs = synth_workload(n_jobs, fleet.total(), seed=seed)
    pol = ElasticPolicy()
    for j in jobs:
        j.arrival = 0.0
    decision = pol.decide(0.0, jobs, fleet)
    total = sum(g for g, _ in decision.alloc.values())
    assert total <= fleet.total()
    # per-cluster placements fit
    per_cluster = {}
    for jid, (g, c) in decision.alloc.items():
        if c is not None:
            per_cluster[c] = per_cluster.get(c, 0) + g
    caps = {c.id: c.total_gpus for c in fleet.clusters()}
    for c, used in per_cluster.items():
        assert used <= caps[c], (c, used)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_no_job_below_zero_floor(seed):
    """ZeRO partial sharding floor: a job is preempted rather than spliced
    below min_gpus (§5.4)."""
    fleet = make_fleet()
    jobs = synth_workload(30, fleet.total(), seed=seed)
    for j in jobs:
        j.arrival = 0.0
    decision = ElasticPolicy().decide(0.0, jobs, fleet)
    for jid, (g, _) in decision.alloc.items():
        job = next(j for j in jobs if j.id == jid)
        assert g == 0 or g >= job.min_gpus


def test_elastic_beats_static_on_utilization():
    """The paper's headline: preemptible+elastic scheduling drives higher
    aggregate utilization than static gang scheduling."""
    results = {}
    for pol in (StaticGangPolicy(), ElasticPolicy()):
        sim = FleetSimulator(make_fleet(), synth_workload(120, 2048, seed=11),
                             pol, SimConfig(horizon_seconds=36 * 3600))
        results[pol.name] = sim.run()
    assert results["elastic"].utilization > results["static"].utilization
    assert results["elastic"].gpu_seconds_idle < results["static"].gpu_seconds_idle
    # mechanisms actually exercised
    assert results["elastic"].resizes > 0
    assert results["elastic"].migrations > 0
    assert results["static"].preemptions == 0


def test_premium_sla_protected():
    sims = {}
    for pol in (StaticGangPolicy(), ElasticPolicy()):
        sim = FleetSimulator(make_fleet(), synth_workload(120, 2048, seed=11),
                             pol, SimConfig(horizon_seconds=36 * 3600))
        sims[pol.name] = sim.run()
    assert sims["elastic"].sla_attainment["premium"] >= \
        sims["static"].sla_attainment["premium"]


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_preemptions_only_for_running_jobs(seed):
    """A queued job whose tentative allocation is zeroed was never running:
    it must not surface as a preemption event."""
    fleet = make_fleet()
    jobs = synth_workload(30, fleet.total(), seed=seed)
    for j in jobs:
        j.arrival = 0.0
    decision = ElasticPolicy().decide(0.0, jobs, fleet)
    for jid in decision.preemptions:
        job = next(j for j in jobs if j.id == jid)
        assert job.allocated > 0, f"{jid} preempted but was never running"


def test_expansion_never_partially_admits():
    """Regression: opportunistic expansion used to hand spare capacity to a
    guaranteed job the all-or-nothing pass skipped, admitting it below its
    guarantee (and below min_gpus, triggering a spurious 'preemption')."""
    fleet = Fleet([Region("r0", [Cluster("r0c0", "r0", 100)])])
    big = Job(id="big", tier="premium", demand_gpus=200, gpu_hours=100.0,
              arrival=0.0, min_gpus=150)
    decision = ElasticPolicy().decide(0.0, [big], fleet)
    g, _ = decision.alloc["big"]
    assert g == 0, "guarantee-skipped job must stay queued, not partial"
    assert decision.preemptions == []


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_guaranteed_slice_before_expansion(seed):
    """No job is expanded beyond its demand while an admitted guaranteed
    job sits below its full demand."""
    fleet = make_fleet()
    jobs = synth_workload(25, fleet.total(), seed=seed)
    for j in jobs:
        j.arrival = 0.0
    decision = ElasticPolicy().decide(0.0, jobs, fleet)
    by_id = {j.id: j for j in jobs}
    expanded = [jid for jid, (g, _) in decision.alloc.items()
                if g > by_id[jid].demand_gpus]
    if expanded:
        for jid, (g, _) in decision.alloc.items():
            j = by_id[jid]
            if TIERS[j.tier].gpu_fraction > 0 and 0 < g < j.demand_gpus:
                # a shrunk guaranteed job may coexist with expansion only
                # if placement fragmentation forced the shrink; it must
                # still be at or above its splice floor
                assert g >= j.min_gpus


# ------------------------------------------------------------------ costs
def test_costs_are_consumed():
    """A sim with free mechanisms vs Table-5 costs must differ measurably:
    charged downtime shows up as dead GPU time, lower utilization, and
    per-tier downtime in SimResult (the seed declared a migration cost and
    never charged it)."""
    results = {}
    for label, cost in (("free", 0.0), ("paper", 600.0)):
        sim = FleetSimulator(make_fleet(), synth_workload(120, 2048, seed=11),
                             ElasticPolicy(),
                             SimConfig(horizon_seconds=36 * 3600,
                                       migration_cost_seconds=cost))
        results[label] = sim.run()
    free, paper = results["free"], results["paper"]
    assert free.gpu_seconds_dead == 0.0
    assert paper.gpu_seconds_dead > 0.0
    assert paper.utilization < free.utilization
    assert sum(paper.downtime_by_tier.values()) > 0
    assert not free.downtime_by_tier


def test_downtime_matches_cost_model():
    """Realized downtime must equal the cost model's per-event charges:
    migrations (priced by region pair — the default 2-region fleet has
    exactly one cross pair) + resizes + restores exactly, plus repaid
    preempt debt for at most the number of preemptions."""
    cfg = SimConfig(horizon_seconds=36 * 3600, migration_cost_seconds=60.0)
    sim = FleetSimulator(make_fleet(), synth_workload(120, 2048, seed=7),
                         ElasticPolicy(), cfg)
    res = sim.run()
    costs = sim.costs    # the topology-attached model actually charged
    cb = 0    # uniform model ignores checkpoint bytes
    intra = res.migrations - res.migrations_cross_region
    intra_restores = res.restores - res.restores_cross_region
    floor = (intra * costs.migrate_seconds(cb)
             + res.migrations_cross_region
             * costs.migrate_seconds(cb, "r0", "r1")
             + res.resizes * costs.resize_seconds(cb)
             + intra_restores * costs.restore_seconds(cb)
             + res.restores_cross_region
             * costs.restore_seconds(cb, "r0", "r1"))
    ceil = floor + res.preemptions * costs.preempt_seconds(cb)
    total = sum(j.downtime_seconds for j in sim.jobs.values())
    assert floor - 1e-6 <= total <= ceil + 1e-6, (floor, total, ceil)
    assert abs(sum(res.downtime_by_tier.values()) - total) < 1e-6
    # cross-region migrations are strictly pricier than intra ones
    assert costs.migrate_seconds(cb, "r0", "r1") > costs.migrate_seconds(cb)


def test_elastic_beats_static_with_costs_charged():
    """Regression pin for the paper's claim: elastic scheduling stays ahead
    of static DESPITE paying real preemption/migration/resize costs."""
    results = {}
    for pol in (StaticGangPolicy(), ElasticPolicy()):
        sim = FleetSimulator(make_fleet(), synth_workload(120, 2048, seed=3),
                             pol, SimConfig(horizon_seconds=36 * 3600,
                                            migration_cost_seconds=120.0))
        results[pol.name] = sim.run()
    assert results["elastic"].utilization > results["static"].utilization


def test_derived_cost_model_scales_with_checkpoint_size():
    cm = CostModel()
    small, large = 1 << 30, 64 << 30
    assert cm.migrate_seconds(large) > cm.migrate_seconds(small)
    assert cm.preempt_seconds(small) > 0
    # resize is in-place: no blob round trip, independent of bytes
    assert cm.resize_seconds(large) == cm.resize_seconds(small)
    assert CostModel.free().migrate_seconds(large) == 0.0
    assert default_checkpoint_bytes(256) > default_checkpoint_bytes(8)


# -------------------------------------------------------------- simulator
def test_vectorized_matches_legacy_loop():
    """The numpy event loop and the seed-style per-event loop must tell the
    same macro story on the same trace."""
    res = {}
    for vec in (True, False):
        sim = FleetSimulator(make_fleet(), synth_workload(60, 2048, seed=5),
                             ElasticPolicy(),
                             SimConfig(horizon_seconds=24 * 3600,
                                       vectorized=vec))
        res[vec] = sim.run()
    assert abs(res[True].utilization - res[False].utilization) < 0.05
    assert abs(res[True].completed - res[False].completed) <= 3
    assert (res[True].gpu_seconds_dead > 0) == (res[False].gpu_seconds_dead > 0)


def test_capacity_conservation_enforced():
    """The simulator's conservation check rejects an over-allocating
    policy."""

    class OverAllocator:
        name = "over"

        def decide(self, now, jobs, fleet):
            from repro.scheduler.policy import Decision
            alloc = {j.id: (j.demand_gpus, fleet.clusters()[0].id)
                     for j in jobs if j.done_at is None}
            return Decision(alloc=alloc, preemptions=[], migrations=[])

    fleet = Fleet([Region("r0", [Cluster("r0c0", "r0", 8)])])
    jobs = [Job(id=f"j{i}", tier="basic", demand_gpus=8, gpu_hours=1.0,
                arrival=0.0) for i in range(3)]
    sim = FleetSimulator(fleet, jobs, OverAllocator(),
                         SimConfig(horizon_seconds=3600))
    with pytest.raises(AssertionError):
        sim.run()


def test_job_rate_model():
    j = Job(id="x", tier="standard", demand_gpus=8, gpu_hours=8.0, arrival=0)
    j.allocated = 8
    full = j.rate()
    j.allocated = 4
    half = j.rate()
    assert half < full
    # splicing overhead applies when scaled down
    assert abs(half / full - 0.5 * (1 - j.splice_overhead)) < 1e-9

"""Hierarchical scheduler + GPU-fraction SLA (§2.5, Table 1)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sla import HOUR, TIERS, GpuFractionAccount
from repro.scheduler.policy import ElasticPolicy, StaticGangPolicy
from repro.scheduler.simulator import (FleetSimulator, SimConfig, make_fleet,
                                       synth_workload)
from repro.scheduler.types import Fleet, Job


# --------------------------------------------------------------------- SLA
def test_gpu_fraction_accounting():
    acc = GpuFractionAccount("standard", demand_gpus=8)
    acc.record(0, 1800, 8)       # half hour full
    acc.record(1800, 3600, 4)    # half hour at half
    assert abs(acc.fraction(0, 3600) - 0.75) < 1e-9
    assert not acc.violated(3600)        # 0.75 >= 0.70
    acc.record(3600, 7200, 0)            # an hour starved
    assert acc.violated(7200)


def test_tier_table_matches_paper():
    assert TIERS["premium"].gpu_fraction == 0.95
    assert TIERS["standard"].gpu_fraction == 0.70
    assert TIERS["basic"].gpu_fraction == 0.0
    # preemption order: basic first, premium last
    assert TIERS["basic"].preempt_priority < TIERS["standard"].preempt_priority \
        < TIERS["premium"].preempt_priority


# ------------------------------------------------------------------ policy
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n_jobs=st.integers(5, 40))
def test_allocations_never_exceed_capacity(seed, n_jobs):
    fleet = make_fleet()
    jobs = synth_workload(n_jobs, fleet.total(), seed=seed)
    pol = ElasticPolicy()
    for j in jobs:
        j.arrival = 0.0
    decision = pol.decide(0.0, jobs, fleet)
    total = sum(g for g, _ in decision.alloc.values())
    assert total <= fleet.total()
    # per-cluster placements fit
    per_cluster = {}
    for jid, (g, c) in decision.alloc.items():
        if c is not None:
            per_cluster[c] = per_cluster.get(c, 0) + g
    caps = {c.id: c.total_gpus for c in fleet.clusters()}
    for c, used in per_cluster.items():
        assert used <= caps[c], (c, used)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_no_job_below_zero_floor(seed):
    """ZeRO partial sharding floor: a job is preempted rather than spliced
    below min_gpus (§5.4)."""
    fleet = make_fleet()
    jobs = synth_workload(30, fleet.total(), seed=seed)
    for j in jobs:
        j.arrival = 0.0
    decision = ElasticPolicy().decide(0.0, jobs, fleet)
    for jid, (g, _) in decision.alloc.items():
        job = next(j for j in jobs if j.id == jid)
        assert g == 0 or g >= job.min_gpus


def test_elastic_beats_static_on_utilization():
    """The paper's headline: preemptible+elastic scheduling drives higher
    aggregate utilization than static gang scheduling."""
    results = {}
    for pol in (StaticGangPolicy(), ElasticPolicy()):
        sim = FleetSimulator(make_fleet(), synth_workload(120, 2048, seed=11),
                             pol, SimConfig(horizon_seconds=36 * 3600))
        results[pol.name] = sim.run()
    assert results["elastic"].utilization > results["static"].utilization
    assert results["elastic"].gpu_seconds_idle < results["static"].gpu_seconds_idle
    # mechanisms actually exercised
    assert results["elastic"].resizes > 0
    assert results["elastic"].migrations > 0
    assert results["static"].preemptions == 0


def test_premium_sla_protected():
    sims = {}
    for pol in (StaticGangPolicy(), ElasticPolicy()):
        sim = FleetSimulator(make_fleet(), synth_workload(120, 2048, seed=11),
                             pol, SimConfig(horizon_seconds=36 * 3600))
        sims[pol.name] = sim.run()
    assert sims["elastic"].sla_attainment["premium"] >= \
        sims["static"].sla_attainment["premium"]


def test_job_rate_model():
    j = Job(id="x", tier="standard", demand_gpus=8, gpu_hours=8.0, arrival=0)
    j.allocated = 8
    full = j.rate()
    j.allocated = 4
    half = j.rate()
    assert half < full
    # splicing overhead applies when scaled down
    assert abs(half / full - 0.5 * (1 - j.splice_overhead)) < 1e-9

"""Fairness aging: long-queued guaranteed jobs must not starve forever
behind expensive-to-stop running peers under permanent overload — and
aging must be a strict no-op when the queue drains.
"""
import hashlib

from repro.scheduler.costs import CostModel
from repro.scheduler.policy import ElasticPolicy
from repro.scheduler.simulator import (
    FleetSimulator,
    SimConfig,
    make_fleet,
    synth_workload,
)
from repro.scheduler.types import Cluster, Fleet, Job, Region

TICK = 300.0
BIG_CKPT = 64 << 30  # expensive to stop: high victim cost protects hogs


def _overloaded_sim(
    aging_rate: float,
    horizon: float,
    vectorized: bool = True,
    job_table: bool = True,
):
    """One 64-GPU cluster permanently saturated by two never-finishing
    premium hogs with huge checkpoints; a same-shape premium job arrives
    at t=300 and queues behind them."""
    fleet = Fleet([Region("r0", [Cluster("r0c0", "r0", 64)])])
    jobs = []
    for k in range(2):
        jobs.append(
            Job(
                id=f"hog{k}",
                tier="premium",
                demand_gpus=32,
                gpu_hours=32 * 1000.0,  # never finishes inside the horizon
                arrival=0.0,
                min_gpus=32,  # cannot shrink: preemption is the only yield
                checkpoint_bytes=BIG_CKPT,
            )
        )
    jobs.append(
        Job(
            id="waiter",
            tier="premium",
            demand_gpus=32,
            gpu_hours=32 * 1000.0,
            arrival=300.0,
            min_gpus=32,
            checkpoint_bytes=BIG_CKPT,
        )
    )
    policy = ElasticPolicy(
        expand_factor=1.0, aging_rate=aging_rate, vectorized=vectorized
    )
    sim = FleetSimulator(
        fleet,
        jobs,
        policy,
        SimConfig(
            horizon_seconds=horizon,
            tick_seconds=TICK,
            cost_model=CostModel(),
            job_table=job_table,
        ),
    )
    return sim, sim.run()


def test_aged_premium_job_admitted_within_bounded_intervals():
    """The waiter outranks a hog once its aging bonus exceeds the hog's
    preempt+restore downtime: admission within threshold intervals plus
    vcost/aging_rate seconds, NOT unbounded starvation."""
    policy_defaults = ElasticPolicy()
    vcost = CostModel().preempt_seconds(BIG_CKPT) + CostModel().restore_seconds(
        BIG_CKPT
    )
    bound_ticks = (
        policy_defaults.aging_threshold_intervals
        + vcost / policy_defaults.aging_rate / TICK
        + 2.0
    )
    horizon = 300.0 + bound_ticks * TICK
    sim, res = _overloaded_sim(aging_rate=1.0, horizon=horizon)
    waiter = sim.jobs["waiter"]
    assert waiter.ever_ran, "aged premium job still starving past the bound"
    assert waiter.progress > 0.0
    assert res.preemptions >= 1  # a hog was rotated out to make room


def test_without_aging_the_queued_job_starves():
    """Same fleet, aging disabled: victim ranking alone keeps the
    expensive hogs running and the waiter starves indefinitely."""
    sim, res = _overloaded_sim(aging_rate=0.0, horizon=8 * 3600.0)
    waiter = sim.jobs["waiter"]
    assert not waiter.ever_ran
    assert waiter.progress == 0.0
    assert res.preemptions == 0


def test_aging_identical_across_vectorized_and_scalar_paths():
    """The aging term must not break the decision-hash equivalence gate:
    both policy paths age identically under permanent overload."""
    digests = {}
    for vectorized in (True, False):
        sim, _ = _overloaded_sim(
            aging_rate=1.0, horizon=6 * 3600.0, vectorized=vectorized
        )
        digest = hashlib.sha256()
        # replay-free check: hash the per-job terminal state instead of
        # decisions (the sims above already ran); allocation trajectory
        # divergence would surface here as different counters
        for jid in sorted(sim.jobs):
            j = sim.jobs[jid]
            digest.update(
                repr(
                    (jid, j.allocated, j.preemptions, j.resizes, j.progress)
                ).encode()
            )
        digests[vectorized] = digest.hexdigest()
    assert digests[True] == digests[False]


def _two_tier_overload(aging_rate, horizon: float, vectorized: bool = True):
    """One saturated 64-GPU cluster: two premium hogs, plus one premium
    and one standard waiter queueing behind them — per-tier aging rates
    decide who gets rotated in."""
    fleet = Fleet([Region("r0", [Cluster("r0c0", "r0", 64)])])
    jobs = []
    for k in range(2):
        jobs.append(
            Job(
                id=f"hog{k}",
                tier="premium",
                demand_gpus=32,
                gpu_hours=32 * 1000.0,
                arrival=0.0,
                min_gpus=32,
                checkpoint_bytes=BIG_CKPT,
            )
        )
    for tier in ("premium", "standard"):
        jobs.append(
            Job(
                id=f"wait_{tier}",
                tier=tier,
                demand_gpus=32,
                gpu_hours=32 * 1000.0,
                arrival=300.0,
                min_gpus=32,
                checkpoint_bytes=BIG_CKPT,
            )
        )
    policy = ElasticPolicy(
        expand_factor=1.0, aging_rate=aging_rate, vectorized=vectorized
    )
    sim = FleetSimulator(
        fleet,
        jobs,
        policy,
        SimConfig(horizon_seconds=horizon, tick_seconds=TICK, cost_model=CostModel()),
    )
    return sim, sim.run()


def test_per_tier_rates_age_premium_ahead_of_standard():
    """With premium aging 8x faster than standard, the premium waiter is
    rotated in while the (equally starved) standard waiter still queues;
    a tier absent from the mapping never ages at all."""
    sim, res = _two_tier_overload(
        {"premium": 8.0, "standard": 0.1}, horizon=10 * 3600.0
    )
    assert sim.jobs["wait_premium"].ever_ran
    assert not sim.jobs["wait_standard"].ever_ran
    assert res.preemptions >= 1
    # standard missing from the map == standard never ages
    sim2, _ = _two_tier_overload({"standard": 0.0}, horizon=10 * 3600.0)
    assert not sim2.jobs["wait_standard"].ever_ran


def test_per_tier_rates_keep_vectorized_scalar_equivalence():
    """The decision-hash gate must hold with a per-tier rate mapping."""
    digests = {}
    for vectorized in (True, False):
        sim, _ = _two_tier_overload(
            {"premium": 4.0, "standard": 0.5},
            horizon=8 * 3600.0,
            vectorized=vectorized,
        )
        digest = hashlib.sha256()
        for jid in sorted(sim.jobs):
            j = sim.jobs[jid]
            digest.update(
                repr(
                    (jid, j.allocated, j.preemptions, j.resizes, j.progress)
                ).encode()
            )
        digests[vectorized] = digest.hexdigest()
    assert digests[True] == digests[False]


def test_scalar_rate_is_equivalent_to_uniform_mapping():
    """Back-compat: a float rate and the equivalent per-tier mapping
    produce identical runs."""
    for vectorized in (True, False):
        a, res_a = _two_tier_overload(1.0, horizon=8 * 3600.0, vectorized=vectorized)
        b, res_b = _two_tier_overload(
            {"premium": 1.0, "standard": 1.0, "basic": 1.0},
            horizon=8 * 3600.0,
            vectorized=vectorized,
        )
        assert res_a.preemptions == res_b.preemptions
        assert res_a.utilization == res_b.utilization
        for jid in a.jobs:
            assert a.jobs[jid].allocated == b.jobs[jid].allocated
            assert a.jobs[jid].progress == b.jobs[jid].progress


def test_queued_since_reset_propagates_through_table_views():
    """``Job.queued_since`` is reset by the simulator's preemption path;
    with the JobTable on, the reset is a column write read back through
    the view — the aging clock (and therefore every subsequent rotation
    decision) must match the scalar-job run tick for tick."""
    runs = {}
    for job_table in (True, False):
        sim, res = _overloaded_sim(
            aging_rate=1.0, horizon=8 * 3600.0, job_table=job_table
        )
        runs[job_table] = (
            res.preemptions,
            tuple(
                (jid, sim.jobs[jid].queued_since, sim.jobs[jid].allocated)
                for jid in sorted(sim.jobs)
            ),
        )
    assert runs[True][0] >= 1  # rotation actually happened
    assert runs[True] == runs[False]
    # the rotated hog's clock was reset to its preemption tick, not its
    # arrival — visible through the table view exactly as through the
    # plain attribute
    sim, _ = _overloaded_sim(aging_rate=1.0, horizon=8 * 3600.0)
    preempted = [
        j for j in sim.jobs.values() if j.preemptions > 0 and j.id != "waiter"
    ]
    assert preempted and all(j.queued_since > j.arrival for j in preempted)


def test_aging_is_noop_when_queue_drains():
    """On an underloaded fleet every decision with aging enabled equals
    the decision without it — aging only reorders under starvation."""
    digests = {}
    for rate in (1.0, 0.0):
        fleet = make_fleet()
        jobs = synth_workload(40, fleet.total(), seed=21)
        policy = ElasticPolicy(aging_rate=rate)
        digest = hashlib.sha256()

        class _Rec:
            name = "rec"

            def bind_costs(self, cm, ih):
                policy.bind_costs(cm, ih)

            def decide(self, now, jobs, fleet):
                decision = policy.decide(now, jobs, fleet)
                digest.update(
                    repr(
                        (
                            sorted(decision.alloc.items()),
                            decision.preemptions,
                            decision.migrations,
                        )
                    ).encode()
                )
                return decision

        FleetSimulator(
            fleet, jobs, _Rec(), SimConfig(horizon_seconds=24 * 3600.0)
        ).run()
        digests[rate] = digest.hexdigest()
    assert digests[1.0] == digests[0.0]

"""Fleet executor: the scheduler driving REAL elastic jobs end-to-end."""
from repro.scheduler.executor import FleetExecutor, ManagedJob


def test_tiered_fleet_with_real_preemption_and_resume():
    """2 slots, a basic job running, then a premium job arrives and takes
    the whole fleet: the basic job is REALLY barrier-quiesced, checkpointed
    and later restored at the exact step — while the premium job runs."""
    ex = FleetExecutor(total_slots=2)
    ex.submit(ManagedJob(id="basic", tier="basic", arch="olmo-1b",
                         world_size=2, total_steps=8))
    ex.tick(); ex.tick()                    # basic runs at full scale
    basic = ex.jobs["basic"]
    assert basic.allocated == 2 and basic.steps_done >= 2

    ex.submit(ManagedJob(id="prem", tier="premium", arch="mamba2-130m",
                         world_size=2, total_steps=4))
    ex.tick()                               # premium preempts basic
    assert ex.jobs["prem"].allocated == 2
    assert basic.allocated == 0 and basic.preemptions == 1
    step_at_preempt = basic.steps_done

    log = ex.run(max_ticks=30)
    assert all(j.done for j in ex.jobs.values())
    events = [e["event"] for e in log]
    assert "preempt" in events and "restore" in events
    restore = next(e for e in log if e["event"] == "restore")
    assert restore["at_step"] == step_at_preempt   # zero lost work
    assert basic.steps_done == 8


def test_shrink_before_preempt():
    """A standard job shrinks (splice) rather than being evicted when a
    same-capacity premium job arrives on a 4-slot fleet."""
    ex = FleetExecutor(total_slots=4)
    ex.submit(ManagedJob(id="std", tier="standard", arch="mamba2-130m",
                         world_size=4, total_steps=6))
    ex.tick()
    assert ex.jobs["std"].allocated == 4
    ex.submit(ManagedJob(id="prem", tier="premium", arch="mamba2-130m",
                         world_size=2, total_steps=4))
    ex.tick()
    std = ex.jobs["std"]
    assert ex.jobs["prem"].allocated == 2
    assert std.allocated == 2 and std.resizes == 1      # shrunk, not killed
    ex.run(max_ticks=30)
    assert std.done and std.steps_done == 6


def test_executor_shadows_live_in_job_table_and_resets_propagate():
    """The executor's shadow jobs are JobTable views on the same table
    the policy slices: a REAL preemption must reset the shadow's
    ``queued_since`` (fairness aging clock) and carry the restore debt
    through the table columns, an injected failure must roll the shadow
    back through the view, and completion must detach it and free the
    row for reuse."""
    from repro.scheduler.job_table import TableJob

    ex = FleetExecutor(total_slots=2)
    ex.submit(ManagedJob(id="job", tier="standard", arch="mamba2-130m",
                         world_size=2, total_steps=8))
    shadow = ex._shadows["job"]
    assert isinstance(shadow, TableJob)
    assert shadow._table is ex.table and ex.table.slots_in_use == 1
    ex.tick(); ex.tick()

    # REAL preemption resets the aging clock and books restore debt —
    # written through the view, visible in the columns the policy reads
    ex.submit(ManagedJob(id="prem", tier="premium", arch="mamba2-130m",
                         world_size=2, total_steps=2))
    ex.tick()
    assert ex.jobs["job"].allocated == 0
    assert shadow.queued_since == ex.clock - ex.tick_seconds  # reset at preempt
    assert shadow.restore_debt > 0.0
    assert float(ex.table.queued_since[shadow._slot]) == shadow.queued_since
    assert float(ex.table.restore_debt[shadow._slot]) == shadow.restore_debt

    # unplanned failure: rollback + failure bookkeeping through the view
    for _ in range(10):
        ex.tick()
        if ex.jobs["job"].allocated > 0 and not ex.jobs["job"].done:
            break
    ex.inject_failure("job")
    assert shadow.failed_at == ex.clock and shadow.failures == 1
    assert shadow.restore_debt == 0.0  # no graceful preempt was paid
    assert bool(ex.table.allocated[shadow._slot] == 0)

    # completion detaches the shadow and frees its row for reuse
    ex.run(max_ticks=40)
    assert ex.jobs["job"].done
    assert type(ex._shadows["job"]) is not TableJob
    assert ex.table.slots_in_use == 0
    assert ex._shadows["job"].done_at is not None

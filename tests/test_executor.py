"""Fleet executor: the scheduler driving REAL elastic jobs end-to-end."""
from repro.scheduler.executor import FleetExecutor, ManagedJob


def test_tiered_fleet_with_real_preemption_and_resume():
    """2 slots, a basic job running, then a premium job arrives and takes
    the whole fleet: the basic job is REALLY barrier-quiesced, checkpointed
    and later restored at the exact step — while the premium job runs."""
    ex = FleetExecutor(total_slots=2)
    ex.submit(ManagedJob(id="basic", tier="basic", arch="olmo-1b",
                         world_size=2, total_steps=8))
    ex.tick(); ex.tick()                    # basic runs at full scale
    basic = ex.jobs["basic"]
    assert basic.allocated == 2 and basic.steps_done >= 2

    ex.submit(ManagedJob(id="prem", tier="premium", arch="mamba2-130m",
                         world_size=2, total_steps=4))
    ex.tick()                               # premium preempts basic
    assert ex.jobs["prem"].allocated == 2
    assert basic.allocated == 0 and basic.preemptions == 1
    step_at_preempt = basic.steps_done

    log = ex.run(max_ticks=30)
    assert all(j.done for j in ex.jobs.values())
    events = [e["event"] for e in log]
    assert "preempt" in events and "restore" in events
    restore = next(e for e in log if e["event"] == "restore")
    assert restore["at_step"] == step_at_preempt   # zero lost work
    assert basic.steps_done == 8


def test_shrink_before_preempt():
    """A standard job shrinks (splice) rather than being evicted when a
    same-capacity premium job arrives on a 4-slot fleet."""
    ex = FleetExecutor(total_slots=4)
    ex.submit(ManagedJob(id="std", tier="standard", arch="mamba2-130m",
                         world_size=4, total_steps=6))
    ex.tick()
    assert ex.jobs["std"].allocated == 4
    ex.submit(ManagedJob(id="prem", tier="premium", arch="mamba2-130m",
                         world_size=2, total_steps=4))
    ex.tick()
    std = ex.jobs["std"]
    assert ex.jobs["prem"].allocated == 2
    assert std.allocated == 2 and std.resizes == 1      # shrunk, not killed
    ex.run(max_ticks=30)
    assert std.done and std.steps_done == 6

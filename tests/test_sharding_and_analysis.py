"""Sharding rules + HLO cost analyzer correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo_cost import analyze_hlo, parse_module
from repro.parallel.constraints import constrain
from repro.parallel.sharding import batch_specs, param_specs

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _mesh22():
    if jax.device_count() < 4:
        pytest.skip("needs >=4 devices (dryrun-only)")
    return jax.make_mesh((2, 2), ("data", "model"))


def test_param_specs_megatron_convention():
    """Row-parallel down-projections shard the contracted dim over model."""
    import jax
    # build a fake mesh object via make_mesh only when possible; otherwise
    # emulate with a 1x1 mesh and assert replicated specs
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {
        "blocks": {
            "attn": {"wq": jnp.zeros((4, 128, 8, 16)),
                     "wo": jnp.zeros((4, 8, 16, 128))},
            "mlp": {"wi": jnp.zeros((4, 128, 512)),
                    "wo": jnp.zeros((4, 512, 128))},
        },
        "embed": jnp.zeros((1024, 128)),
    }
    specs = param_specs(params, mesh)
    # 1x1 mesh -> everything replicated but specs still well-formed
    flat = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(s, P) for s in flat)


def test_batch_specs_leading_dim():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    batch = {"tokens": jnp.zeros((8, 16), jnp.int32)}
    specs = batch_specs(batch, mesh)
    assert isinstance(specs["tokens"], P)


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = constrain(x, "data", "model")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------- HLO cost analyzer
def test_trip_count_multiplication():
    """A scan of N matmuls must count N x the flops of one matmul."""
    n, m = 8, 64

    def one(x, w):
        return x @ w, None

    def scanned(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
        return y

    x = jnp.zeros((m, m))
    ws = jnp.zeros((n, m, m))
    hlo = jax.jit(scanned).lower(x, ws).compile().as_text()
    cost = analyze_hlo(hlo)
    expected = n * 2 * m * m * m
    assert abs(cost.flops - expected) / expected < 0.05, cost.flops


def test_flops_single_dot():
    a, b, k = 32, 48, 64
    hlo = jax.jit(lambda x, y: x @ y).lower(
        jnp.zeros((a, k)), jnp.zeros((k, b))).compile().as_text()
    cost = analyze_hlo(hlo)
    assert abs(cost.flops - 2 * a * b * k) / (2 * a * b * k) < 0.05


def test_nested_scan_trip_counts():
    m = 16

    def inner(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
        return y

    def outer(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (inner(c, w), None), x, ws)
        return y

    x = jnp.zeros((m, m))
    ws = jnp.zeros((3, 5, m, m))     # 15 matmuls total
    hlo = jax.jit(outer).lower(x, ws).compile().as_text()
    cost = analyze_hlo(hlo)
    expected = 15 * 2 * m ** 3
    assert abs(cost.flops - expected) / expected < 0.05


def test_parse_module_finds_entry():
    hlo = jax.jit(lambda x: x + 1).lower(jnp.zeros((4,))).compile().as_text()
    comps, entry = parse_module(hlo)
    assert entry is not None and entry in comps

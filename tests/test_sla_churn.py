"""Churn/fault-injection: SLA invariants under preempt/resize/migration
storms, with the fleet SLA ledger in place.

A deliberately overloaded trace (arrivals ~5x steady-state density on the
default 2048-GPU fleet) forces heavy mechanism churn.  The run must keep
the paper's tiering invariant — premium jobs receive a strictly better
realized GPU fraction than standard, and standard better than basic —
conserve per-cluster capacity on every decision (``SimConfig.validate``
asserts inside the run), and produce decision-for-decision identical
sequences under the vectorized and scalar policy paths while both consult
the batched ledger.
"""
import hashlib

import numpy as np

from repro.core.sla import FleetSlotAccount
from repro.scheduler.policy import ElasticPolicy
from repro.scheduler.simulator import (
    FleetSimulator,
    SimConfig,
    make_fleet,
    synth_workload,
)

SEED = 1234
N_JOBS = 250
HORIZON = 36 * 3600.0


class _DigestPolicy:
    """Folds every Decision into a running hash so two runs can be
    compared decision-for-decision, not just on aggregates."""

    def __init__(self, inner):
        self.inner = inner
        self.name = inner.name
        self.digest = hashlib.sha256()

    def bind_costs(self, cost_model, interval_hint):
        self.inner.bind_costs(cost_model, interval_hint)

    def decide(self, now, jobs, fleet):
        decision = self.inner.decide(now, jobs, fleet)
        payload = repr(
            (
                sorted(decision.alloc.items()),
                decision.preemptions,
                decision.migrations,
            )
        )
        self.digest.update(payload.encode())
        return decision


def _storm_run(vectorized: bool):
    fleet = make_fleet()
    jobs = synth_workload(N_JOBS, fleet.total(), seed=SEED, mean_interarrival=120.0)
    policy = _DigestPolicy(ElasticPolicy(vectorized=vectorized))
    sim = FleetSimulator(
        fleet,
        jobs,
        policy,
        SimConfig(
            horizon_seconds=HORIZON,
            migration_cost_seconds=120.0,
            validate=True,  # per-cluster capacity conservation, every tick
        ),
    )
    result = sim.run()
    return result, policy.digest.hexdigest(), sim


def _realized_fraction(sim, tier: str) -> float:
    """Mean realized GPU fraction (ideal progress over wall time) across
    ALL arrived jobs of a tier — completed-only samples are survivorship
    biased toward lucky basic jobs."""
    vals = []
    for j in sim.jobs.values():
        if j.tier != tier or j.arrival >= sim.now:
            continue
        end = j.done_at if j.done_at is not None else sim.now
        if end > j.arrival:
            vals.append(min(1.0, j.progress * j.ideal_seconds / (end - j.arrival)))
    assert vals, f"no arrived {tier} jobs in the storm trace"
    return float(np.mean(vals))


def test_churn_storm_keeps_sla_invariants_and_path_equality():
    res_vec, digest_vec, sim = _storm_run(True)
    res_ref, digest_ref, _ = _storm_run(False)

    # the storm actually stormed: every mechanism fired repeatedly
    assert res_vec.preemptions > 100
    assert res_vec.migrations > 10
    assert res_vec.resizes > 10
    assert res_vec.restores > 100
    assert res_vec.gpu_seconds_dead > 0

    # the fleet ledger was in place and in use
    assert sim.fleet.sla is not None
    views = [j for j in sim.jobs.values() if isinstance(j.account, FleetSlotAccount)]
    assert len(views) == N_JOBS

    # vectorized and scalar policies: identical decision sequences and
    # identical macro results, with the ledger answering headroom
    assert digest_vec == digest_ref
    assert res_vec.preemptions == res_ref.preemptions
    assert res_vec.migrations == res_ref.migrations
    assert res_vec.resizes == res_ref.resizes
    assert res_vec.utilization == res_ref.utilization
    assert res_vec.gpu_seconds_dead == res_ref.gpu_seconds_dead

    # tiering invariant: realized GPU fraction orders premium > standard
    # > basic under overload (the whole point of the SLA machinery)
    premium = _realized_fraction(sim, "premium")
    standard = _realized_fraction(sim, "standard")
    basic = _realized_fraction(sim, "basic")
    assert premium >= standard >= basic, (premium, standard, basic)
    # and the attainment of each tier's own guarantee orders the same way
    # for the guaranteed tiers
    assert res_vec.sla_attainment["premium"] >= res_vec.sla_attainment["standard"]

"""Data pipeline determinism/resumability + optimizer unit tests."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.configs.base import TrainConfig
from repro.data.pipeline import DataPipeline
from repro.optim.adamw import adamw_init, adamw_update, global_norm
from repro.optim.schedule import lr_schedule


def test_pipeline_deterministic():
    a = DataPipeline(100, 16, 8, 4, seed=3)
    b = DataPipeline(100, 16, 8, 4, seed=3)
    ta, la = a.next_batch()
    tb, lb = b.next_batch()
    np.testing.assert_array_equal(ta, tb)
    np.testing.assert_array_equal(la, lb)


def test_pipeline_resume_exact():
    a = DataPipeline(100, 16, 8, 4, seed=3)
    a.next_batch()
    snap = a.snapshot()
    want = a.next_batch()
    b = DataPipeline(100, 16, 8, 4, seed=3)
    b.restore(snap)
    got = b.next_batch()
    np.testing.assert_array_equal(want[0], got[0])


def test_rank_slicing_independent_of_grouping():
    """Rows for rank r are identical whether fetched alone or with others —
    the property that makes splicing content-transparent."""
    p = DataPipeline(1000, 8, 8, 4, seed=7)
    alone = p.batch_for_ranks([2], step=5)[0]
    grouped = p.batch_for_ranks([0, 1, 2, 3], step=5)[0]
    per = p.per_rank
    np.testing.assert_array_equal(alone, grouped[2 * per:3 * per])


def test_labels_are_shifted_tokens():
    p = DataPipeline(1000, 8, 4, 2, seed=1)
    t, l = p.next_batch()
    assert t.shape == l.shape == (4, 8)
    # labels = next token of the same stream
    rows = p._rows(0, 0, 1)
    np.testing.assert_array_equal(rows[0, 1:], l[0])
    np.testing.assert_array_equal(rows[0, :-1], t[0])


# ---------------------------------------------------------------- optimizer
def test_adamw_descends_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)
    tcfg = TrainConfig(learning_rate=0.1, weight_decay=0.0, warmup_steps=0)
    for _ in range(200):
        grads = {"w": params["w"]}     # grad of 0.5*||w||^2
        params, opt = adamw_update(params, grads, opt, 0.1, tcfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_applies():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    tcfg = TrainConfig(grad_clip=1.0, weight_decay=0.0)
    huge = {"w": jnp.full(4, 1e6)}
    p1, _ = adamw_update(params, huge, opt, 1e-3, tcfg)
    assert float(jnp.abs(p1["w"]).max()) < 1e-2   # clipped step


def test_global_norm():
    t = {"a": jnp.ones(4), "b": jnp.ones((2, 2)) * 2}
    # sqrt(4*1 + 4*4) = sqrt(20)
    assert abs(float(global_norm(t)) - np.sqrt(20)) < 1e-6


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 2000))
def test_lr_schedule_bounded(step):
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=100, total_steps=1000)
    lr = float(lr_schedule(jnp.asarray(step), tcfg))
    assert 0.0 <= lr <= 1e-3 + 1e-9

"""Distributed-barrier protocol (§4.3.1): safety + liveness properties."""
from hypothesis import given, settings, strategies as st

from repro.core.barrier import CollectiveEngine, run_barrier_simulation
from repro.core.barrier_jax import BarrierDriver, meta_allreduce


@settings(max_examples=40, deadline=None)
@given(world=st.integers(2, 8),
       n_coll=st.integers(1, 6),
       cmd_at=st.integers(0, 60),
       seed=st.integers(0, 2**31 - 1),
       mode=st.sampled_from(["per_allreduce", "minibatch_end"]))
def test_barrier_properties(world, n_coll, cmd_at, seed, mode):
    """Under adversarial interleavings: every rank acquires; the cut is
    consistent (identical issue counts, nothing in flight); termination
    within <= 2 mini-batches of command delivery (the paper's bound)."""
    res = run_barrier_simulation(world, n_coll, cmd_at, seed, mode=mode)
    assert res.acquired
    assert res.consistent_cut
    assert res.minibatches_to_acquire <= 2
    counts = res.issue_counts["data"] if mode == "per_allreduce" \
        else res.issue_counts["meta"]
    assert len(set(counts)) == 1


def test_meta_allreduce_payload_is_two_ints():
    """The steady-state payload is exactly (need, ack) — two integers."""
    eng = CollectiveEngine(4)
    eng.register("meta")
    for r in range(4):
        eng.issue("meta", r, (0, 0))
    assert eng.result("meta", 0) == (0, 0)


def test_barrier_driver_in_graph():
    """Host driver over the in-graph psum: request -> ack -> acquire."""
    drv = BarrierDriver(n_shards=1)
    # phase 1: free
    summed = meta_allreduce(drv.flags(), mesh=None)
    assert not drv.observe(summed)
    drv.request()
    summed = meta_allreduce(drv.flags(), mesh=None)
    assert not drv.observe(summed)          # need seen -> ack next step
    summed = meta_allreduce(drv.flags(), mesh=None)
    assert drv.observe(summed)              # all acked -> acquired
    assert drv.acquired


def test_no_barrier_without_command():
    res = run_barrier_simulation(4, 3, command_at_step=10**9, schedule_seed=0,
                                 max_steps=2000)
    assert not res.acquired   # ran to step budget in steady state

"""Real multi-device execution: the sharded step must match single-device
numerics.  Runs in a subprocess (jax locks the host device count at first
init, so the main test process must stay at 1 device)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.models.frontend import synth_extra_inputs
from repro.parallel.sharding import batch_specs, param_specs, to_shardings
from repro.training.state import init_train_state
from repro.training.step import build_train_step

cfg = get_smoke_config("olmo-1b")
tcfg = TrainConfig(total_steps=10, warmup_steps=1, learning_rate=1e-3)
key = jax.random.PRNGKey(0)
state = init_train_state(cfg, tcfg, key)
tokens = jax.random.randint(key, (8, 64), 0, cfg.vocab_size)
batch = {"tokens": tokens, "labels": tokens}

# single-device reference
ref_step = jax.jit(build_train_step(cfg, tcfg, splice=1))
ref_state, ref_metrics = ref_step(state, batch)
ref_losses = [float(ref_metrics["loss"])]
ref_state2, m2 = ref_step(ref_state, batch)
ref_losses.append(float(m2["loss"]))

# sharded on a (2, 4) mesh: data x model
mesh = jax.make_mesh((2, 4), ("data", "model"))
st_sh = to_shardings(param_specs(state, mesh), mesh)
b_sh = to_shardings(batch_specs(batch, mesh), mesh)
state_s = jax.device_put(state, st_sh)
batch_s = jax.device_put(batch, b_sh)
with mesh:
    step = jax.jit(build_train_step(cfg, tcfg, splice=1),
                   in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None))
    s1, mm1 = step(state_s, batch_s)
    s2, mm2 = step(s1, batch_s)
losses = [float(mm1["loss"]), float(mm2["loss"])]
print(json.dumps({"ref": ref_losses, "sharded": losses}))
"""


@pytest.mark.slow
def test_sharded_step_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=900,
                          env=env, cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for a, b in zip(out["ref"], out["sharded"]):
        assert abs(a - b) / abs(a) < 1e-4, out

import sys

import jax
import pytest

# Hermetic containers may not have the dev dependencies; fall back to the
# vendored minimal hypothesis shim so the whole tier-1 suite still collects
# and runs.  The real package (requirements-dev.txt) always wins.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import os
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_fallback import build_module
    _mod = build_module()
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies

# Tests run on the single real CPU device (the 512-device override is
# strictly dryrun-only, per the assignment).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)

import jax
import pytest

# Tests run on the single real CPU device (the 512-device override is
# strictly dryrun-only, per the assignment).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)

"""NodeMap property suite: gang arithmetic, per-node conservation, slot
lifecycle, failure blast radii, and node-granular decision equivalence.

The NodeMap is the simulator-owned source of truth for which nodes every
job's gang actually occupies.  These tests pin the contracts the rest of
the scheduler builds on:

- gang/splice arithmetic: ``gang_down`` always lands on a compatible
  world size (a divisor or multiple of the demand), the vectorized
  variant agrees with the scalar one, and ``min_piece``/``floor_gang``
  derive from the same ladder;
- per-node conservation (``free + used + dead == cap``) survives
  arbitrary interleavings of span assignment, release, failure claims
  and repairs — and the fleet returns to full strength afterwards;
- row slots grow by doubling, are reused after release, and surviving
  spans are byte-identical across pool compaction;
- a node failure kills exactly the jobs with pieces on the failed
  node — free capacity dies first, then rows in ascending order — and
  jobs elsewhere are untouched;
- with node placement on, the vectorized and scalar reference decide
  paths emit identical decisions AND identical span plans, storm
  included (per-node conservation asserted every tick via validate).
"""

import hashlib

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.scheduler.costs import CostModel
from repro.scheduler.node_map import (
    NodeMap,
    floor_gang,
    gang_down,
    gang_down_vec,
    gang_values,
    min_piece,
    splice_divisors,
)
from repro.scheduler.policy import ElasticPolicy
from repro.scheduler.reliability import FailureModel, FailureTrace
from repro.scheduler.simulator import (
    FleetSimulator,
    SimConfig,
    make_fleet,
    synth_workload,
)
from repro.scheduler.types import Cluster, Fleet, Region


def _compatible(demand: int, upto: int = 400) -> set:
    vals = set(splice_divisors(demand))
    vals.update(m * demand for m in range(1, upto // demand + 2))
    return vals


# ------------------------------------------------------ gang arithmetic
@settings(max_examples=200, deadline=None)
@given(g=st.integers(0, 160), demand=st.integers(1, 96))
def test_gang_down_lands_on_largest_compatible(g, demand):
    v = gang_down(g, demand)
    compat = _compatible(demand)
    if v:
        assert v in compat and v <= g
        assert not any(c for c in compat if v < c <= g)
    else:
        assert not any(c for c in compat if 0 < c <= g)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 64))
def test_gang_down_vec_matches_scalar(seed, n):
    rng = np.random.Generator(np.random.Philox(seed))
    demand = rng.integers(1, 96, n)
    galloc = rng.integers(0, 160, n)
    vec = gang_down_vec(galloc.astype(np.int64), demand.astype(np.int64))
    ref = np.array([gang_down(int(g), int(d)) for g, d in zip(galloc, demand)])
    assert (vec == ref).all()


@settings(max_examples=100, deadline=None)
@given(demand=st.integers(1, 96), min_gpus=st.integers(1, 200))
def test_floor_gang_is_smallest_admissible(demand, min_gpus):
    v = floor_gang(demand, min_gpus)
    if min_gpus > demand:
        # degenerate floor: admission grants are capped at the demand, so
        # no admissible world size exists — never a multiple past demand
        assert v == 0
        return
    assert v >= min_gpus
    assert v <= demand
    assert v in _compatible(demand)
    assert not any(c for c in _compatible(demand) if min_gpus <= c < v)


def test_min_piece_tracks_node_size():
    # 16-GPU gangs on 8-GPU nodes split as 8+8: nothing smaller than a
    # full node ever lands, so a 7-GPU hole is useless to them
    assert min_piece(16, 8, 8) == 8
    # but a job that can shrink to tiny divisors can use any hole
    assert min_piece(4, 1, 8) == 1
    # a 12-GPU gang leaves a 4-GPU remainder piece
    assert min_piece(12, 12, 8) == 4


def test_trailing_partial_node_keeps_true_capacity():
    c = Cluster("c0", "r0", 20, gpus_per_node=8)
    assert list(c.node_capacities()) == [8, 8, 4]
    fleet = Fleet([Region("r0", [c])])
    nm = NodeMap.from_fleet(fleet)
    assert list(nm.node_cap) == [8, 8, 4]
    assert int(nm.cluster_free_vector()[0]) == 20


# ------------------------------------------- conservation under chaos
@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 100_000), n_ops=st.integers(1, 120))
def test_conservation_under_random_ops(seed, n_ops):
    """free + used + dead == cap per node after every operation, and the
    fleet returns to full strength once every span is released and every
    failure repaired."""
    rng = np.random.Generator(np.random.Philox(seed))
    fleet = Fleet(
        [
            Region(
                "r0",
                [
                    Cluster("r0c0", "r0", 48, gpus_per_node=8),
                    Cluster("r0c1", "r0", 20, gpus_per_node=8),
                ],
            )
        ]
    )
    nm = NodeMap.from_fleet(fleet, capacity_rows=2)
    live: set = set()
    outstanding: list = []
    next_row = 0
    for _ in range(n_ops):
        op = int(rng.integers(0, 4))
        if op == 0:  # place a new span wherever capacity exists
            k = int(rng.integers(0, nm.n_clusters))
            free = int(nm.cluster_free_vector()[k])
            if free > 0:
                g = int(rng.integers(1, free + 1))
                nm.auto_fit(next_row, k, g)
                live.add(next_row)
                next_row += 1
        elif op == 1 and live:  # release a random live span
            row = int(rng.choice(sorted(live)))
            nm.release(row)
            live.discard(row)
        elif op == 2:  # fail part (or all) of a cluster
            k = int(rng.integers(0, nm.n_clusters))
            want = int(rng.integers(1, 49))
            claims = nm.fail_claims(k, want)
            victims = nm.apply_claims(claims)
            live.difference_update(victims)
            outstanding.append(claims)
        elif op == 3 and outstanding:  # repair a random failure
            idx = int(rng.integers(0, len(outstanding)))
            nm.repair_claims(outstanding.pop(idx))
        nm.check()
        # row bookkeeping matches the span pool at every step
        for row in live:
            assert nm.span_total(row) > 0
    for claims in outstanding:
        nm.repair_claims(claims)
    for row in sorted(live):
        nm.release(row)
    nm.check()
    assert (nm.node_free == nm.node_cap).all()
    assert nm.live_rows().size == 0


# -------------------------------------------------- slot/pool lifecycle
def test_row_growth_reuse_and_compaction():
    fleet = Fleet([Region("r0", [Cluster("r0c0", "r0", 4096, gpus_per_node=8)])])
    nm = NodeMap.from_fleet(fleet, capacity_rows=1)
    for row in range(200):  # forces repeated doubling of the row arrays
        nm.auto_fit(row, 0, 16)
        nm.check()
    assert nm.row_len.size >= 200
    before = {
        row: (nm.row_pieces(row)[0].copy(), nm.row_pieces(row)[1].copy())
        for row in range(1, 200, 2)
    }
    for row in range(0, 200, 2):  # > half the pool becomes garbage ...
        nm.release(row)
    for row in range(0, 200, 2):  # ... and reuse triggers compaction
        nm.auto_fit(row, 0, 8)
        nm.check()
    for row, (nodes, gpus) in before.items():  # survivors are untouched
        n2, g2 = nm.row_pieces(row)
        assert (n2 == nodes).all() and (g2 == gpus).all()
    assert int(nm.row_total[:200].sum()) == 100 * 16 + 100 * 8
    nm.check()


# ------------------------------------------------- failure blast radius
def test_node_failure_kills_exactly_mapped_rows():
    fleet = Fleet([Region("r0", [Cluster("r0c0", "r0", 64, gpus_per_node=8)])])
    nm = NodeMap.from_fleet(fleet)
    # rows 0..3 each take a full node; packing is lowest-index greedy
    for row in range(4):
        nm.auto_fit(row, 0, 8)
    assert list(nm.rows_on_node(1)) == [1]
    # an 8-GPU partial failure claims node 0's capacity first: with no
    # free GPUs on it, exactly row 0 dies
    claims = nm.fail_claims(0, 8)
    assert claims == [(0, 8)]
    victims = nm.apply_claims(claims)
    assert victims == [0]
    for row in (1, 2, 3):  # everyone else keeps their span
        assert nm.span_total(row) == 8
    nm.check()
    nm.repair_claims(claims)
    nm.check()
    assert int(nm.node_free[0]) == 8


def test_partial_failure_eats_free_capacity_before_jobs():
    fleet = Fleet([Region("r0", [Cluster("r0c0", "r0", 64, gpus_per_node=8)])])
    nm = NodeMap.from_fleet(fleet)
    nm.assign(0, [0], [4])  # node 0: 4 used, 4 free
    victims = nm.apply_claims(nm.fail_claims(0, 4))
    assert victims == []  # the free half dies, the job survives
    assert nm.span_total(0) == 4
    victims = nm.apply_claims(nm.fail_claims(0, 2))
    assert victims == [0]  # now the job must die; its whole gang goes
    nm.check()


def test_whole_cluster_failure_kills_every_resident():
    fleet = Fleet(
        [
            Region(
                "r0",
                [
                    Cluster("r0c0", "r0", 32, gpus_per_node=8),
                    Cluster("r0c1", "r0", 32, gpus_per_node=8),
                ],
            )
        ]
    )
    nm = NodeMap.from_fleet(fleet)
    nm.auto_fit(0, 0, 12)
    nm.auto_fit(1, 0, 12)
    nm.auto_fit(2, 1, 12)
    victims = nm.apply_claims(nm.fail_claims(0, 32))
    assert sorted(victims) == [0, 1]
    assert nm.span_total(2) == 12  # the other cluster is untouched
    assert nm.cluster_dead(0) == 32
    nm.check()


# --------------------------------- decide-path equivalence, storm included
class _PlanDigestPolicy:
    """Hashes every decision INCLUDING its node span plan, so the
    equivalence gate catches span-level drift the alloc map would hide."""

    def __init__(self, inner):
        self.inner = inner
        self.name = inner.name
        self.digest = hashlib.sha256()

    def bind_costs(self, cost_model, interval_hint):
        self.inner.bind_costs(cost_model, interval_hint)

    def decide(self, now, jobs, fleet):
        decision = self.inner.decide(now, jobs, fleet)
        plan = decision.node_plan
        spans = None
        if plan is not None:
            _, released, assigns = plan
            spans = (
                sorted(released),
                [(r, list(n), list(g)) for r, n, g in assigns],
            )
        self.digest.update(
            repr(
                (
                    sorted(decision.alloc.items()),
                    decision.preemptions,
                    decision.migrations,
                    spans,
                )
            ).encode()
        )
        return decision


def _node_storm_run(vectorized: bool, job_table: bool) -> tuple:
    fleet = make_fleet(n_regions=2, clusters_per_region=2, gpus_per_cluster=256)
    storm = FailureTrace.merge(
        FailureModel(
            device_mtbf_seconds=10 * 24 * 3600.0,
            node_mtbf_seconds=15 * 24 * 3600.0,
            cluster_mtbf_seconds=45 * 24 * 3600.0,
            seed=11,
        ).sample(fleet, 12 * 3600.0),
        FailureTrace.cluster_outage("r0c0", at=4 * 3600.0),
    )
    wrapper = _PlanDigestPolicy(ElasticPolicy(vectorized=vectorized))
    sim = FleetSimulator(
        fleet,
        synth_workload(80, fleet.total(), seed=5, mean_interarrival=180.0),
        wrapper,
        SimConfig(
            horizon_seconds=12 * 3600.0,
            cost_model=CostModel(),
            failures=storm,
            validate=True,  # per-node conservation asserted every tick
            job_table=job_table,
        ),
    )
    res = sim.run()
    return res, wrapper.digest.hexdigest()


def test_scalar_equals_vectorized_span_plans_under_storm():
    res_v, dig_v = _node_storm_run(vectorized=True, job_table=True)
    res_p, dig_p = _node_storm_run(vectorized=True, job_table=False)
    res_s, dig_s = _node_storm_run(vectorized=False, job_table=True)
    assert res_v.job_failures > 0  # the storm actually stormed
    assert dig_v == dig_p == dig_s
    assert res_v.utilization == res_p.utilization == res_s.utilization
    assert (
        (res_v.preemptions, res_v.migrations, res_v.resizes)
        == (res_p.preemptions, res_p.migrations, res_p.resizes)
        == (res_s.preemptions, res_s.migrations, res_s.resizes)
    )


def test_calm_sea_span_plans_match_too():
    """Equivalence with failures OFF: the plain workload must also walk
    identical span plans down both decide paths."""
    digests = {}
    for vec in (True, False):
        fleet = make_fleet(n_regions=2, clusters_per_region=2, gpus_per_cluster=256)
        wrapper = _PlanDigestPolicy(ElasticPolicy(vectorized=vec))
        sim = FleetSimulator(
            fleet,
            synth_workload(60, fleet.total(), seed=2, mean_interarrival=240.0),
            wrapper,
            SimConfig(horizon_seconds=8 * 3600.0, validate=True),
        )
        sim.run()
        digests[vec] = wrapper.digest.hexdigest()
    assert digests[True] == digests[False]


# ------------------------------------------------------- fragmentation
def test_stranded_gpus_counts_unusable_holes():
    fleet = Fleet([Region("r0", [Cluster("r0c0", "r0", 16, gpus_per_node=8)])])
    nm = NodeMap.from_fleet(fleet)
    nm.assign(0, [0], [5])  # node 0 keeps a 3-GPU hole
    # a 16-GPU gang only ever lands in full-node pieces: the hole is dead
    assert nm.stranded_gpus([(16, 8)]) == 3
    # a job that can shrink to 1 GPU can use it: nothing stranded
    assert nm.stranded_gpus([(16, 8), (4, 1)]) == 0
    assert nm.stranded_gpus([]) == 0

"""Content-deduplicated checkpointing (§4.6, Table 4)."""
import numpy as np

from repro.core.checkpoint import CheckpointStore


def _state(seed, scale=1.0):
    rng = np.random.Generator(np.random.Philox(seed))
    return {"p": (scale * rng.standard_normal((64, 64))).astype(np.float32),
            "o": {"m": rng.standard_normal(128).astype(np.float32)}}


def test_cross_worker_dedup_sg_independent_of_dp_degree():
    """DP replicas hold identical device state: stored bytes must not grow
    with the worker count (Table 4's S_G property)."""
    shared = _state(1)
    sizes = {}
    for workers in (2, 8):
        store = CheckpointStore()
        stats = store.snapshot(
            "job", 0,
            {w: shared for w in range(workers)},
            {w: {"rank": w, "step": 0} for w in range(workers)})
        sizes[workers] = stats.device_stored_bytes
        assert stats.device_logical_bytes == workers * sizes[workers] \
            or stats.device_stored_bytes < stats.device_logical_bytes
    assert sizes[2] == sizes[8]


def test_temporal_dedup_incremental_smaller():
    """Subsequent snapshots store only changed chunks (§4.6)."""
    store = CheckpointStore()
    s0 = _state(2)
    first = store.snapshot("job", 0, {0: s0}, {0: {"step": 0}})
    # small mutation: one tensor changes, the other doesn't
    s1 = {"p": s0["p"] + 0.1, "o": s0["o"]}
    second = store.snapshot("job", 1, {0: s1}, {0: {"step": 1}})
    assert second.device_stored_bytes < first.device_stored_bytes


def test_restore_roundtrip_bit_exact():
    store = CheckpointStore()
    state = _state(3)
    store.snapshot("job", 5, {0: state, 1: state}, {0: {"x": 1}, 1: {"x": 2}})
    device, host, step = store.restore("job")
    assert step == 5
    np.testing.assert_array_equal(device[0]["p"], state["p"])
    np.testing.assert_array_equal(device[1]["o"]["m"], state["o"]["m"])
    assert host[0] == {"x": 1} and host[1] == {"x": 2}


def test_restore_specific_step():
    store = CheckpointStore()
    store.snapshot("job", 1, {0: _state(1)}, {0: {}})
    store.snapshot("job", 2, {0: _state(2)}, {0: {}})
    _, _, step = store.restore("job", step=1)
    assert step == 1


def test_disk_backed_store(tmp_path):
    store = CheckpointStore(root=str(tmp_path))
    state = _state(4)
    store.snapshot("job", 0, {0: state}, {0: {"step": 0}})
    # fresh store over the same root can read chunks back
    fresh = CheckpointStore(root=str(tmp_path))
    fresh.manifests = store.manifests
    device, _, _ = fresh.restore("job")
    np.testing.assert_array_equal(device[0]["p"], state["p"])


def test_file_tracking_dedup():
    store = CheckpointStore()
    files = {0: {"/w/a.txt": b"hello" * 100},
             1: {"/w/a.txt": b"hello" * 100}}   # identical content
    stats = store.snapshot("job", 0, {0: _state(5), 1: _state(5)},
                           {0: {}, 1: {}}, files_by_worker=files)
    # file content stored once despite two workers writing it
    assert stats.host_stored_bytes < 2 * len(b"hello" * 100) + 1000

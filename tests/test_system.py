"""End-to-end behaviour: the paper's §2 lifecycle as one integration test.

A job trains; the scheduler shrinks it (transparent 4->1 resize), a
checkpoint is taken through the barrier-quiesced boundary, the job is
migrated to a different "cluster" with a different device count, and
training continues — with zero lost work and an unchanged trajectory.
"""
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.core.barrier import run_barrier_simulation
from repro.core.checkpoint import CheckpointStore
from repro.core.elastic import ElasticRuntime
from repro.core.migration import migrate
from repro.serving.engine import ServingEngine

CFG = get_smoke_config("olmo-1b")
TCFG = TrainConfig(total_steps=60, warmup_steps=2, learning_rate=1e-3)
W, G, S = 4, 8, 32


def test_full_lifecycle():
    # reference: undisturbed run
    ref = ElasticRuntime(CFG, TCFG, W, W, G, S)
    ref_hist = ref.run_steps(10)

    # the managed job: shrink -> checkpoint/migrate -> grow
    rt = ElasticRuntime(CFG, TCFG, W, W, G, S)
    rt.run_steps(3)
    rt.resize(1)                                 # capacity crunch: 4 -> 1
    rt.run_steps(2)

    bres = run_barrier_simulation(W, 3, command_at_step=5, schedule_seed=0)
    assert bres.acquired and bres.consistent_cut  # quiesce before dump

    store = CheckpointStore()
    rt2, report = migrate(rt, store, "lifecycle", 2, CFG, TCFG, G, S)
    assert report.work_conserving
    rt2.run_steps(3)
    rt2.resize(4)                                # capacity back: grow
    rt2.run_steps(2)

    hist = rt.history + rt2.history
    assert len(hist) == 10
    for a, b in zip(ref_hist, hist):
        assert abs(a["loss"] - b["loss"]) / a["loss"] < 2e-3, (a, b)


def test_serving_engine_generates():
    cfg = get_smoke_config("h2o-danube-3-4b")
    eng = ServingEngine(cfg, seed=0)
    import jax, jax.numpy as jnp
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                                 cfg.vocab_size, jnp.int32)
    out = eng.generate(prompts, max_new_tokens=5)
    assert out.shape == (2, 5)
    assert int(out.max()) < cfg.vocab_size
    # greedy decoding is deterministic
    out2 = eng.generate(prompts, max_new_tokens=5)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))

"""Ledger compaction: finalized interval prefixes collapse into summary
rows without changing what the scheduler observes.

A months-long churny job appends SLA intervals forever; compaction keeps
the fleet ledger's interval axis bounded by churn within the keep
horizon instead of job lifetime.  The contract: for the scheduler's
query pattern (monotone ``now``, consistent window sizes), a compacting
ledger answers identically to the scalar ``GpuFractionAccount`` oracle —
property-tested here at 1e-9 — while the scalar account's interval list
grows without bound.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.sla import (
    HOUR,
    FleetSLAAccounts,
    FleetSlotAccount,
    GpuFractionAccount,
)

TIER_NAMES = ["premium", "standard", "basic"]


def _churn(rng, view, oracle, n_records, query_every=5, window=HOUR):
    """Drive both accounts through an identical churny record stream with
    monotone interleaved queries; returns the max |ledger - oracle|."""
    t = 0.0
    err = 0.0
    demand = oracle.demand
    for i in range(n_records):
        dt = float(rng.uniform(30.0, 900.0))
        g = int(rng.integers(0, demand + 2)) if demand > 0 else 0
        view.record(t, t + dt, g)
        oracle.record(t, t + dt, g)
        t += dt
        if i % query_every == 0:
            now = t + float(rng.uniform(0.0, 120.0))
            err = max(
                err,
                abs(
                    view.worst_window_fraction(now, window)
                    - oracle.worst_window_fraction(now, window)
                ),
                abs(view.headroom(now, window) - oracle.headroom(now, window)),
            )
    return err, t


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10**6), n_records=st.integers(50, 400))
def test_compacting_ledger_matches_scalar_oracle(seed, n_records):
    """Tiny axis + aggressive compaction thresholds force constant
    compaction; every interleaved query must still match the oracle."""
    rng = np.random.Generator(np.random.Philox(seed))
    ledger = FleetSLAAccounts(
        slot_capacity=1,
        interval_capacity=2,
        compact_after=8,
        keep_horizon_seconds=2 * HOUR,
    )
    tier = TIER_NAMES[int(rng.integers(0, 3))]
    demand = int(rng.integers(1, 13))
    view = FleetSlotAccount(ledger, tier, demand)
    oracle = GpuFractionAccount(tier, demand)
    err, _ = _churn(rng, view, oracle, n_records)
    assert err < 1e-9, err


def test_interval_axis_stays_bounded_while_oracle_grows():
    """The point of compaction: months of churn, bounded axis."""
    rng = np.random.Generator(np.random.Philox(0))
    ledger = FleetSLAAccounts(
        slot_capacity=1,
        interval_capacity=2,
        compact_after=16,
        keep_horizon_seconds=2 * HOUR,
    )
    view = FleetSlotAccount(ledger, "standard", 8)
    oracle = GpuFractionAccount("standard", 8)
    err, t = _churn(rng, view, oracle, 8000)
    assert err < 1e-9
    assert t > 30 * 24 * 3600.0  # over a month of simulated churn
    assert len(oracle.intervals) > 4000  # the scalar account grew linearly
    assert ledger._iv_cap <= 64  # the ledger's axis did not
    assert int(ledger._count[0]) <= 64


def test_explicit_compact_frees_rows_and_preserves_queries():
    ledger = FleetSLAAccounts(slot_capacity=1, interval_capacity=2, compact_after=None)
    view = FleetSlotAccount(ledger, "standard", 8)
    oracle = GpuFractionAccount("standard", 8)
    t = 0.0
    for i in range(500):
        g = [8, 0, 4, 8][i % 4]
        view.record(t, t + 600.0, g)
        oracle.record(t, t + 600.0, g)
        t += 600.0
    now = t + 10.0
    before = view.worst_window_fraction(now)  # initializes the window cache
    rows = int(ledger._count[0])
    freed = ledger.compact()
    assert freed > 0
    assert int(ledger._count[0]) == rows - freed
    assert abs(view.worst_window_fraction(now) - before) < 1e-12
    # queries keep matching the oracle as time moves on
    for _ in range(20):
        view.record(t, t + 600.0, 4)
        oracle.record(t, t + 600.0, 4)
        t += 600.0
        assert abs(view.headroom(t) - oracle.headroom(t)) < 1e-9


def test_compaction_skips_unfinalized_and_kept_suffix():
    """Nothing inside the keep horizon may be summarized: a fresh ledger
    whose whole history is recent compacts to nothing."""
    ledger = FleetSLAAccounts(
        slot_capacity=1,
        interval_capacity=2,
        compact_after=None,
        keep_horizon_seconds=24 * HOUR,
    )
    view = FleetSlotAccount(ledger, "premium", 4)
    t = 0.0
    for i in range(20):
        view.record(t, t + 300.0, i % 5)
        t += 300.0  # 100 minutes total — all inside the keep horizon
    assert ledger.compact() == 0


def test_slot_reuse_after_compaction():
    """A released slot's summary row must not leak into its next tenant."""
    ledger = FleetSLAAccounts(
        slot_capacity=1,
        interval_capacity=2,
        compact_after=8,
        keep_horizon_seconds=HOUR,
    )
    view = FleetSlotAccount(ledger, "standard", 8)
    t = 0.0
    for i in range(200):
        view.record(t, t + 600.0, [8, 0][i % 2])
        t += 600.0
    view.worst_window_fraction(t)
    view.release()
    fresh = FleetSlotAccount(ledger, "premium", 2)
    oracle = GpuFractionAccount("premium", 2)
    t2 = 5000.0
    for i in range(50):
        g = [2, 1, 0][i % 3]
        fresh.record(t2, t2 + 400.0, g)
        oracle.record(t2, t2 + 400.0, g)
        t2 += 400.0
        assert abs(fresh.headroom(t2) - oracle.headroom(t2)) < 1e-9

"""Cost-aware vectorized policy: equivalence, region pricing, calibration.

The vectorized ``ElasticPolicy`` path is the production path for
million-job traces; the scalar path is the reference oracle.  The
property test here is the contract that lets the benchmark trust the
numpy passes: on arbitrary fleets and arbitrary job runtime states the
two paths must emit byte-identical decisions.
"""
import dataclasses

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.migration import MigrationReport
from repro.scheduler.costs import CostModel, RegionTopology
from repro.scheduler.policy import ElasticPolicy
from repro.scheduler.simulator import (
    FleetSimulator,
    SimConfig,
    make_fleet,
    synth_workload,
)
from repro.scheduler.types import Cluster, Fleet, Job, Region

TIER_NAMES = ["premium", "standard", "basic"]


def _random_fleet(rng: np.random.Generator) -> Fleet:
    regions = []
    for r in range(int(rng.integers(1, 4))):
        clusters = [
            Cluster(f"r{r}c{c}", f"r{r}", int(rng.integers(1, 9)) * 32)
            for c in range(int(rng.integers(1, 4)))
        ]
        regions.append(Region(f"r{r}", clusters))
    topology = RegionTopology.tiered([r.id for r in regions])
    return Fleet(regions, topology=topology)


def _random_jobs(rng: np.random.Generator, fleet: Fleet, n: int, now: float):
    clusters = fleet.clusters()
    jobs = []
    for i in range(n):
        demand = int(2 ** rng.integers(0, 8))
        job = Job(
            id=f"j{i}",
            tier=str(rng.choice(TIER_NAMES)),
            demand_gpus=demand,
            gpu_hours=float(rng.uniform(0.1, 4.0)) * demand,
            arrival=float(rng.uniform(0.0, now * 1.5)),
            min_gpus=max(1, demand // int(2 ** rng.integers(0, 3))),
            # half the jobs carry a concave scaling curve so the
            # water-filling expansion blocks are exercised, half keep the
            # flat sentinel so the legacy pricing stays covered
            knee_gpus=(
                int(rng.integers(demand, 2 * demand + 1))
                if rng.integers(0, 2)
                else 0
            ),
            sat_slope=float(rng.uniform(0.0, 1.0)),
        )
        state = rng.integers(0, 4)
        if state == 1:  # running somewhere, with delivered history
            job.allocated = int(rng.integers(1, 2 * demand + 1))
            job.cluster = str(rng.choice([c.id for c in clusters]))
            job.ever_ran = True
            job.account.record(0.0, now, int(rng.integers(0, demand + 1)))
        elif state == 2:  # preempted earlier: queued with restore debt
            job.ever_ran = True
            job.restore_debt = float(rng.uniform(0.0, 600.0))
            job.account.record(0.0, now * 0.5, demand)
            job.account.record(now * 0.5, now, 0)
        elif state == 3 and rng.integers(0, 2) == 0:
            job.done_at = now * 0.9  # finished: must be ignored entirely
        jobs.append(job)
    return jobs


def _cost_model(rng: np.random.Generator):
    pick = int(rng.integers(0, 4))
    if pick == 0:
        return None
    if pick == 1:
        return CostModel.uniform(float(rng.uniform(0.0, 900.0)))
    if pick == 2:
        return CostModel()
    return CostModel(scale=float(rng.uniform(0.0, 3.0)))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 100_000), n_jobs=st.integers(1, 60))
def test_vectorized_decide_equals_scalar_reference(seed, n_jobs):
    """The numpy passes and the per-job reference loops must agree
    exactly: same allocations, same placements, same preemption and
    migration lists — on random fleets, tiers, runtime states and cost
    models."""
    rng = np.random.Generator(np.random.Philox(seed))
    now = float(rng.uniform(600.0, 7200.0))
    fleet = _random_fleet(rng)
    jobs = _random_jobs(rng, fleet, n_jobs, now)
    cm = _cost_model(rng)
    interval = float(rng.choice([60.0, 300.0, 900.0]))
    vec = ElasticPolicy(cost_model=cm, interval_hint=interval)
    ref = ElasticPolicy(cost_model=cm, interval_hint=interval, vectorized=False)
    d_vec = vec.decide(now, jobs, fleet)
    d_ref = ref.decide(now, jobs, fleet)
    assert d_vec.alloc == d_ref.alloc
    assert d_vec.preemptions == d_ref.preemptions
    assert d_vec.migrations == d_ref.migrations
    assert d_vec.slope_expanded == d_ref.slope_expanded


def test_full_simulation_identical_under_both_policy_paths():
    """End to end: a whole simulated day must be decision-for-decision
    identical whichever policy implementation drives it."""
    results = {}
    for vectorized in (True, False):
        sim = FleetSimulator(
            make_fleet(),
            synth_workload(80, 2048, seed=9),
            ElasticPolicy(vectorized=vectorized),
            SimConfig(horizon_seconds=24 * 3600),
        )
        results[vectorized] = sim.run()
    a, b = results[True], results[False]
    assert a.utilization == b.utilization
    assert a.completed == b.completed
    assert (a.preemptions, a.migrations, a.resizes, a.restores) == (
        b.preemptions,
        b.migrations,
        b.resizes,
        b.restores,
    )
    assert a.gpu_seconds_dead == b.gpu_seconds_dead


def test_policy_rebinds_costs_when_reused_across_simulators():
    """A reused policy must price decisions with the cost model of the
    simulator currently driving it, while an explicitly-configured model
    is never overwritten."""
    pol = ElasticPolicy()
    cfg_paid = SimConfig(horizon_seconds=3600.0, migration_cost_seconds=600.0)
    cfg_free = SimConfig(horizon_seconds=3600.0, migration_cost_seconds=0.0)
    FleetSimulator(make_fleet(), synth_workload(5, 2048, seed=1), pol, cfg_paid)
    paid_model = pol.cost_model
    assert paid_model is not None
    FleetSimulator(make_fleet(), synth_workload(5, 2048, seed=1), pol, cfg_free)
    assert pol.cost_model is not paid_model
    assert pol.cost_model.migrate_seconds(0) == 0.0

    fixed = CostModel()
    pol2 = ElasticPolicy(cost_model=fixed)
    FleetSimulator(make_fleet(), synth_workload(5, 2048, seed=1), pol2, cfg_paid)
    assert pol2.cost_model is fixed


def test_cross_region_migration_pricier_than_intra():
    """Identical job, identical bytes: moving it across regions must cost
    more than moving it within one — under both cost model families."""
    topo = RegionTopology.tiered(["r0", "r1", "r2", "r3"])
    cb = 8 << 30
    derived = CostModel(topology=topo)
    uniform = dataclasses.replace(CostModel.uniform(60.0), topology=topo)
    for cm in (derived, uniform):
        intra = cm.migrate_seconds(cb, "r0", "r0")
        near = cm.migrate_seconds(cb, "r0", "r1")
        far = cm.migrate_seconds(cb, "r0", "r2")
        assert near > intra
        assert far > near
    # region-blind calls keep the seed behaviour (intra pricing)
    assert derived.migrate_seconds(cb) == derived.migrate_seconds(cb, "r0", "r0")


def test_cost_model_calibrates_from_migration_reports():
    """CostModel.from_reports must recover the bandwidths/latencies that
    produced a set of measured migration reports."""
    reports = []
    for i in range(4):
        gib = float(2 + i)
        nbytes = int(gib * (1 << 30))
        reports.append(
            MigrationReport(
                job_id=f"j{i}",
                from_physical=4,
                to_physical=2,
                barrier_seconds=1.0,
                barrier_minibatches=2,
                dump_seconds=nbytes / 32e9,
                upload_seconds=nbytes / 2e9,
                download_seconds=nbytes / 2e9,
                restore_seconds=5.0,
                total_seconds=0.0,
                device_stored_bytes=nbytes,
                host_stored_bytes=0,
                work_conserving=True,
            )
        )
    cm = CostModel.from_reports(reports)
    assert abs(cm.blob_bandwidth - 2e9) / 2e9 < 1e-6
    assert abs(cm.host_device_bandwidth - 32e9) / 32e9 < 1e-6
    assert cm.barrier_minibatches == 2
    assert abs(cm.minibatch_seconds - 0.5) < 1e-9
    assert abs(cm.rendezvous_seconds - 5.0) < 1e-9
    # the calibrated model reproduces the measured end-to-end downtime
    cb = reports[0].device_stored_bytes
    measured = (
        reports[0].barrier_seconds
        + reports[0].dump_seconds
        + reports[0].upload_seconds
        + reports[0].download_seconds
        + reports[0].restore_seconds
    )
    assert abs(cm.migrate_seconds(cb) - measured) / measured < 1e-6


def _report(i, nbytes, blob_bw, src=None, dst=None):
    return MigrationReport(
        job_id=f"j{i}",
        from_physical=4,
        to_physical=2,
        barrier_seconds=1.0,
        barrier_minibatches=2,
        dump_seconds=nbytes / 32e9,
        upload_seconds=nbytes / blob_bw,
        download_seconds=nbytes / blob_bw,
        restore_seconds=5.0,
        total_seconds=0.0,
        device_stored_bytes=nbytes,
        host_stored_bytes=0,
        work_conserving=True,
        src_region=src,
        dst_region=dst,
    )


def test_from_reports_fits_per_region_pair_bandwidths():
    """Reports carrying src/dst regions calibrate a RegionTopology: intra
    reports set the base blob tier, each measured cross pair gets its own
    fitted link, and unmeasured pairs fall back to the slowest tier."""
    gib = 1 << 30
    reports = (
        [_report(i, 4 * gib, 2e9, "r0", "r0") for i in range(2)]
        + [_report(10 + i, 4 * gib, 0.5e9, "r0", "r1") for i in range(2)]
        + [_report(20 + i, 4 * gib, 0.25e9, "r0", "r2") for i in range(2)]
    )
    cm = CostModel.from_reports(reports)
    assert abs(cm.blob_bandwidth - 2e9) / 2e9 < 1e-6
    topo = cm.topology
    assert topo is not None
    assert abs(topo.bandwidth("r0", "r1") - 0.5e9) / 0.5e9 < 1e-6
    assert abs(topo.bandwidth("r0", "r2") - 0.25e9) / 0.25e9 < 1e-6
    # unmeasured pair: the slowest fitted tier, not intra speed
    assert topo.bandwidth("r1", "r2") == topo.cross_bandwidth
    assert abs(topo.cross_bandwidth - 0.25e9) / 0.25e9 < 1e-6
    # the calibrated model reproduces each measured end-to-end downtime
    for r in (reports[0], reports[2], reports[4]):
        measured = (
            r.barrier_seconds
            + r.dump_seconds
            + r.upload_seconds
            + r.download_seconds
            + r.restore_seconds
        )
        charged = cm.migrate_seconds(
            r.device_stored_bytes, r.src_region, r.dst_region
        )
        assert abs(charged - measured) / measured < 1e-6
    # an explicitly-passed topology is never overwritten by the fit
    fixed = RegionTopology.tiered(["r0", "r1"])
    cm2 = CostModel.from_reports(reports, topology=fixed)
    assert cm2.topology is fixed


def test_victim_selection_prefers_cheap_checkpoints():
    """Two equal-tier running jobs, capacity for one: the survivor must be
    the one whose checkpoint is expensive to move, regardless of arrival
    order."""
    for cheap_first in (True, False):
        fleet = Fleet([Region("r0", [Cluster("r0c0", "r0", 8)])])
        cheap = Job(
            id="cheap",
            tier="standard",
            demand_gpus=8,
            gpu_hours=8.0,
            arrival=0.0 if cheap_first else 100.0,
            min_gpus=8,
            checkpoint_bytes=1 << 28,
        )
        costly = Job(
            id="costly",
            tier="standard",
            demand_gpus=8,
            gpu_hours=8.0,
            arrival=100.0 if cheap_first else 0.0,
            min_gpus=8,
            checkpoint_bytes=64 << 30,
        )
        for j in (cheap, costly):
            j.allocated = 8
            j.cluster = "r0c0"
            j.ever_ran = True
            j.account.record(0.0, 1800.0, 8)
        policy = ElasticPolicy(cost_model=CostModel())
        decision = policy.decide(1800.0, [cheap, costly], fleet)
        assert decision.alloc["costly"][0] == 8, f"cheap_first={cheap_first}"
        assert decision.alloc["cheap"][0] == 0


def test_expansion_gated_by_resize_downtime():
    """Opportunistic scale-up of a running job is a splice resize; it must
    not fire when the resize downtime outweighs one interval's gain."""
    fleet = Fleet([Region("r0", [Cluster("r0c0", "r0", 100)])])

    def steady_job():
        j = Job(
            id="j",
            tier="standard",
            demand_gpus=10,
            gpu_hours=100.0,
            arrival=0.0,
            min_gpus=1,
        )
        j.allocated = 10
        j.cluster = "r0c0"
        j.ever_ran = True
        j.account.record(0.0, 1800.0, 10)
        return j

    costly = ElasticPolicy(cost_model=CostModel.uniform(3600.0), interval_hint=300.0)
    d = costly.decide(1800.0, [steady_job()], fleet)
    assert d.alloc["j"][0] == 10  # resize would burn more than it gains

    cheap = ElasticPolicy(cost_model=CostModel.uniform(6.0), interval_hint=300.0)
    d = cheap.decide(1800.0, [steady_job()], fleet)
    assert d.alloc["j"][0] == 20  # cheap resize: expansion proceeds


def test_running_jobs_prefer_in_region_moves():
    """A running job forced off its cluster lands in its own region when
    a same-region cluster fits, even if another region has more room."""
    r0_clusters = [Cluster("r0c0", "r0", 16), Cluster("r0c1", "r0", 32)]
    fleet = Fleet(
        [Region("r0", r0_clusters), Region("r1", [Cluster("r1c0", "r1", 64)])],
        topology=RegionTopology.tiered(["r0", "r1"]),
    )
    # running at 16/24 it is below its 0.70 guarantee, so the policy must
    # grow it to full demand — which no longer fits its current cluster
    mover = Job(
        id="mover",
        tier="standard",
        demand_gpus=24,
        gpu_hours=24.0,
        arrival=0.0,
        min_gpus=1,
    )
    mover.allocated = 16
    mover.cluster = "r0c0"
    mover.ever_ran = True
    mover.account.record(0.0, 1800.0, 16)
    policy = ElasticPolicy(expand_factor=1.0, cost_model=CostModel())
    decision = policy.decide(1800.0, [mover], fleet)
    gpus, cluster = decision.alloc["mover"]
    assert gpus == 24
    assert cluster == "r0c1", "should stay in-region despite r1c0 being freer"
    assert decision.migrations == ["mover"]

"""Minimal stand-in for ``hypothesis`` so the tier-1 suite collects and
runs in hermetic containers where dev dependencies cannot be installed.

Installed by ``conftest.py`` into ``sys.modules`` ONLY when the real
``hypothesis`` is absent (``pip install -r requirements-dev.txt`` gets the
real thing, which always takes precedence).

It implements exactly the subset this repo's tests use:

  @settings(max_examples=N, deadline=None)
  @given(x=st.integers(a, b), y=st.sampled_from([...]),
         z=st.lists(st.integers(a, b).map(f), min_size=i, max_size=j))

Example generation is deterministic pseudo-random (seeded per test by the
test's qualified name), so failures are reproducible run-to-run.  There is
no shrinking — the fallback reports the first failing example as-is.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
from typing import Any, Callable, List


class SearchStrategy:
    """A sampler: ``example(rng)`` draws one value."""

    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        def draw(rng: random.Random) -> Any:
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate too restrictive")
        return SearchStrategy(draw)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.getrandbits(1)))


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_ignored) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(options) -> SearchStrategy:
    opts = list(options)
    return SearchStrategy(lambda rng: rng.choice(opts))


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rng: random.Random) -> List[Any]:
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]
    return SearchStrategy(draw)


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def one_of(*strategies) -> SearchStrategy:
    strats = list(strategies)
    return SearchStrategy(lambda rng: rng.choice(strats).example(rng))


class settings:
    """Decorator recording run options; consumed by ``given`` below."""

    def __init__(self, max_examples: int = 100, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._fallback_settings = self
        return fn


def given(**strategies):
    """Drive the wrapped test with ``max_examples`` deterministic draws.

    First example is always drawn from a fixed seed derived from the test
    name, so reruns exercise identical cases.
    """
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings is conventionally stacked ABOVE @given, i.e. applied
            # to this wrapper after decoration — so resolve at call time,
            # wrapper first
            base = getattr(wrapper, "_fallback_settings", None) \
                or getattr(fn, "_fallback_settings", None)
            n = base.max_examples if base is not None else 100
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for i in range(n):
                drawn = {name: s.example(rng)
                         for name, s in strategies.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (draw {i + 1}/{n}): {drawn!r}"
                    ) from e
            return None

        # keep pytest from trying to fixture-inject the strategy params
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items()
                  if name not in strategies]
        wrapper.__signature__ = sig.replace(parameters=params)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return decorate


def build_module() -> types.ModuleType:
    """Assemble ``hypothesis`` and ``hypothesis.strategies`` modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)
    mod.__version__ = "0.0-fallback"

    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "floats", "sampled_from", "lists",
                 "just", "one_of"):
        setattr(st_mod, name, globals()[name])
    st_mod.SearchStrategy = SearchStrategy
    mod.strategies = st_mod
    return mod

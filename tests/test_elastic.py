"""Transparent elasticity (§5): work conservation and trajectory invariance."""
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.core.checkpoint import CheckpointStore
from repro.core.elastic import ElasticRuntime
from repro.core.migration import checkpoint_job, migrate

CFG = get_smoke_config("olmo-1b")
TCFG = TrainConfig(total_steps=40, warmup_steps=2, learning_rate=1e-3)
W, G, S = 4, 8, 32


def test_trajectory_invariant_under_resize():
    """Resizes mid-run must not change the training trajectory (to float
    accumulation-order tolerance) — the work-conserving claim."""
    full = ElasticRuntime(CFG, TCFG, W, W, G, S)
    h_full = full.run_steps(7)

    elastic = ElasticRuntime(CFG, TCFG, W, W, G, S)
    elastic.run_steps(2)
    elastic.resize(1)         # scale down 4 GPUs -> 1 (4-way splice)
    elastic.run_steps(3)
    elastic.resize(2)         # scale up to 2
    elastic.run_steps(2)

    for a, b in zip(h_full, elastic.history):
        assert abs(a["loss"] - b["loss"]) / a["loss"] < 1e-3, (a, b)


def test_resize_is_instant_on_state():
    rt = ElasticRuntime(CFG, TCFG, W, W, G, S)
    rt.run_steps(1)
    step_before = int(rt.state["step"])
    ev = rt.resize(1)
    assert ev["at_step"] == step_before      # no lost work
    assert rt.splice == 4


def test_invalid_resize_rejected():
    rt = ElasticRuntime(CFG, TCFG, W, W, G, S)
    with pytest.raises(AssertionError):
        rt.resize(3)                         # 4 % 3 != 0


def test_zero_partial_sharding_blocks_oversplice():
    import dataclasses
    tcfg = dataclasses.replace(TCFG, zero_shard_factor=2)
    rt = ElasticRuntime(CFG, tcfg, 4, 4, G, S)
    rt.resize(2)                             # splice 2 == max allowed
    with pytest.raises(ValueError):
        rt.resize(1)                         # splice 4 > 4/2


def test_snapshot_resume_bit_exact():
    rt = ElasticRuntime(CFG, TCFG, W, 2, G, S)
    rt.run_steps(3)
    snap = rt.snapshot()
    resumed = ElasticRuntime.from_snapshot(CFG, TCFG, snap, 2, G, S)
    a = rt.run_steps(2)
    b = resumed.run_steps(2)
    for x, y in zip(a, b):
        assert x["loss"] == y["loss"]        # BIT exact


def test_migration_work_conserving():
    rt = ElasticRuntime(CFG, TCFG, W, 4, G, S)
    rt.run_steps(2)
    store = CheckpointStore()
    # same physical count -> BIT-exact resume
    same_rt, report = migrate(rt, store, "mig-same", 4, CFG, TCFG, G, S)
    assert report.work_conserving
    assert report.barrier_minibatches <= 2
    l_old = rt.run_steps(1)[0]["loss"]
    assert same_rt.run_steps(1)[0]["loss"] == l_old
    # migrate + scale-down: work-conserving, trajectory equal to float
    # accumulation-order tolerance (splice changes the reduction order)
    rt2 = ElasticRuntime(CFG, TCFG, W, 4, G, S)
    rt2.run_steps(2)
    store2 = CheckpointStore()
    new_rt, report2 = migrate(rt2, store2, "mig-down", 2, CFG, TCFG, G, S)
    assert report2.work_conserving
    l_new = new_rt.run_steps(1)[0]["loss"]
    assert abs(l_new - l_old) / l_old < 1e-4


def test_checkpoint_size_independent_of_world_size():
    sizes = {}
    for w in (2, 4):
        rt = ElasticRuntime(CFG, TCFG, w, w, G, S)
        rt.run_steps(1)
        store = CheckpointStore()
        stats = checkpoint_job(rt, store, "job")
        sizes[w] = stats.device_stored_bytes
    assert sizes[2] == sizes[4]              # Table 4: S_G dedup across DP

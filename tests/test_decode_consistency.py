"""Decode-vs-prefill consistency: the incremental (KV/SSM cache) path must
produce the same logits as re-running prefill on the extended prompt."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import decode_step_fn, init_params, prefill_fn
from repro.models.frontend import synth_extra_inputs

# dense, GQA+SWA (ring cache), SSM, hybrid, MoE, enc-dec, VLM
ARCHS = ["olmo-1b", "h2o-danube-3-4b", "mamba2-130m", "zamba2-1.2b",
         "granite-moe-3b-a800m", "whisper-base", "llama-3.2-vision-11b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch, rng_key):
    cfg = get_smoke_config(arch)
    # float32 compute for a tight comparison
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe:
        # capacity drops are routing-history-dependent; give the router
        # enough capacity that no token drops (exactness is then required)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    b, s = 2, 160 if cfg.sliding_window else 48   # exceed the SWA window
    params = init_params(cfg, rng_key)
    tokens = jax.random.randint(rng_key, (b, s + 1), 0, cfg.vocab_size)
    extras = synth_extra_inputs(cfg, b, rng_key)

    batch_s = {"tokens": tokens[:, :s], **extras}
    batch_s1 = {"tokens": tokens, **extras}

    logits_s, state = jax.jit(
        lambda p, x: prefill_fn(p, x, cfg, cache_len=s + 4))(
        params, batch_s)
    logits_ref, _ = jax.jit(lambda p, x: prefill_fn(p, x, cfg))(
        params, batch_s1)

    # decode the next token from the cache: must match prefill(s+1)
    next_tok = tokens[:, s]
    logits_dec, _ = jax.jit(lambda p, st, t: decode_step_fn(p, st, t, cfg))(
        params, state, next_tok)

    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_ref),
                               rtol=2e-3, atol=2e-3)

"""Replica splicing (§5): dedup, squashing, conservative validation."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.splicing import SplicedTrainer
from repro.core.validation import (run_validated_training,
                                   validate_squashing_window)
from repro.optim.zero import (max_splice_factor, spliceable_groups,
                              validate_partial_sharding)


def test_stable_addresses_consistent_across_ranks():
    t = SplicedTrainer(n_ranks=4, seed=1)
    ref = t.stable_addresses(0)
    for r in range(1, 4):
        assert t.stable_addresses(r) == ref


def test_squashing_preserves_trajectory():
    """Squashed and unsquashed execution reach identical parameters."""
    a = SplicedTrainer(n_ranks=3, seed=5, squash=True)
    b = SplicedTrainer(n_ranks=3, seed=5, squash=False)
    for _ in range(10):
        a.run_minibatch()
        b.run_minibatch()
    np.testing.assert_allclose(a.params(0), b.params(0), rtol=1e-6)
    for r in range(3):
        np.testing.assert_allclose(a.params(r), a.params(0))


def test_squashing_elides_work():
    a = SplicedTrainer(n_ranks=4, seed=2, squash=True)
    b = SplicedTrainer(n_ranks=4, seed=2, squash=False)
    for _ in range(8):
        a.run_minibatch()
        b.run_minibatch()
    ma, mb = a.device.metrics, b.device.metrics
    assert ma.squashed_ops == 8 * 3
    assert ma.executed_update_ops < mb.executed_update_ops
    # checksum dedup: squashed run moves far fewer swap-in bytes
    assert ma.swapin_bytes < mb.swapin_bytes
    # exactly one real allreduce per mini-batch per device (§5.1)
    assert ma.allreduces_issued == 8


def test_conservative_validation_accepts_conforming_model():
    t = SplicedTrainer(n_ranks=3, seed=3)
    out = run_validated_training(t, 9, validate_every=3)
    assert out["squash_disabled"] is None
    assert all(r.ok for r in out["reports"])


def test_conservative_validation_catches_pathological_model():
    """A rank-dependent update violates the mutation-identity invariant:
    validation must catch it and fall back (correctness -> perf problem)."""
    def bad(p, o, g, rank):
        return p - 0.05 * (0.9 * o + g) - 1e-3 * rank, 0.9 * o + g

    t = SplicedTrainer(n_ranks=3, seed=4, update_fn=bad)
    out = run_validated_training(t, 6, validate_every=2)
    assert out["squash_disabled"] is not None
    # fallback still yields consistent per-rank state histories (swap mode)
    assert t.params(0).shape == (64,)


def test_validation_report_structure():
    rep = validate_squashing_window({0: {"P": (1, "x")}, 1: {"P": (1, "x")}})
    assert rep.ok and rep.n_ranks_checked == 2
    rep2 = validate_squashing_window({0: {"P": (1, "x")}, 1: {"P": (2, "x")}})
    assert not rep2.ok


@settings(max_examples=20, deadline=None)
@given(dp=st.sampled_from([2, 4, 8, 16]), shard=st.sampled_from([1, 2, 4]))
def test_zero_partial_sharding_rules(dp, shard):
    """§5.4: DP = k x shard supports at most k-way splicing; groups hold
    ranks with identical shards only."""
    if dp % shard:
        return
    k = max_splice_factor(dp, shard)
    assert k == dp // shard
    validate_partial_sharding(dp, shard, k)
    with pytest.raises(ValueError):
        validate_partial_sharding(dp, shard, k * 2)
    groups = spliceable_groups(dp, shard)
    assert len(groups) == shard
    assert sorted(sum(groups, [])) == list(range(dp))

"""Elastic inference serving tier: SLO replica groups on the shared fleet.

Five contracts from docs/serving.md:

1. The analytic qps -> replicas model is monotone and consistent (decode
   roofline, memory-fit TP degree, Holt forecaster, seeded trace).
2. Reclaim: when a traffic spike retargets a service upward, the
   guaranteed-first admission claws back loaned GPUs within the
   CostModel-charged deadline.
3. Loaned capacity is conserved: loaned GPU-hours never exceed the
   reserved quota's idle hours, loaning is measurable for best-effort
   training, and the no-loaning baseline loans exactly nothing.
4. Serving preserves the decision-digest equivalence gate: all four
   {JobTable, plain jobs} x {vectorized, scalar reference} combinations
   walk the same decision sequence with services active.
5. The predictive (Holt) autoscaler strictly beats the reactive baseline
   on SLO attainment for the seeded trace: pre-warming lands the resize
   downtime before the ramp instead of inside the window.
"""

import hashlib

import numpy as np
import pytest

from repro.configs import get_config
from repro.scheduler.costs import CostModel
from repro.scheduler.policy import ElasticPolicy
from repro.scheduler.serving import (
    ServiceSpec,
    ServiceTable,
    ServingConfig,
    ServingTier,
    TrafficConfig,
    TrafficTrace,
)
from repro.scheduler.simulator import (
    FleetSimulator,
    SimConfig,
    make_fleet,
    synth_workload,
)
from repro.serving.engine import (
    ReplicaProfile,
    decode_step_seconds,
    min_gpus_for_memory,
)

# the seeded scenario every simulator test here drives: a 2,048-GPU fleet
# under heavy best-effort training load, two toy services whose diurnal
# peaks keep the reserved quota ~13% of the fleet, traffic seed chosen so
# the 24h trace carries ramps steep enough to separate the autoscalers
TOY_PROFILE = ReplicaProfile(
    name="toy",
    gpus_per_replica=8,
    batch=64,
    p99_decode_seconds=0.03,
    tokens_per_second=2000.0,
    qps_per_replica=16.0,
    weight_bytes=8 << 30,
)
SERVICES = [
    ServiceSpec("chat", TOY_PROFILE, peak_qps=16.0 * 8),
    ServiceSpec("code", TOY_PROFILE, peak_qps=16.0 * 5),
]
TRAFFIC_SEED = 11
HORIZON = 24 * 3600.0


def _run(
    autoscaler="predictive",
    loaning=True,
    vec_policy=True,
    job_table=True,
    horizon=HORIZON,
    digest=False,
):
    fleet = make_fleet(2, 2, 512, gpus_per_node=8)
    jobs = synth_workload(
        500, fleet.total(), seed=3, mean_interarrival=90.0, work_scale=0.3
    )
    scfg = ServingConfig(
        services=SERVICES,
        traffic=TrafficConfig(seed=TRAFFIC_SEED),
        autoscaler=autoscaler,
        loaning=loaning,
    )
    cfg = SimConfig(
        horizon_seconds=horizon,
        vectorized=True,
        job_table=job_table,
        serving=scfg,
    )
    policy = ElasticPolicy(vectorized=vec_policy, cost_model=cfg.costs())
    if digest:
        policy = _DigestPolicy(policy)
    sim = FleetSimulator(fleet, jobs, policy, cfg)
    res = sim.run()
    return res, sim, policy


class _DigestPolicy:
    """Folds every Decision into a running hash (the sched_scale bench's
    equivalence recipe) so the serving test compares full decision
    sequences, not aggregates that could mask compensating divergences."""

    def __init__(self, inner):
        self.inner = inner
        self.name = inner.name
        self.digest = hashlib.sha256()

    def bind_costs(self, cost_model, interval_hint):
        self.inner.bind_costs(cost_model, interval_hint)

    def decide(self, now, jobs, fleet):
        decision = self.inner.decide(now, jobs, fleet)
        payload = repr(
            (
                sorted(decision.alloc.items()),
                decision.preemptions,
                decision.migrations,
            )
        )
        self.digest.update(payload.encode())
        return decision


# -- 1. analytic model ----------------------------------------------------


def test_qps_to_replicas_monotone():
    cfg = get_config("olmo-1b")
    prof = ReplicaProfile.from_config(cfg, slo_ms=30.0)
    assert prof.qps_per_replica > 0
    assert prof.p99_decode_seconds <= 0.030
    # replicas_for is monotone in qps and inverse to qps_per_replica
    qps = np.linspace(0.0, 20 * prof.qps_per_replica, 50)
    reps = [prof.replicas_for(q) for q in qps]
    assert all(b >= a for a, b in zip(reps, reps[1:]))
    assert prof.replicas_for(prof.qps_per_replica) == 1
    assert prof.replicas_for(prof.qps_per_replica + 1e-6) == 2
    # headroom costs replicas, never saves them
    assert prof.replicas_for(qps[-1], utilization=0.5) >= prof.replicas_for(
        qps[-1], utilization=1.0
    )


def test_decode_roofline_monotone():
    cfg = get_config("yi-9b")
    g = min_gpus_for_memory(cfg)
    assert g & (g - 1) == 0  # a power of two (TP degree)
    # step time grows with batch (flops side) and context (KV side)...
    steps = [decode_step_seconds(cfg, b, g) for b in (1, 8, 64, 256)]
    assert all(b > a for a, b in zip(steps, steps[1:]))
    assert decode_step_seconds(cfg, 8, g, context_len=8192) > decode_step_seconds(
        cfg, 8, g, context_len=512
    )
    # ...and shrinks when the weights shard over more GPUs
    assert decode_step_seconds(cfg, 8, 2 * g) < decode_step_seconds(cfg, 8, g)
    # a tighter SLO can only lower the sustainable qps per replica
    loose = ReplicaProfile.from_config(cfg, slo_ms=60.0)
    tight = ReplicaProfile.from_config(cfg, slo_ms=40.0)
    assert tight.qps_per_replica / tight.gpus_per_replica <= (
        loose.qps_per_replica / loose.gpus_per_replica
    )


def test_traffic_trace_deterministic_and_bounded():
    tcfg = TrafficConfig(seed=TRAFFIC_SEED)
    a = TrafficTrace(SERVICES, tcfg, HORIZON)
    b = TrafficTrace(SERVICES, tcfg, HORIZON)
    assert np.array_equal(a.qps, b.qps)
    other = TrafficTrace(SERVICES, TrafficConfig(seed=TRAFFIC_SEED + 1), HORIZON)
    assert not np.array_equal(a.qps, other.qps)
    # bounded by trough and peak * max spike amplitude
    for i, spec in enumerate(SERVICES):
        assert a.qps[i].min() >= tcfg.trough_fraction * spec.peak_qps - 1e-9
        assert a.qps[i].max() <= spec.peak_qps * tcfg.spike_amplitude[1] + 1e-9
    assert np.all(a.window_peak(0.0, 3600.0) <= a.peak() + 1e-9)


def test_traffic_trace_rejects_queries_past_horizon():
    """A simulation horizon longer than the trace must surface as an
    error, not silently replay the final sample as flat qps forever."""
    tcfg = TrafficConfig(seed=TRAFFIC_SEED)
    trace = TrafficTrace(SERVICES, tcfg, 3600.0)
    end = trace.end_seconds
    assert end >= 3600.0  # the trace covers the horizon it was built for
    trace.at(end)  # the boundary itself is in range
    horizon = trace.horizon_seconds
    assert horizon == 3600.0
    # the final in-simulation window may overhang the trace end by part
    # of a tick: t1 clamps to the samples that exist (documented, and
    # bounded by tick - sample thanks to the trailing sample padding)
    short = trace.window_peak(horizon, horizon + 600.0)
    assert short.shape == (len(SERVICES),)
    full = trace.window_peak(horizon, end)
    assert np.array_equal(short, full)  # the clamp reads the same samples
    with pytest.raises(ValueError):
        trace.at(end + 1.0)
    # a window STARTING in the trailing padding is an out-of-horizon
    # query, not a legitimate final-window overhang: it must raise like
    # ``at`` does, not silently read padding samples
    with pytest.raises(ValueError):
        trace.window_peak(horizon + 1.0, horizon + 600.0)
    with pytest.raises(ValueError):
        trace.window_peak(end + 1.0, end + 600.0)
    # driving a 2h simulation off a 1h trace trips the guard instead of
    # flat-lining: the first tick past the trace end raises
    for now in np.arange(0.0, 2 * 3600.0, 300.0):
        if now > end:
            with pytest.raises(ValueError):
                trace.at(float(now))
            break
        trace.at(float(now))
    else:  # pragma: no cover - the trace would have to cover 2h
        raise AssertionError("guard never engaged")


def test_holt_forecaster_leads_a_ramp():
    spec = SERVICES[:1]
    table = ServiceTable(spec, reserved_replicas=np.array([64]))
    cfg = ServingConfig(services=spec, scale_down_ticks=1)
    # feed a linear ramp: after warm-up the trend term must push the
    # predictive target ABOVE what the same qps gives a reactive scaler
    targets = [
        int(table.retarget(cfg, np.array([float(q)]))[0])
        for q in range(10, 200, 10)
    ]
    reactive = ServingConfig(services=spec, autoscaler="reactive", scale_down_ticks=1)
    rtable = ServiceTable(spec, reserved_replicas=np.array([64]))
    rtargets = [
        int(rtable.retarget(reactive, np.array([float(q)]))[0])
        for q in range(10, 200, 10)
    ]
    assert targets[-1] > rtargets[-1]
    assert all(p >= r for p, r in zip(targets[3:], rtargets[3:]))


# -- 2. reclaim beats the deadline ---------------------------------------


def test_reclaim_beats_deadline_under_spikes():
    res, sim, _ = _run("predictive", loaning=True)
    assert res.serving_windows > 0
    assert res.serving_reclaims > 0  # the seeded trace does spike
    assert res.serving_reclaim_deadline_seconds > 0
    assert res.serving_reclaim_max_seconds <= res.serving_reclaim_deadline_seconds
    assert res.serving_reclaims_over_deadline == 0
    # and the attainment that reclaim protects holds the bench bar
    assert res.serving_slo_attainment >= 0.99


# -- 3. loaned capacity is conserved -------------------------------------


def test_loaned_capacity_conservation():
    res, sim, _ = _run("predictive", loaning=True)
    hours = HORIZON / 3600.0
    assert res.serving_loaned_gpu_hours > 0.0
    # can never loan more than the reserved quota's full idle hours
    assert res.serving_loaned_gpu_hours <= res.serving_reserved_gpus * hours
    # serving itself never consumes more than its reservation
    assert res.serving_gpu_hours <= res.serving_reserved_gpus * hours + 1e-6
    noloan, sim_n, _ = _run("predictive", loaning=False)
    assert noloan.serving_loaned_gpu_hours == 0.0
    assert noloan.serving_reclaims == 0  # pinned at reserved: no deficits
    # loaning converts idle reserved GPUs into best-effort training
    # throughput (Aryl's claim): busy GPU-hours delivered to training rise
    train = sim.busy_gpu_seconds / 3600.0 - res.serving_gpu_hours
    train_noloan = sim_n.busy_gpu_seconds / 3600.0 - noloan.serving_gpu_hours
    assert train > train_noloan


# -- 4. digest equivalence with services active --------------------------


def test_policy_paths_equivalent_with_services():
    digests = {}
    signatures = {}
    for vec_policy in (True, False):
        for job_table in (True, False):
            res, _, policy = _run(
                "predictive",
                loaning=True,
                vec_policy=vec_policy,
                job_table=job_table,
                horizon=8 * 3600.0,
                digest=True,
            )
            key = (vec_policy, job_table)
            digests[key] = policy.digest.hexdigest()
            signatures[key] = (
                res.serving_windows,
                res.serving_violations,
                res.serving_reclaims,
                round(res.serving_loaned_gpu_hours, 6),
                res.preemptions,
                res.migrations,
                res.completed,
            )
    ref = digests[(True, True)]
    assert all(d == ref for d in digests.values()), digests
    sig = signatures[(True, True)]
    assert all(s == sig for s in signatures.values()), signatures


# -- 5. predictive beats reactive ----------------------------------------


def test_predictive_beats_reactive_attainment():
    pred, _, _ = _run("predictive", loaning=True)
    react, _, _ = _run("reactive", loaning=True)
    assert pred.serving_windows == react.serving_windows
    assert pred.serving_violations < react.serving_violations
    assert pred.serving_slo_attainment > react.serving_slo_attainment


def test_reclaim_deadline_is_cost_model_charged():
    scfg = ServingConfig(services=SERVICES, traffic=TrafficConfig(seed=TRAFFIC_SEED))
    tier = ServingTier(
        scfg, tick_seconds=10.0, horizon_seconds=HORIZON, costs=CostModel()
    )
    d = tier.reclaim_deadline()
    assert d > 10.0  # at least a tick plus real preempt+restore time
    pinned = ServingConfig(
        services=SERVICES,
        traffic=TrafficConfig(seed=TRAFFIC_SEED),
        reclaim_deadline_seconds=123.0,
    )
    tier2 = ServingTier(pinned, 10.0, HORIZON, CostModel())
    assert tier2.reclaim_deadline() == 123.0

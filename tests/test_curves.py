"""Concave scaling curves: the family, fitting, Job plumbing, validation.

Covers the curve math itself (``scheduler/curves.py``), the derivation
helpers fed by the roofline/hillclimb step-time estimates, the Job /
JobTable columns the policy and simulator consume, and the construction
validation regressions that rode along (min_gpus bounds, the
``snap_time == 0.0`` sentinel fix).
"""
import math

import numpy as np
import pytest

from repro.scheduler.curves import (
    MAX_SCALE,
    curve_from_step_seconds,
    fit_knee,
    scaling_eff,
    scaling_eff_vec,
    synth_curve_params,
    validate_curve,
)
from repro.scheduler.job_table import JobTable
from repro.scheduler.simulator import synth_workload
from repro.scheduler.types import Job


def test_flat_sentinel_is_the_seed_linear_model():
    for d in (1, 8, 64):
        for g in range(0, 3 * d + 1):
            assert scaling_eff(g, d) == min(g / d, MAX_SCALE)
            assert scaling_eff(g, d, knee=0, sat_slope=0.3) == min(g / d, MAX_SCALE)


def test_curve_linear_below_knee_and_sloped_above():
    d, knee, sat = 64, 96, 0.25
    # below (and at) the knee: identical to linear
    for g in (1, 32, 64, 96):
        assert scaling_eff(g, d, knee, sat) == min(g / d, MAX_SCALE)
    # above the knee: marginal GPU buys sat/d, continuous at the knee
    assert scaling_eff(97, d, knee, sat) == pytest.approx((96 + 0.25) / 64)
    assert scaling_eff(128, d, knee, sat) == pytest.approx((96 + 0.25 * 32) / 64)
    # capped at the 2x fleet limit no matter the slope
    assert scaling_eff(10_000, d, knee=64, sat_slope=1.0) == MAX_SCALE
    # concave: marginal gains never increase
    gains = [
        scaling_eff(g + 1, d, knee, sat) - scaling_eff(g, d, knee, sat)
        for g in range(1, 2 * d + 4)
    ]
    for earlier, later in zip(gains, gains[1:]):
        assert later <= earlier + 1e-12


def test_vector_form_matches_scalar():
    rng = np.random.Generator(np.random.Philox(3))
    d = 2 ** rng.integers(0, 8, 200)
    knee = np.where(
        rng.integers(0, 2, 200) > 0, rng.integers(1, 3, 200) * d, 0
    ).astype(np.int64)
    knee = np.minimum(knee, 2 * d)
    sat = rng.uniform(0.0, 1.0, 200)
    g = rng.integers(0, 4 * d.max(), 200)
    vec = scaling_eff_vec(g, d, knee, sat)
    for i in range(200):
        assert vec[i] == scaling_eff(int(g[i]), int(d[i]), int(knee[i]), sat[i])


def test_validate_curve_rejects_non_members():
    validate_curve(64, 0, 1.0)  # flat sentinel
    validate_curve(64, 64, 0.0)  # hard saturation at demand
    validate_curve(64, 128, 0.5)
    with pytest.raises(ValueError):
        validate_curve(64, -1, 0.5)
    with pytest.raises(ValueError):
        validate_curve(64, 32, 0.5)  # knee below demand: nominal unreachable
    with pytest.raises(ValueError):
        validate_curve(64, 96, 1.5)
    with pytest.raises(ValueError):
        validate_curve(64, 96, -0.1)


def test_fit_knee_recovers_a_planted_curve():
    d, knee, sat = 64, 96, 0.3
    worlds = [16, 32, 64, 80, 96, 112, 128]
    thr = [scaling_eff(w, d, knee, sat) for w in worlds]
    k, s = fit_knee(worlds, thr, d)
    assert k == knee
    assert s == pytest.approx(sat, abs=1e-6)
    validate_curve(d, k, s)


def test_fit_knee_degenerates_to_flat_on_linear_samples():
    d = 64
    worlds = [32, 64, 96, 128]
    thr = [w / d for w in worlds]
    assert fit_knee(worlds, thr, d) == (0, 1.0)
    # too few samples above demand: flat, not a fabricated knee
    assert fit_knee([32, 64], [0.5, 1.0], d) == (0, 1.0)


def test_curve_from_step_seconds_matches_roofline_convention():
    # step time rises sub-linearly past the knee: throughput ~ 1/step
    d, knee, sat = 64, 96, 0.4
    steps = {
        w: 1.0 / scaling_eff(w, d, knee, sat) for w in (32, 64, 96, 112, 128)
    }
    k, s = curve_from_step_seconds(steps, d)
    assert k == knee
    assert s == pytest.approx(sat, abs=1e-6)
    with pytest.raises(ValueError):
        curve_from_step_seconds({64: 0.0}, d)


def test_synth_curve_params_stay_in_family():
    rng = np.random.Generator(np.random.Philox(11))
    demand = 2 ** rng.integers(3, 9, 500)
    knee, sat = synth_curve_params(rng, demand)
    for d, k, s in zip(demand, knee, sat):
        validate_curve(int(d), int(k), float(s))
        assert d <= k <= 2 * d


def _job(**kw):
    base = dict(id="j", tier="standard", demand_gpus=64, gpu_hours=64.0, arrival=0.0)
    base.update(kw)
    return Job(**base)


def test_job_rate_consumes_the_curve():
    flat = _job()
    curved = _job(knee_gpus=96, sat_slope=0.25)
    for alloc in (16, 64, 96):
        flat.allocated = curved.allocated = alloc
        assert curved.rate() == flat.rate()  # identical below the knee
    flat.allocated = curved.allocated = 128
    assert flat.rate() == pytest.approx(2.0 / flat.ideal_seconds)
    assert curved.rate() == pytest.approx(
        ((96 + 0.25 * 32) / 64) / curved.ideal_seconds
    )
    assert curved.rate() < flat.rate()
    # splice overhead still applies below demand only
    flat.allocated = curved.allocated = 32
    assert curved.rate() == pytest.approx(
        (32 / 64) * (1.0 - curved.splice_overhead) / curved.ideal_seconds
    )


def test_job_construction_rejects_bad_curves_with_job_id():
    with pytest.raises(ValueError, match="job j:.*knee"):
        _job(knee_gpus=32)
    with pytest.raises(ValueError, match="job j:.*sat_slope"):
        _job(knee_gpus=96, sat_slope=2.0)


def test_job_construction_validates_min_gpus_bounds():
    _job(min_gpus=1)
    _job(min_gpus=64)
    with pytest.raises(ValueError, match="min_gpus"):
        _job(min_gpus=0)
    with pytest.raises(ValueError, match="min_gpus"):
        _job(min_gpus=-4)
    with pytest.raises(ValueError, match="min_gpus"):
        _job(min_gpus=65)
    with pytest.raises(ValueError, match="demand_gpus"):
        _job(demand_gpus=0, min_gpus=1)


def test_snap_time_zero_survives_construction():
    """A restored/replayed job with a legitimate snapshot AT t=0 must keep
    it — the old ``<= 0`` clamp overwrote it with the arrival."""
    j = _job(arrival=500.0, snap_time=0.0, snap_progress=0.25)
    assert j.snap_time == 0.0
    assert j.snap_progress == 0.25
    # the sentinel default still fills the arrival (initial restartable)
    assert _job(arrival=500.0).snap_time == 500.0


def test_job_table_round_trips_curve_columns():
    t = JobTable(capacity=4)
    j = _job(knee_gpus=96, sat_slope=0.25)
    t.adopt(j)
    assert j.knee_gpus == 96
    assert j.sat_slope == 0.25
    j.allocated = 128
    curved_rate = j.rate()  # rate() reads the columns through TableJob
    assert curved_rate == pytest.approx(((96 + 0.25 * 32) / 64) / j.ideal_seconds)
    t.detach(j)
    assert j.knee_gpus == 96
    assert j.sat_slope == 0.25
    assert j.rate() == curved_rate


def test_synth_workload_curves_leave_base_trace_untouched():
    plain = synth_workload(200, 4096, seed=7)
    curved = synth_workload(200, 4096, seed=7, curves=True)
    assert all(j.knee_gpus == 0 and j.sat_slope == 1.0 for j in plain)
    n_curved = 0
    for a, b in zip(plain, curved):
        # arrivals/sizes/tiers/floors byte-identical: the curve draw uses
        # a separate stream
        assert (a.id, a.tier, a.demand_gpus, a.gpu_hours, a.arrival, a.min_gpus) == (
            b.id,
            b.tier,
            b.demand_gpus,
            b.gpu_hours,
            b.arrival,
            b.min_gpus,
        )
        validate_curve(b.demand_gpus, b.knee_gpus, b.sat_slope)
        assert b.demand_gpus <= b.knee_gpus <= 2 * b.demand_gpus
        if b.knee_gpus < 2 * b.demand_gpus or b.sat_slope < 1.0:
            n_curved += 1
    assert n_curved > 150  # the draw actually produces concave curves
    # and the draw itself is deterministic
    again = synth_workload(200, 4096, seed=7, curves=True)
    assert all(
        (a.knee_gpus, a.sat_slope) == (b.knee_gpus, b.sat_slope)
        for a, b in zip(curved, again)
    )


def test_curve_roundtrip_through_fit_is_stable():
    """Fitting samples generated from a fitted curve returns the same
    curve (idempotence of the derivation pipeline)."""
    d = 64
    worlds = [32, 64, 96, 128]
    rng = np.random.Generator(np.random.Philox(5))
    thr = [
        scaling_eff(w, d, 96, 0.2) * float(rng.uniform(0.995, 1.005))
        for w in worlds
    ]
    k1, s1 = fit_knee(worlds, thr, d)
    model = [scaling_eff(w, d, k1, s1) for w in worlds]
    k2, s2 = fit_knee(worlds, model, d)
    assert (k1, s1) == (k2, pytest.approx(s2))
    assert math.isfinite(s2)

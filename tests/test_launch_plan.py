"""Dry-run planning logic (no 512-device lowering here — that's the sweep)."""
import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS
from repro.configs.base import INPUT_SHAPES
from repro.launch.mesh import make_local_mesh
from repro.launch.specs import (SWA_VARIANT_WINDOW, decode_specs,
                                input_specs, plan_pair, state_specs)
from repro.configs.base import TrainConfig


def test_all_40_pairs_planned():
    planned = skipped = 0
    for arch in ASSIGNED_ARCHS:
        for shape in INPUT_SHAPES:
            plan = plan_pair(arch, shape.name)
            if plan.skip_reason:
                skipped += 1
                assert arch == "whisper-base" and shape.name == "long_500k"
            else:
                planned += 1
    assert planned == 39 and skipped == 1


def test_long_context_is_subquadratic():
    """Every non-skipped long_500k plan has O(window) or O(1) state."""
    for arch in ASSIGNED_ARCHS:
        plan = plan_pair(arch, "long_500k")
        if plan.skip_reason:
            continue
        cfg = plan.cfg
        assert cfg.arch_type == "ssm" or cfg.sliding_window > 0, arch
        if plan.swa_variant:
            assert cfg.sliding_window == SWA_VARIANT_WINDOW


def test_decode_cache_sized_by_window():
    plan = plan_pair("yi-9b", "long_500k")          # SWA variant
    st = decode_specs(plan.cfg, plan.shape)
    assert st["kv"]["k"].shape[2] == SWA_VARIANT_WINDOW
    plan2 = plan_pair("yi-9b", "decode_32k")        # full attention
    st2 = decode_specs(plan2.cfg, plan2.shape)
    assert st2["kv"]["k"].shape[2] == 32_768


def test_input_specs_shapes():
    plan = plan_pair("llama-3.2-vision-11b", "train_4k")
    specs = input_specs(plan.cfg, plan.shape)
    assert specs["tokens"].shape == (256, 4096)
    assert specs["labels"].shape == (256, 4096)
    assert specs["image_embeds"].shape == (256, 1601, 1280)

    dplan = plan_pair("olmo-1b", "decode_32k")
    dspecs = input_specs(dplan.cfg, dplan.shape)
    assert dspecs["token"].shape == (128,)


def test_state_specs_no_allocation():
    """eval_shape-based state specs are abstract (no device buffers)."""
    plan = plan_pair("granite-8b", "train_4k")
    st = state_specs(plan.cfg, TrainConfig())
    leaf = jax.tree_util.tree_leaves(st)[0]
    assert isinstance(leaf, jax.ShapeDtypeStruct)
    # full config, real sizes: yi-scale params present abstractly
    total = sum(
        int(jnp.prod(jnp.array(l.shape))) for l in
        jax.tree_util.tree_leaves(st["params"]) if hasattr(l, "shape"))
    assert total > 5e9           # granite-8b ~8B params, never allocated


def test_local_mesh():
    mesh = make_local_mesh()
    assert mesh.devices.size == 1

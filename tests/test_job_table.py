"""JobTable vs plain scalar Jobs: the decide path's state source.

The fleet ``JobTable`` is where every simulator/executor job's numeric
state lives at million-job scale; its contract is that a trace run over
table-backed jobs is *indistinguishable* from the same trace run over
plain scalar ``Job`` objects — identical full decision-hash sequences,
identical ``SimResult`` aggregates and identical per-job terminal state,
under the vectorized and the legacy event loop, with failures on and
off.  CI's bench-smoke job enforces the same property at trace scale
(``sched_scale.py --check-equivalence``).

Mechanically the table mirrors ``FleetSLAAccounts``: slots register on
adopt (the columns grow by doubling), release on detach and freed rows
are reused — pinned here the way ``tests/test_sla_ledger.py`` pins the
ledger's slot lifecycle.
"""
import hashlib

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.sla import FleetSLAAccounts, FleetSlotAccount
from repro.scheduler.job_table import JobTable, JobView, TableJob, shared_table
from repro.scheduler.policy import ElasticPolicy
from repro.scheduler.reliability import FailureTrace
from repro.scheduler.simulator import FleetSimulator, SimConfig
from repro.scheduler.types import Cluster, Fleet, Job, Region

TIER_NAMES = ["premium", "standard", "basic"]

# per-job terminal state folded into the differential digest: everything
# the table stores, read back through whatever the job ended up as
STATE_FIELDS = (
    "allocated",
    "cluster",
    "progress",
    "done_at",
    "queued_since",
    "restore_debt",
    "ever_ran",
    "snap_progress",
    "snap_time",
    "downtime_seconds",
    "downtime_until",
    "preemptions",
    "migrations",
    "resizes",
    "failures",
)


def _spec_trace(seed: int, n_jobs: int):
    """Job constructor kwargs (not objects — each run builds fresh ones,
    since adoption binds the instances to that run's table)."""
    rng = np.random.Generator(np.random.Philox(seed))
    specs = []
    for i in range(n_jobs):
        demand = int(2 ** rng.integers(0, 6))
        specs.append(
            dict(
                id=f"j{i}",
                tier=str(rng.choice(TIER_NAMES)),
                demand_gpus=demand,
                gpu_hours=float(rng.uniform(0.05, 2.0)) * demand,
                arrival=float(rng.uniform(0.0, 6 * 3600.0)),
                min_gpus=max(1, demand // int(2 ** rng.integers(0, 3))),
            )
        )
    return specs


def _fleet():
    return Fleet(
        [
            Region("r0", [Cluster("r0c0", "r0", 64), Cluster("r0c1", "r0", 32)]),
            Region("r1", [Cluster("r1c0", "r1", 64)]),
        ]
    )


class _DigestPolicy:
    def __init__(self, inner):
        self.inner = inner
        self.name = inner.name
        self.digest = hashlib.sha256()

    def bind_costs(self, cost_model, interval_hint):
        self.inner.bind_costs(cost_model, interval_hint)

    def decide(self, now, jobs, fleet):
        decision = self.inner.decide(now, jobs, fleet)
        self.digest.update(
            repr(
                (
                    sorted(decision.alloc.items()),
                    decision.preemptions,
                    decision.migrations,
                )
            ).encode()
        )
        return decision


def _run(specs, job_table, vectorized_loop, failures, sla_ledger=True):
    fleet = _fleet()
    jobs = [Job(**s) for s in specs]
    policy = _DigestPolicy(ElasticPolicy())
    trace = (
        FailureTrace.cluster_outage("r0c0", at=2 * 3600.0, repair_seconds=3600.0)
        if failures
        else None
    )
    sim = FleetSimulator(
        fleet,
        jobs,
        policy,
        SimConfig(
            horizon_seconds=12 * 3600.0,
            vectorized=vectorized_loop,
            job_table=job_table,
            sla_ledger=sla_ledger,
            failures=trace,
        ),
    )
    res = sim.run()
    state = tuple(
        (j.id,) + tuple(getattr(j, f) for f in STATE_FIELDS) for j in sim._jobs_list
    )
    agg = (
        res.utilization,
        res.completed,
        res.preemptions,
        res.migrations,
        res.resizes,
        res.restores,
        res.queue_seconds,
        res.gpu_seconds_dead,
        res.gpu_seconds_idle,
        res.failure_events,
        res.job_failures,
        res.lost_work_gpu_seconds,
        res.goodput_fraction,
        tuple(sorted(res.sla_attainment.items())),
        tuple(sorted(res.mean_jct.items())),
    )
    return policy.digest.hexdigest(), agg, state


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 10**6),
    n_jobs=st.integers(1, 14),
    vec_loop=st.booleans(),
    failures=st.booleans(),
)
def test_table_backed_runs_match_scalar_runs(seed, n_jobs, vec_loop, failures):
    """Random traces run twice — JobTable-backed vs plain scalar Jobs —
    must emit identical decision-hash sequences, aggregates and per-job
    terminal state, on both event loops, with failures on and off."""
    specs = _spec_trace(seed, n_jobs)
    d_t, a_t, s_t = _run(specs, True, vec_loop, failures)
    d_p, a_p, s_p = _run(specs, False, vec_loop, failures)
    assert d_t == d_p, (seed, vec_loop, failures)
    assert a_t == a_p
    assert s_t == s_p


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_table_without_sla_ledger_matches_scalar(seed):
    """``sla_ledger=False`` (scalar accounts) with the table on: the
    policy's SLA consultation falls back per job, everything else stays
    columnar — still identical to the fully scalar run."""
    specs = _spec_trace(seed, 10)
    d_t, a_t, s_t = _run(specs, True, True, False, sla_ledger=False)
    d_p, a_p, s_p = _run(specs, False, True, False, sla_ledger=False)
    assert (d_t, a_t, s_t) == (d_p, a_p, s_p)


# --------------------------------------------------- slot lifecycle
def _mk_job(i: int, demand: int = 8) -> Job:
    return Job(
        id=f"j{i}",
        tier="standard",
        demand_gpus=demand,
        gpu_hours=float(demand),
        arrival=60.0 * i,
    )


def test_adopt_flips_class_and_properties_read_columns():
    table = JobTable(clusters=["c0"], capacity=1)
    j = _mk_job(0)
    slot = table.adopt(j)
    assert isinstance(j, TableJob) and table.slots_in_use == 1
    # property reads come from the columns, as plain Python scalars
    assert j.demand_gpus == 8 and type(j.demand_gpus) is int
    assert j.arrival == 0.0 and type(j.arrival) is float
    assert j.done_at is None and j.cluster is None
    # property writes land in the columns
    j.allocated = 4
    j.cluster = "c0"
    j.progress = 0.25
    assert int(table.allocated[slot]) == 4
    assert int(table.cluster_idx[slot]) == 0
    assert float(table.progress[slot]) == 0.25
    # and column writes are visible through the view
    table.queued_since[slot] = 123.0
    assert j.queued_since == 123.0


def test_detach_restores_plain_job_and_frees_slot_for_reuse():
    table = JobTable(clusters=["c0"], capacity=1)
    j = _mk_job(0)
    slot = table.adopt(j)
    j.allocated = 4
    j.cluster = "c0"
    j.progress = 1.0
    j.done_at = 3600.0
    j.ever_ran = True
    table.detach(j)
    assert type(j) is Job and table.slots_in_use == 0
    # detached state survives exactly
    assert j.allocated == 4 and j.cluster == "c0"
    assert j.progress == 1.0 and j.done_at == 3600.0 and j.ever_ran
    # the freed row is reused by the next adopt, fully reset
    k = _mk_job(1, demand=2)
    assert table.adopt(k) == slot
    assert k.allocated == 0 and k.cluster is None and k.done_at is None
    assert k.demand_gpus == 2


def test_slot_growth_by_doubling():
    table = JobTable(capacity=2)
    jobs = [_mk_job(i) for i in range(9)]
    slots = [table.adopt(j) for j in jobs]
    assert slots == list(range(9))
    assert table.slots_in_use == 9 and table.capacity >= 9
    for i, j in enumerate(jobs):  # state survived the growth
        assert j.arrival == 60.0 * i
    table.detach_batch(np.array(slots[:4]))
    assert table.slots_in_use == 5
    assert all(type(j) is Job for j in jobs[:4])
    assert all(isinstance(j, TableJob) for j in jobs[4:])


def test_shared_table_detection_mixed_and_foreign():
    """The policy's fallback contract, as in ``_shared_ledger``: a plain
    list of same-table views resolves to (table, slots); mixed or
    foreign-table lists fall back to the object path."""
    t1, t2 = JobTable(), JobTable()
    a, b, c = _mk_job(0), _mk_job(1), _mk_job(2)
    t1.adopt(a)
    t1.adopt(b)
    t2.adopt(c)
    table, slots = shared_table([a, b])
    assert table is t1 and list(slots) == [a._slot, b._slot]
    assert shared_table([a, c]) == (None, None)  # foreign table mixed in
    assert shared_table([a, _mk_job(3)]) == (None, None)  # plain Job mixed
    view = JobView(t1, np.array([b._slot], np.int64))
    assert shared_table(view) == (t1, view.slots)
    assert list(view) == [b] and view[0] is b and len(view) == 1


def test_adopted_account_mirrors_ledger_slot_into_column():
    """Ledger slots register lazily; every registration path must sync
    the table's sla_slot column so the policy can trust it."""
    sla = FleetSLAAccounts()
    table = JobTable(sla=sla)
    j = _mk_job(0)
    j.account = FleetSlotAccount(sla, j.tier, j.demand_gpus)
    slot = table.adopt(j)
    assert bool(table.sla_view[slot])
    assert int(table.sla_slot[slot]) == -1  # not registered yet
    j.account.record(0.0, 300.0, 4)  # lazy registration happens here
    assert int(table.sla_slot[slot]) == j.account.slot >= 0
    # scalar accounts are flagged out so the policy falls back per job
    k = _mk_job(1)
    kslot = table.adopt(k)
    assert not bool(table.sla_view[kslot])


def test_decision_alloc_mapping_matches_scalar_dict():
    """The lazily-materialized Decision.alloc of the table path equals
    the scalar path's dict, entry for entry."""
    fleet = _fleet()
    specs = _spec_trace(3, 8)
    now = 7 * 3600.0

    def decision_for(job_table: bool):
        jobs = [Job(**s) for s in specs]
        policy = ElasticPolicy()
        sim = FleetSimulator(
            fleet if job_table else _fleet(),
            jobs,
            policy,
            SimConfig(job_table=job_table),
        )
        table = sim.fleet.jobs  # the fleet carries the driver's table
        active = table.view(np.arange(len(jobs))) if job_table else list(jobs)
        return policy.decide(now, active, sim.fleet)

    d_t = decision_for(True)
    d_p = decision_for(False)
    assert dict(d_t.alloc) == dict(d_p.alloc)
    assert sorted(d_t.alloc.items()) == sorted(d_p.alloc.items())
    assert len(d_t.alloc) == len(specs)
    assert d_t.table_update is not None
    assert d_p.table_update is None


def test_foreign_table_jobs_keep_object_path_in_simulator():
    """Jobs already adopted by another table: the simulator must refuse
    the fast path (slot != index) and still produce a correct run."""
    foreign = JobTable()
    specs = _spec_trace(11, 6)
    jobs = [Job(**s) for s in specs]
    for j in jobs:
        foreign.adopt(j)
    fleet = _fleet()
    sim = FleetSimulator(fleet, jobs, ElasticPolicy(), SimConfig())
    assert sim._table is None  # fast path refused
    assert fleet.jobs is None  # and the fleet carries no table handle
    res = sim.run()
    d_p, a_p, _ = _run(specs, False, True, False)
    assert (
        res.utilization,
        res.completed,
        res.preemptions,
        res.migrations,
        res.resizes,
    ) == (a_p[0], a_p[1], a_p[2], a_p[3], a_p[4])


def test_fleet_handle_tracks_current_driver_and_pinned_table_cannot_grow():
    """A reused Fleet's ``jobs`` handle must follow the CURRENT
    simulator's table (never a stale detached one), and a table whose
    columns are bound into an event loop must refuse to grow (growth
    would silently decouple the bound views)."""
    fleet = _fleet()
    specs = _spec_trace(5, 4)
    sim1 = FleetSimulator(
        fleet, [Job(**s) for s in specs], ElasticPolicy(), SimConfig()
    )
    t1 = fleet.jobs
    assert t1 is sim1._table
    sim1.run()
    sim2 = FleetSimulator(
        fleet, [Job(**s) for s in specs], ElasticPolicy(), SimConfig()
    )
    assert fleet.jobs is sim2._table and fleet.jobs is not t1
    # the run bound and pinned sim1's table: adopting past its capacity
    # must assert instead of silently replacing the bound arrays
    assert t1.pinned
    extra = [_mk_job(100 + i) for i in range(t1.capacity + 1)]
    try:
        for j in extra:
            t1.adopt(j)
    except AssertionError:
        pass
    else:
        raise AssertionError("pinned table grew under a bound event loop")


def test_dataclass_repr_reads_live_columns():
    table = JobTable(clusters=["c0"])
    j = _mk_job(0)
    table.adopt(j)
    j.allocated = 4
    assert "allocated=4" in repr(j)  # dataclass repr reads properties
    table.detach(j)
    assert "allocated=4" in repr(j)  # and survives detach unchanged

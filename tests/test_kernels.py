"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.checksum.ops import _as_words, fingerprint
from repro.kernels.checksum.ref import fingerprint_u32_ref
from repro.kernels.ssd_scan.ops import ssd_chunked_pallas
from repro.kernels.ssd_scan.ref import ssd_chunked_ref, ssd_sequential_ref
from repro.kernels.swa_attention.ops import swa_attention
from repro.kernels.swa_attention.ref import swa_attention_ref


# ---------------------------------------------------------------- checksum
@pytest.mark.parametrize("shape,dtype", [
    ((1000,), jnp.float32), ((64, 128), jnp.bfloat16),
    ((7, 11, 13), jnp.int32), ((100_000,), jnp.float32),
    ((3, 5), jnp.float32), ((256, 128), jnp.uint8),
])
def test_fingerprint_matches_oracle(shape, dtype, rng_key):
    if jnp.issubdtype(dtype, jnp.floating) or dtype == jnp.bfloat16:
        x = jax.random.normal(rng_key, shape, jnp.float32).astype(dtype)
    else:
        x = jax.random.randint(rng_key, shape, 0, 100).astype(dtype)
    got = fingerprint(x)
    want = fingerprint_u32_ref(_as_words(x))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fingerprint_sensitivity(rng_key):
    x = jax.random.normal(rng_key, (4096,), jnp.float32)
    base = np.asarray(fingerprint(x))
    for i in (0, 1000, 4095):
        mod = x.at[i].add(1e-6)
        assert not np.array_equal(np.asarray(fingerprint(mod)), base)
    # permutation sensitivity (position-weighted)
    assert not np.array_equal(np.asarray(fingerprint(x[::-1])), base)


def test_fingerprint_equal_content_equal_digest(rng_key):
    x = jax.random.normal(rng_key, (512, 128), jnp.float32)
    assert np.array_equal(np.asarray(fingerprint(x)),
                          np.asarray(fingerprint(jnp.array(x))))


# ------------------------------------------------------------- attention
@pytest.mark.parametrize("b,s,h,d,w", [
    (2, 256, 4, 64, 0),       # full causal
    (1, 384, 2, 128, 128),    # window == block
    (2, 200, 3, 64, 96),      # ragged seq, odd window
    (1, 512, 2, 64, 0),
    (1, 128, 1, 32, 48),
])
def test_swa_attention_matches_oracle(b, s, h, d, w, rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    out = swa_attention(q, k, v, window=w)

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    ref = swa_attention_ref(to_bh(q), to_bh(k), to_bh(v), window=w)
    ref = ref.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_swa_attention_bf16(rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32).astype(jnp.bfloat16)
    out = swa_attention(q, k, v, window=64)
    def to_bh(x): return x.transpose(0, 2, 1, 3).reshape(2, 256, 64)
    ref = swa_attention_ref(to_bh(q.astype(jnp.float32)),
                            to_bh(k.astype(jnp.float32)),
                            to_bh(v.astype(jnp.float32)), window=64)
    ref = ref.reshape(1, 2, 256, 64).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(ref), rtol=2e-2, atol=2e-2)


# ------------------------------------------------------------------- SSD
@pytest.mark.parametrize("bs,l,h,p,n,chunk", [
    (2, 128, 4, 32, 16, 32),
    (1, 200, 2, 64, 32, 64),      # ragged length
    (2, 96, 8, 16, 64, 32),
    (1, 64, 1, 128, 128, 64),
])
def test_ssd_kernel_matches_oracles(bs, l, h, p, n, chunk, rng_key):
    ks = jax.random.split(rng_key, 5)
    x = jax.random.normal(ks[0], (bs, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, l, h)))
    a = -jnp.exp(0.1 * jax.random.normal(ks[2], (h,)))
    b = jax.random.normal(ks[3], (bs, l, n))
    c = jax.random.normal(ks[4], (bs, l, n))
    y1, s1 = ssd_chunked_pallas(x, dt, a, b, c, chunk)
    y2, s2 = ssd_chunked_ref(x, dt, a, b, c, chunk)
    y3, s3 = ssd_sequential_ref(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y3),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s3),
                               rtol=1e-3, atol=1e-3)


def test_ssd_initial_state_continuation(rng_key):
    """Chunked scan with carried state == one long scan (prefill/decode
    continuity)."""
    ks = jax.random.split(rng_key, 5)
    bs, l, h, p, n = 1, 128, 2, 32, 16
    x = jax.random.normal(ks[0], (bs, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, l, h)))
    a = -jnp.exp(0.1 * jax.random.normal(ks[2], (h,)))
    b = jax.random.normal(ks[3], (bs, l, n))
    c = jax.random.normal(ks[4], (bs, l, n))
    y_full, s_full = ssd_chunked_pallas(x, dt, a, b, c, 32)
    half = l // 2
    y1, s1 = ssd_chunked_pallas(x[:, :half], dt[:, :half], a,
                                b[:, :half], c[:, :half], 32)
    y2, s2 = ssd_chunked_pallas(x[:, half:], dt[:, half:], a,
                                b[:, half:], c[:, half:], 32,
                                initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------- fused CE
from repro.kernels.fused_ce.ops import fused_cross_entropy
from repro.kernels.fused_ce.ref import cross_entropy_ref


@pytest.mark.parametrize("t,d,v", [
    (100, 64, 500), (256, 128, 1024), (130, 32, 777), (128, 64, 512),
])
def test_fused_ce_matches_oracle(t, d, v, rng_key):
    ks = jax.random.split(rng_key, 3)
    h = jax.random.normal(ks[0], (t, d), jnp.float32)
    w = 0.05 * jax.random.normal(ks[1], (d, v), jnp.float32)
    lab = jax.random.randint(ks[2], (t,), -1, v)   # includes ignored labels
    l1, c1 = fused_cross_entropy(h, w, lab)
    l2, c2 = cross_entropy_ref(h, w, lab)
    assert float(c1) == float(c2)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_fused_ce_bf16_inputs(rng_key):
    ks = jax.random.split(rng_key, 3)
    h = jax.random.normal(ks[0], (128, 64), jnp.float32).astype(jnp.bfloat16)
    w = (0.05 * jax.random.normal(ks[1], (64, 512))).astype(jnp.bfloat16)
    lab = jax.random.randint(ks[2], (128,), 0, 512)
    l1, c1 = fused_cross_entropy(h, w, lab)
    l2, c2 = cross_entropy_ref(h.astype(jnp.float32),
                               w.astype(jnp.float32), lab)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-2)

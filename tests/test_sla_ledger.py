"""FleetSLAAccounts vs the scalar GpuFractionAccount oracle.

The fleet ledger is the decide path's SLA source at million-job scale;
its contract is bit-for-bit agreement (within 1e-9) with the scalar
account under ANY interleaving of records and queries — including
out-of-order query times, window sizes other than HOUR, coalescing
records, zero-demand accounts, and slot release/reuse.  CI's bench-smoke
job runs this module as part of the equivalence gate.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.sla import (
    HOUR,
    FleetSLAAccounts,
    FleetSlotAccount,
    GpuFractionAccount,
)

TIER_NAMES = ["premium", "standard", "basic"]
WINDOWS = [HOUR, 600.0, 1800.0, 7200.0, 411.7]


def _check_close(got: float, want: float, ctx) -> None:
    assert abs(got - want) < 1e-9, (got, want, ctx)


@settings(max_examples=60, deadline=None)
@given(
    seed=st.integers(0, 10**6),
    n_jobs=st.integers(1, 8),
    n_ops=st.integers(1, 60),
)
def test_ledger_matches_scalar_oracle_under_random_interleavings(seed, n_jobs, n_ops):
    """Random record/headroom/worst_window_fraction interleavings (single
    and fleet-batched queries, out-of-order times, non-HOUR windows) must
    agree with a fresh scalar account per job within 1e-9."""
    rng = np.random.Generator(np.random.Philox(seed))
    # tiny initial capacities force the slot- and interval-growth paths
    ledger = FleetSLAAccounts(slot_capacity=1, interval_capacity=2)
    tiers = [str(rng.choice(TIER_NAMES)) for _ in range(n_jobs)]
    demands = [int(rng.integers(0, 13)) for _ in range(n_jobs)]  # 0 legal
    views = [FleetSlotAccount(ledger, tiers[i], demands[i]) for i in range(n_jobs)]
    oracles = [GpuFractionAccount(tiers[i], demands[i]) for i in range(n_jobs)]
    frontier = [0.0] * n_jobs  # records are append-only in time per job

    def query_time() -> float:
        return float(rng.uniform(0.0, 1.5 * max(max(frontier), 1.0) + 10.0))

    for _ in range(n_ops):
        i = int(rng.integers(0, n_jobs))
        op = int(rng.integers(0, 5))
        if op == 4:
            # the simulator's write path: ONE record_batch over a random
            # job subset (mixed coalesce/append/no-op rows in one call)
            sel = np.flatnonzero(rng.integers(0, 2, n_jobs).astype(bool))
            if sel.size == 0:
                continue
            starts, ends, allocs = [], [], []
            for k in sel:
                s = frontier[k]
                if rng.integers(0, 2) == 1:
                    s += float(rng.uniform(0.0, 900.0))
                d = float(rng.choice([0.0, 1.0, 117.3, 1800.0]))
                starts.append(s)
                ends.append(s + d)
                allocs.append(int(rng.integers(0, demands[k] + 3)))
                frontier[k] = max(frontier[k], s + d)
            slots = np.array([views[k].ensure_slot() for k in sel], np.int64)
            ledger.record_batch(
                slots, np.array(starts), np.array(ends), np.array(allocs, np.int64)
            )
            for pos, k in enumerate(sel):
                oracles[k].record(starts[pos], ends[pos], allocs[pos])
            continue
        if op == 0:
            # record; half the time contiguous with the previous record so
            # the coalescing path is exercised, sometimes zero-length
            start = frontier[i]
            if rng.integers(0, 2) == 1:
                start += float(rng.uniform(0.0, 900.0))
            dur = float(rng.choice([0.0, 1.0, 117.3, 1800.0, 4000.0]))
            alloc = int(rng.integers(0, demands[i] + 3))
            views[i].record(start, start + dur, alloc)
            oracles[i].record(start, start + dur, alloc)
            frontier[i] = max(frontier[i], start + dur)
        elif op == 1:
            now = query_time()
            window = float(rng.choice(WINDOWS))
            _check_close(
                views[i].headroom(now, window),
                oracles[i].headroom(now, window),
                ("headroom", i, now, window),
            )
        elif op == 2:
            now = query_time()
            window = float(rng.choice(WINDOWS))
            _check_close(
                views[i].worst_window_fraction(now, window),
                oracles[i].worst_window_fraction(now, window),
                ("worst", i, now, window),
            )
        else:
            # the decide path's shape: one batched query over the fleet
            now = query_time()
            window = float(rng.choice(WINDOWS))
            slots = np.array([v.slot for v in views], np.int64)
            gfrac = np.array([o.tier.gpu_fraction for o in oracles])
            got = ledger.headroom_all(now, slots, gfrac, window=window)
            for k, o in enumerate(oracles):
                _check_close(
                    float(got[k]),
                    o.headroom(now, window),
                    ("batched", k, now, window),
                )


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_unfinalized_frontier_rule_matches_scalar(seed):
    """A query issued past the recorded frontier must not poison the
    window cache: later records re-evaluate those windows, exactly like
    the scalar account's finalization rule."""
    rng = np.random.Generator(np.random.Philox(seed))
    ledger = FleetSLAAccounts(slot_capacity=1, interval_capacity=2)
    view = FleetSlotAccount(ledger, "standard", 8)
    oracle = GpuFractionAccount("standard", 8)
    for acc in (view, oracle):
        acc.record(0.0, 1800.0, 8)
    # query far past the frontier: windows beyond 1800s are not final
    early_now = float(rng.uniform(3600.0, 4 * HOUR))
    _check_close(
        view.worst_window_fraction(early_now),
        oracle.worst_window_fraction(early_now),
        "past-frontier query",
    )
    # now the interval actually gets recorded with full allocation
    for acc in (view, oracle):
        acc.record(1800.0, early_now, 8)
    for now in (early_now, early_now + HOUR / 3, early_now * 2):
        _check_close(
            view.worst_window_fraction(now),
            oracle.worst_window_fraction(now),
            ("post-record query", now),
        )
        _check_close(
            view.headroom(now), oracle.headroom(now), ("headroom", now)
        )


def test_empty_and_unregistered_accounts_answer_like_scalar():
    ledger = FleetSLAAccounts()
    view = FleetSlotAccount(ledger, "premium", 16)
    oracle = GpuFractionAccount("premium", 16)
    assert view.slot == -1  # lazy: no slot until a real record lands
    for now in (0.0, 1800.0, 7200.0):
        _check_close(
            view.worst_window_fraction(now),
            oracle.worst_window_fraction(now),
            now,
        )
        _check_close(view.headroom(now), oracle.headroom(now), now)
        assert view.delivered_seconds(0.0, now) == 0.0
    # zero-length records stay no-ops and never register a slot
    view.record(10.0, 10.0, 8)
    assert view.slot == -1
    # batched query over unregistered slots answers 1.0 - gfrac
    got = ledger.headroom_all(
        1800.0, np.array([-1, -1], np.int64), np.array([0.95, 0.0])
    )
    assert abs(got[0] - 0.05) < 1e-12
    assert abs(got[1] - 1.0) < 1e-12


def test_slot_release_and_reuse():
    ledger = FleetSLAAccounts(slot_capacity=1, interval_capacity=2)
    a = FleetSlotAccount(ledger, "standard", 8)
    a.record(0.0, 1800.0, 4)
    slot_a = a.slot
    assert ledger.slots_in_use == 1
    assert a.worst_window_fraction(1800.0) < 1.0
    a.release()
    assert ledger.slots_in_use == 0
    # the freed row is reused and starts fresh
    b = FleetSlotAccount(ledger, "premium", 2)
    b.record(0.0, 900.0, 2)
    assert b.slot == slot_a
    oracle = GpuFractionAccount("premium", 2)
    oracle.record(0.0, 900.0, 2)
    _check_close(
        b.worst_window_fraction(900.0),
        oracle.worst_window_fraction(900.0),
        "reused slot",
    )
    # a released view refuses further use
    try:
        a.headroom(3600.0)
    except RuntimeError:
        pass
    else:
        raise AssertionError("released account should raise on query")

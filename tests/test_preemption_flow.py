"""On-demand preemption through the in-graph barrier (§4: scheduler command
-> tandem meta-allreduce rides the compiled step -> quiesce -> checkpoint
-> resume), end to end on the elastic runtime."""
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.core.checkpoint import CheckpointStore
from repro.core.elastic import ElasticRuntime
from repro.core.migration import checkpoint_job

CFG = get_smoke_config("olmo-1b")
TCFG = TrainConfig(total_steps=40, warmup_steps=2, learning_rate=1e-3)


def test_preemption_via_in_graph_barrier():
    rt = ElasticRuntime(CFG, TCFG, 4, 4, 8, 32)
    recs = rt.run_steps(2)
    assert not any(r["barrier_acquired"] for r in recs)   # phase 1 is free

    rt.request_preemption()
    recs = rt.run_steps(4, stop_on_barrier=True)
    # the paper's bound: quiesced within two mini-batches of the command
    assert len(recs) <= 2
    assert recs[-1]["barrier_acquired"] and rt.quiesced

    # checkpoint at the quiesced boundary, release, resume
    store = CheckpointStore()
    stats = checkpoint_job(rt, store, "preempt-job")
    assert stats.device_stored_bytes > 0
    step_at_ckpt = int(rt.state["step"])
    rt.barrier.reset()
    more = rt.run_steps(2)
    assert int(rt.state["step"]) == step_at_ckpt + 2
    assert not any(r["barrier_acquired"] for r in more)

    # restore elsewhere: exactly the checkpointed step
    device, host, step = store.restore("preempt-job")
    assert step == step_at_ckpt
    resumed = ElasticRuntime.from_snapshot(
        CFG, TCFG,
        {"state": device[0], "pipeline": host[0]["pipeline"],
         "world_size": host[0]["world_size"]}, 2, 8, 32)
    assert int(resumed.state["step"]) == step_at_ckpt
    l = resumed.run_steps(1)[0]["loss"]
    assert np.isfinite(l)
